"""Benchmark-artifact schema guard: fail the build on column drift.

``BENCH_simulate.json`` and ``BENCH_profile.json`` are quoted by the
README and consumed by CI artifact diffing; a benchmark refactor that
renames or drops a column silently breaks both.  This guard pins the
required keys (top-level and per-row) of every committed benchmark
artifact; run it after any benchmark change:

    PYTHONPATH=src python -m benchmarks.schema_guard [PATHS...]

With no arguments it checks the repo-root artifacts that exist;
``BENCH_simulate.json`` must exist (it is committed), ``BENCH_profile``
is checked when present.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: artifact name -> (required top-level keys, required per-row keys[,
#: extra list sections: {key: required per-entry keys} — the section
#: itself must exist but may be empty)
SCHEMAS = {
    "BENCH_simulate.json": (
        {"benchmark", "platform", "max_transitions", "pairs", "candidates",
         "repeats", "lowering_s", "scalar_s", "batch_s",
         "scalar_cands_per_s", "batch_cands_per_s", "speedup",
         "max_abs_makespan_diff", "rows"},
        {"pair", "iterations", "candidates", "best_makespan_ms"},
    ),
    "BENCH_search.json": (
        {"benchmark", "platform", "solver", "max_transitions", "pairs",
         "population", "seed", "repeats", "device_count", "host_cores",
         "total_evaluated", "search_cands_per_s", "speedup_vs_jax_eval",
         "worst_gap_rel", "scaling", "scenarios", "rows"},
        {"pair", "iterations", "space", "population", "steps", "evaluated",
         "device_count", "search_s", "compile_s", "cands_per_s",
         "objective_ms", "bb_objective_ms", "gap_rel"},
        # extra list sections: key -> required per-entry keys ("scaling"
        # may be empty — populated only by --device-sweep runs).
        {"scaling": {"devices", "per_device_population", "population",
                     "steps", "evaluated", "search_s", "cands_per_s",
                     "worst_gap_rel", "digest", "digest_backend_ok",
                     "digest_fanout_ok", "digest_chunk_ok",
                     "speedup_vs_1dev", "digest_invariant"}},
    ),
    "BENCH_gateway.json": (
        {"benchmark", "splits", "tenant_mix", "fleet_tenants", "requests",
         "seed", "trace_kind", "trace_hash", "base_rps", "burst_rps",
         "slo_p99_ms", "plan_cold_solves", "plan_cold_s",
         "cache_boot_solves", "cache_boot_s", "p99_speedup", "rows"},
        {"policy", "requests", "completed", "shed", "p50_ms", "p99_ms",
         "sustained_rps", "slo_p99_violations", "served_tenants",
         "replay_s", "replay_req_per_s"},
    ),
    "BENCH_recalibrate.json": (
        {"benchmark", "splits", "tenant_mix", "fleet_tenants", "requests",
         "seed", "trace_hash", "slo_p99_ms", "drift",
         "offline_bundle_hash", "offline_fit_max_rel_err", "bundle_s",
         "refits", "lineage_depth", "head_bundle_hash",
         "refit_max_rel_err", "frozen_max_rel_err", "err_budget",
         "violations_frozen", "violations_closed",
         "recalibration_events", "rows"},
        {"arm", "requests", "completed", "shed", "throttled", "p50_ms",
         "p99_ms", "slo_p99_violations", "served_tenants", "reschedules",
         "recalibrations", "throttle_events", "replay_s"},
    ),
    "BENCH_obs.json": (
        {"benchmark", "requests", "repeats", "seed", "trace_hash",
         "disabled_ns_per_span", "replay_disabled_s", "replay_traced_s",
         "overhead_pct", "overhead_gate_pct", "overhead_gated",
         "export_s", "exported_spans", "trace_events", "trace_bytes",
         "determinism_requests", "determinism_ok", "rows"},
        {"mode", "replay_s", "replay_req_per_s", "events",
         "exported_spans"},
    ),
    "BENCH_profile.json": (
        {"benchmark", "worst_fit_max_rel_err", "worst_vs_generating",
         "worst_objective_rel_diff", "rows"},
        {"platform", "dnns", "generating_model", "fit_kind", "n_samples",
         "fit_rmse", "fit_max_rel_err", "max_rel_err_vs_generating",
         "objective_rel_diff", "bundle_hash", "pipeline_s"},
    ),
}

#: artifacts that must exist even when no path is passed explicitly.
REQUIRED = ("BENCH_simulate.json",)


def check(path: pathlib.Path) -> list[str]:
    """Problems with one artifact ([] = schema holds).

    CI smoke runs write reduced-size artifacts named
    ``BENCH_<x>_smoke.json``; they are held to the same schema as the
    committed ``BENCH_<x>.json``.
    """
    schema = SCHEMAS.get(path.name.replace("_smoke.json", ".json"))
    if schema is None:
        return [f"{path.name}: no schema registered "
                f"(known: {', '.join(sorted(SCHEMAS))})"]
    top_required, row_required = schema[0], schema[1]
    sections = schema[2] if len(schema) > 2 else {}
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path}: unreadable ({exc})"]
    problems = [f"{path.name}: missing top-level key {k!r}"
                for k in sorted(top_required - set(data))]
    rows = data.get("rows", [])
    if not isinstance(rows, list) or not rows:
        problems.append(f"{path.name}: 'rows' must be a non-empty list")
        return problems
    for i, row in enumerate(rows):
        missing = row_required - set(row)
        if missing:
            problems.append(f"{path.name}: rows[{i}] missing "
                            f"{', '.join(sorted(missing))}")
    for key, entry_required in sections.items():
        entries = data.get(key)
        if not isinstance(entries, list):
            problems.append(f"{path.name}: {key!r} must be a list")
            continue
        for i, entry in enumerate(entries):
            missing = entry_required - set(entry)
            if missing:
                problems.append(f"{path.name}: {key}[{i}] missing "
                                f"{', '.join(sorted(missing))}")
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv:
        paths = [pathlib.Path(a) for a in argv]
    else:
        paths = [ROOT / name for name in SCHEMAS
                 if (ROOT / name).exists() or name in REQUIRED]
    problems = []
    for p in paths:
        if not p.exists():
            problems.append(f"{p}: missing (required benchmark artifact)")
            continue
        found = check(p)
        problems.extend(found)
        if not found:
            print(f"{p.name}: schema OK "
                  f"({len(json.loads(p.read_text())['rows'])} rows)")
    for msg in problems:
        print(f"ERROR: {msg}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Table 6: the paper's ten concurrent-DNN experiments across three SoCs.

Each experiment runs every baseline plus HaX-CoNN under the exact contention
simulator and reports latency / FPS and the improvement over the *best*
baseline, side by side with the paper's published improvement.  Scenario
semantics follow §5.2:

  * scenario 2  — two DNNs on the same input, synchronized (concurrent).
  * scenario 3  — streaming pipeline: DNN-2's iteration k consumes DNN-1's
    iteration-k output (``depends_on``); several frames in flight.
  * scenario 4  — a serial chain (DNN-a → DNN-b) concurrent with a third DNN.
"""
from __future__ import annotations

from repro.core import Scheduler
from repro.core.profiles import chain, get_graph
from repro.core.scheduler import failed
from repro.obs import get_logger

from .common import emit, fmt_table, timed

log = get_logger(__name__)

#: exp no -> (platform, objective, dnn spec, scenario, paper impr lat%, fps%)
EXPERIMENTS = {
    1: ("xavier-agx", "latency", ["vgg19", "resnet152"], 2, 23, 22),
    2: ("xavier-agx", "latency", ["resnet152", "inception"], 2, 20, 18),
    3: ("xavier-agx", "throughput", ["alexnet", "resnet101"], 3, 26, 23),
    4: ("xavier-agx", "throughput", ["resnet101", "googlenet"], 3, 0, 0),
    5: ("xavier-agx", "latency", [("googlenet", "resnet152"), "fcn-resnet18"],
        4, 22, 21),
    6: ("agx-orin", "latency", ["vgg19", "resnet152"], 2, 23, 22),
    7: ("agx-orin", "throughput", ["googlenet", "resnet101"], 3, 19, 18),
    8: ("agx-orin", "latency", [("resnet101", "googlenet"), "inception"],
        4, 13, 12),
    9: ("snapdragon-865", "throughput", ["googlenet", "resnet101"], 3, 11, 10),
    10: ("snapdragon-865", "latency", ["inception", "resnet152"], 2, 15, 15),
}

PIPELINE_FRAMES = 4


def build(plat, spec, scenario):
    graphs, deps, its = [], [], []
    for item in spec:
        if isinstance(item, tuple):          # serial chain inside one slot
            graphs.append(chain(*[get_graph(d, plat) for d in item]))
        else:
            graphs.append(get_graph(item, plat))
        deps.append(None)
        its.append(1)
    if scenario == 3:                        # streaming: 1 -> 2 per frame
        deps[1] = 0
        its = [PIPELINE_FRAMES] * len(graphs)
    return graphs, deps, its


def run_experiment(no: int) -> dict:
    plat_name, objective, spec, scenario, p_lat, p_fps = EXPERIMENTS[no]
    sched = Scheduler(plat_name)
    graphs, deps, its = build(sched.platform, spec, scenario)

    with timed() as t:
        rows = sched.compare(graphs, objective, max_transitions=2,
                             iterations=its, depends_on=deps,
                             deadline_s=30.0)
    plan = rows.pop("haxconn")
    if failed(plan):
        raise RuntimeError(f"exp {no}: solver failed: {plan['error']}")
    sol = plan.solution
    # structured per-row failure reasons: "infeasible" vs "crashed" is now
    # visible in the benchmark output instead of a silent None.
    errors = {k: v["error"] for k, v in rows.items() if failed(v)}
    usable = {k: v for k, v in rows.items() if not failed(v)}
    best_name = min(usable, key=lambda k: usable[k].objective(objective))
    best = usable[best_name]
    lat_impr = 100 * (1 - sol.result.latency_ms / best.latency_ms)
    fps_impr = 100 * (sol.result.throughput_fps / best.throughput_fps - 1)
    return dict(
        no=no, platform=plat_name, objective=objective, scenario=scenario,
        dnns="+".join(str(s) for s in spec),
        best_baseline=best_name,
        base_lat=best.latency_ms, base_fps=best.throughput_fps,
        hax_lat=sol.result.latency_ms, hax_fps=sol.result.throughput_fps,
        lat_impr=lat_impr, fps_impr=fps_impr,
        paper_lat_impr=p_lat, paper_fps_impr=p_fps,
        optimal=sol.optimal, solver_s=t["s"],
        solver=plan.solver, solve_s=plan.solve_time_s,
        plan_hash=plan.request_hash,
        baseline_errors=errors,
        assignments=[list(a) for a in sol.assignments],
    )


def main() -> list[dict]:
    rows = []
    out = []
    for no in EXPERIMENTS:
        r = run_experiment(no)
        rows.append(r)
        out.append([r["no"], r["platform"], r["objective"][:4], r["dnns"][:34],
                    r["best_baseline"], f"{r['base_lat']:.2f}",
                    f"{r['hax_lat']:.2f}", f"{r['lat_impr']:+.0f}%",
                    f"{r['paper_lat_impr']}%", f"{r['fps_impr']:+.0f}%",
                    f"{r['paper_fps_impr']}%",
                    "opt" if r["optimal"] else "time",
                    f"{r['solver']}:{r['solve_s']:.1f}s"])
        for name, err in r["baseline_errors"].items():
            log.warning("exp%s: baseline %s failed (%s): %s",
                        no, name, err["type"], err["message"])
        emit(f"table6.exp{no}", r["solver_s"] * 1e6,
             f"lat_impr={r['lat_impr']:.1f}%;paper={r['paper_lat_impr']}%;"
             f"fps_impr={r['fps_impr']:.1f}%;paper_fps={r['paper_fps_impr']}%")
    print("\n== Table 6: concurrent DNN scenarios vs best baseline ==")
    print(fmt_table(
        ["#", "platform", "obj", "DNNs", "best-base", "base lat",
         "hax lat", "lat impr", "paper", "fps impr", "paper", "cert",
         "solve"], out))
    return rows


if __name__ == "__main__":
    main()

"""Candidate-evaluation throughput: batch/jax evaluators vs scalar simulator.

Reproduces the hot loop behind Table 8: for every unordered DNN pair of the
evaluation set on AGX Orin, enumerate the full exhaustive assignment
population (``max_transitions`` transitions per DNN, §5.4 iteration
balancing) and score every candidate schedule under the exact Eq. 2-8
timeline — through the scalar event-driven simulator (one timeline at a
time), the vectorized NumPy batch evaluator (the whole sweep as one
lockstep pass), and the XLA evaluator (:mod:`repro.core.simulate_jax`,
jit+vmap over the lowered :class:`~repro.core.lowering.ProblemSpec`).

Writes ``BENCH_simulate.json`` (repo root) with per-pair rows and the
aggregate candidates/second of all paths; the README performance table
quotes it, and CI uploads it as an artifact.  Every path records the
minimum over ``--repeats`` steady-state runs (the same protocol for the
scalar and vectorized paths), and the jax column records **jit compile
time separately from steady-state throughput**, so the Table-8 sweep
numbers stay honest: a one-shot solve pays the compile, a search loop
does not.  Agreement is asserted while
measuring — batch vs scalar to 1e-6, jax (float64) vs scalar to 1e-6 — so
the benchmark doubles as a coarse differential check.

    PYTHONPATH=src python -m benchmarks.bench_simulate [--pairs N]
    [--max-transitions T] [--out PATH] [--skip-jax]
"""
from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import time

import numpy as np

from repro.core import Scheduler
from repro.core.lowering import lower_sweep
from repro.core.simulate import Workload, simulate
from repro.core.simulate_batch import simulate_spec
from repro.core.solver_bb import enumerate_assignments
from repro.core.profiles import DNN_SET

from .common import emit, fmt_table

from .table8_exhaustive import balanced_iterations

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_simulate.json"


def build_problems(sched: Scheduler, pairs, max_transitions: int):
    problems = []
    for a, b in pairs:
        graphs = sched.graphs([a, b])
        its = balanced_iterations(sched.platform, graphs)
        cands = [enumerate_assignments(g, sched.platform.names,
                                       max_transitions) for g in graphs]
        problems.append(((a, b), graphs, cands, its))
    return problems


def _best_of(fn, repeats: int) -> tuple[float, object]:
    """Steady-state wall time: min over ``repeats`` runs (the standard
    answer to scheduler/cache noise on shared boxes) + last result."""
    best, out = float("inf"), None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(pairs_limit: int | None, max_transitions: int,
        out_path: pathlib.Path, skip_jax: bool = False,
        repeats: int = 3) -> dict:
    sched = Scheduler("agx-orin")
    plat, model = sched.platform, sched.model
    pairs = list(itertools.combinations(DNN_SET, 2))
    if pairs_limit:
        pairs = pairs[:pairs_limit]
    problems = build_problems(sched, pairs, max_transitions)
    sizes = [int(np.prod([len(c) for c in cands]))
             for _, _, cands, _ in problems]
    total = sum(sizes)
    print(f"Table-8 sweep: {len(problems)} pairs, {total} candidate "
          f"schedules (max_transitions={max_transitions})")

    # -- lowering: one ProblemSpec for the whole sweep (shared by both
    # vectorized paths; lowering cost is reported separately) -------------
    t0 = time.perf_counter()
    spec, slices = lower_sweep(
        plat,
        [(graphs, cands, its, None)
         for _pair, graphs, cands, its in problems],
        model, validate=False)
    t_lower = time.perf_counter() - t0

    # -- scalar path: one event-driven timeline per candidate.  Same
    # best-of-N protocol as the vectorized paths below, so the recorded
    # speedups compare steady states symmetrically (Workload construction
    # stays inside the timed loop: it is the scalar path's packing cost,
    # just as lowering — reported separately — is the vectorized paths').
    def scalar_sweep():
        makespans = []
        for _pair, graphs, cands, its in problems:
            for asgs in itertools.product(*cands):
                wls = [Workload(g, tuple(asg), iterations=it)
                       for g, asg, it in zip(graphs, asgs, its)]
                res = simulate(plat, wls, model, record_timeline=False)
                makespans.append(res.makespan)
        return np.asarray(makespans)

    t_scalar, scalar_makespans = _best_of(scalar_sweep, repeats)

    # -- batch path: the whole sweep in one lockstep NumPy pass -----------
    t_batch, bt = _best_of(lambda: simulate_spec(spec), repeats)

    diff = float(np.abs(bt.makespan - scalar_makespans).max())
    assert diff < 1e-6, f"batch/scalar disagreement: {diff}"

    # -- jax path: same spec through the XLA evaluator ---------------------
    jax_fields: dict = {}
    try:
        from repro.core import simulate_jax
        have_jax = simulate_jax.HAVE_JAX and not skip_jax
    except ImportError:
        have_jax = False
    if have_jax:
        t0 = time.perf_counter()
        btj = simulate_jax.simulate_spec(spec)
        t_jax_first = time.perf_counter() - t0      # compile + run
        t_jax, btj = _best_of(                       # steady state
            lambda: simulate_jax.simulate_spec(spec), repeats)
        diff_jax = float(np.abs(btj.makespan - scalar_makespans).max())
        assert diff_jax < 1e-6, f"jax/scalar disagreement: {diff_jax}"
        jax_fields = {
            "jax_s": round(t_jax, 4),
            "jax_first_call_s": round(t_jax_first, 4),
            # compile time kept separate from steady-state throughput so
            # the sweep numbers stay honest (one-shot solves pay this once
            # per shape bucket; search loops do not).
            "jax_compile_s": round(max(0.0, t_jax_first - t_jax), 4),
            "jax_cands_per_s": round(total / t_jax, 1),
            "speedup_jax_vs_scalar": round(t_scalar / t_jax, 2),
            "speedup_jax_vs_batch": round(t_batch / t_jax, 2),
            "max_abs_makespan_diff_jax": diff_jax,
        }

    rows = []
    for (pair, _g, cands, its), size, sl in zip(problems, sizes, slices):
        rows.append({
            "pair": list(pair), "iterations": its,
            "candidates": size,
            "best_makespan_ms": float(bt.makespan[sl].min()),
        })
    result = {
        "benchmark": "table8_candidate_evaluation",
        "platform": "agx-orin",
        "max_transitions": max_transitions,
        "pairs": len(problems),
        "candidates": total,
        #: every path reports min-of-N steady-state wall time; one-time
        #: costs (lowering, jit compile) are separate fields.
        "repeats": max(1, repeats),
        "timing": "min over `repeats` runs per path; lowering_s (shared "
                  "by batch/jax) and jax compile time reported separately",
        "lowering_s": round(t_lower, 4),
        "scalar_s": round(t_scalar, 4),
        "batch_s": round(t_batch, 4),
        "scalar_cands_per_s": round(total / t_scalar, 1),
        "batch_cands_per_s": round(total / t_batch, 1),
        "speedup": round(t_scalar / t_batch, 2),
        "max_abs_makespan_diff": diff,
        **jax_fields,
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    table_rows = [
        ["scalar", f"{t_scalar:.2f}", f"{total / t_scalar:.0f}", "-"],
        ["batch", f"{t_batch:.2f}", f"{total / t_batch:.0f}", "-"],
    ]
    if jax_fields:
        table_rows.append(["jax", f"{jax_fields['jax_s']:.2f}",
                           f"{jax_fields['jax_cands_per_s']:.0f}",
                           f"{jax_fields['jax_compile_s']:.2f}"])
    print(fmt_table(["path", "wall s", "candidates/s", "compile s"],
                    table_rows))
    print(f"batch speedup: {result['speedup']}x "
          f"(max |makespan diff| = {diff:.2e})")
    if jax_fields:
        print(f"jax speedup: {jax_fields['speedup_jax_vs_scalar']}x vs "
              f"scalar, {jax_fields['speedup_jax_vs_batch']}x vs batch "
              f"(max |makespan diff| = "
              f"{jax_fields['max_abs_makespan_diff_jax']:.2e})")
    print(f"wrote {out_path}")
    emit("bench_simulate.candidate_throughput", t_batch * 1e6,
         f"speedup={result['speedup']}x;candidates={total};"
         f"batch_cps={result['batch_cands_per_s']:.0f}")
    if jax_fields:
        emit("bench_simulate.jax_candidate_throughput",
             jax_fields["jax_s"] * 1e6,
             f"jax_cps={jax_fields['jax_cands_per_s']:.0f};"
             f"compile_s={jax_fields['jax_compile_s']}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairs", type=int, default=None,
                    help="limit the sweep to the first N pairs (default: "
                         "all 45)")
    ap.add_argument("--max-transitions", type=int, default=2,
                    help="transition budget per DNN for the candidate "
                         "population (default 2)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--skip-jax", action="store_true",
                    help="measure only the scalar/batch paths")
    ap.add_argument("--repeats", type=int, default=3,
                    help="steady-state runs per path (scalar included — "
                         "the dominant ~50s leg — as well as batch/jax); "
                         "the minimum is recorded (default 3)")
    args = ap.parse_args(argv)
    return run(args.pairs, args.max_transitions, args.out,
               skip_jax=args.skip_jax, repeats=args.repeats)


if __name__ == "__main__":
    main()

"""Candidate-evaluation throughput: batch evaluator vs scalar simulator.

Reproduces the hot loop behind Table 8: for every unordered DNN pair of the
evaluation set on AGX Orin, enumerate the full exhaustive assignment
population (``max_transitions`` transitions per DNN, §5.4 iteration
balancing) and score every candidate schedule under the exact Eq. 2-8
timeline — once through the scalar event-driven simulator (one timeline at
a time) and once through the vectorized batch evaluator (the whole sweep as
one lockstep pass via :func:`repro.core.simulate_batch.simulate_sweep`).

Writes ``BENCH_simulate.json`` (repo root) with per-pair rows and the
aggregate candidates/second of both paths; the README performance table
quotes it, and CI uploads it as an artifact.  Agreement between the two
paths is asserted to 1e-6 on every candidate's makespan while measuring —
the benchmark doubles as a coarse differential check.

    PYTHONPATH=src python -m benchmarks.bench_simulate [--pairs N]
    [--max-transitions T] [--out PATH]
"""
from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import time

import numpy as np

from repro.core import Scheduler
from repro.core.simulate import Workload, simulate
from repro.core.simulate_batch import simulate_sweep
from repro.core.solver_bb import enumerate_assignments
from repro.core.profiles import DNN_SET

from .common import emit, fmt_table

from .table8_exhaustive import balanced_iterations

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_simulate.json"


def build_problems(sched: Scheduler, pairs, max_transitions: int):
    problems = []
    for a, b in pairs:
        graphs = sched.graphs([a, b])
        its = balanced_iterations(sched.platform, graphs)
        cands = [enumerate_assignments(g, sched.platform.names,
                                       max_transitions) for g in graphs]
        problems.append(((a, b), graphs, cands, its))
    return problems


def run(pairs_limit: int | None, max_transitions: int,
        out_path: pathlib.Path) -> dict:
    sched = Scheduler("agx-orin")
    plat, model = sched.platform, sched.model
    pairs = list(itertools.combinations(DNN_SET, 2))
    if pairs_limit:
        pairs = pairs[:pairs_limit]
    problems = build_problems(sched, pairs, max_transitions)
    sizes = [int(np.prod([len(c) for c in cands]))
             for _, _, cands, _ in problems]
    total = sum(sizes)
    print(f"Table-8 sweep: {len(problems)} pairs, {total} candidate "
          f"schedules (max_transitions={max_transitions})")

    # -- scalar path: one event-driven timeline per candidate -------------
    t0 = time.perf_counter()
    scalar_makespans = []
    for _pair, graphs, cands, its in problems:
        for asgs in itertools.product(*cands):
            wls = [Workload(g, tuple(asg), iterations=it)
                   for g, asg, it in zip(graphs, asgs, its)]
            res = simulate(plat, wls, model, record_timeline=False)
            scalar_makespans.append(res.makespan)
    t_scalar = time.perf_counter() - t0

    # -- batch path: the whole sweep in one lockstep pass -----------------
    t0 = time.perf_counter()
    bt, slices = simulate_sweep(
        plat,
        [(graphs, cands, its, None)
         for _pair, graphs, cands, its in problems],
        model, validate=False)
    t_batch = time.perf_counter() - t0

    diff = float(np.abs(bt.makespan
                        - np.asarray(scalar_makespans)).max())
    assert diff < 1e-6, f"batch/scalar disagreement: {diff}"

    rows = []
    for (pair, _g, cands, its), size, sl in zip(problems, sizes, slices):
        rows.append({
            "pair": list(pair), "iterations": its,
            "candidates": size,
            "best_makespan_ms": float(bt.makespan[sl].min()),
        })
    result = {
        "benchmark": "table8_candidate_evaluation",
        "platform": "agx-orin",
        "max_transitions": max_transitions,
        "pairs": len(problems),
        "candidates": total,
        "scalar_s": round(t_scalar, 4),
        "batch_s": round(t_batch, 4),
        "scalar_cands_per_s": round(total / t_scalar, 1),
        "batch_cands_per_s": round(total / t_batch, 1),
        "speedup": round(t_scalar / t_batch, 2),
        "max_abs_makespan_diff": diff,
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    print(fmt_table(
        ["path", "wall s", "candidates/s"],
        [["scalar", f"{t_scalar:.2f}", f"{total / t_scalar:.0f}"],
         ["batch", f"{t_batch:.2f}", f"{total / t_batch:.0f}"]]))
    print(f"speedup: {result['speedup']}x "
          f"(max |makespan diff| = {diff:.2e})")
    print(f"wrote {out_path}")
    emit("bench_simulate.candidate_throughput", t_batch * 1e6,
         f"speedup={result['speedup']}x;candidates={total};"
         f"batch_cps={result['batch_cands_per_s']:.0f}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairs", type=int, default=None,
                    help="limit the sweep to the first N pairs (default: "
                         "all 45)")
    ap.add_argument("--max-transitions", type=int, default=2,
                    help="transition budget per DNN for the candidate "
                         "population (default 2)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(args.pairs, args.max_transitions, args.out)


if __name__ == "__main__":
    main()

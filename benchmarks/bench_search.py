"""Device-resident schedule-search throughput and solution quality.

Runs the ``anneal`` solver's compiled island search
(:mod:`repro.core.search_jax`) over the Table-8 pair spaces on AGX Orin
and reports:

* **throughput** — steady-state candidates/second of the annealing loop
  (mutation + full Eq. 2-8 timeline evaluation + Metropolis/incumbent
  selection per step), with jit compile time reported separately, per
  pair and aggregate.  ``speedup_vs_jax_eval`` relates the aggregate to
  the plain jit+vmap evaluator sweep recorded in ``BENCH_simulate.json``
  — the search adds mutation/selection work per candidate on top of
  evaluation, so parity-or-better here means the annealing machinery is
  effectively free.  Both loops are op-dispatch bound on a single-core
  CPU host; on an accelerator-backed deployment the same program scales
  with device parallelism instead.
* **quality** — per pair, the incumbent's scalar-re-simulated objective
  against the exact branch-and-bound optimum (``gap_rel``); plus the
  three golden Table-6 scenario shapes (concurrent pair, streaming
  pipeline, chain + third DNN) as an end-to-end ``anneal`` vs ``bb``
  solver comparison.

The search budget scales with each pair's exhaustive space size, so
small spaces are not over-sampled and large spaces are not starved.

``--device-sweep 1,2,4,8`` additionally measures the multi-device mesh
path (``shard_map`` fan-out + ring elite migration): each device count
runs in a fresh subprocess whose ``XLA_FLAGS`` emulate that many host
devices (:mod:`repro.core.xla_env`), at equal *per-device* population.
Every sweep point also re-runs a fixed-total-population search and
digests its incumbents — the digests must agree across device counts and
select-kernel backends (the determinism contract), and the scalar
re-simulated quality keeps its gap vs exact bb.  ``host_cores`` is
recorded because emulated devices time-share the host CPU: aggregate
scaling on a 1-core CI box is bounded by arithmetic intensity, not by
the fan-out (accelerator deployments scale with real device count).

Writes ``BENCH_search.json`` (repo root), guarded by
:mod:`benchmarks.schema_guard`; the README performance table quotes it
and the scheduled CI lane uploads it as an artifact.

    PYTHONPATH=src python -m benchmarks.bench_search [--pairs N]
    [--population P] [--repeats R] [--device-sweep 1,2,4,8] [--out PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import itertools
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.core import Scheduler, search_jax, solver_anneal, xla_env
from repro.obs import Tracer, set_tracer
from repro.core.simulate import Workload, simulate
from repro.core.solver_bb import enumerate_assignments
from repro.core.profiles import DNN_SET

from .common import emit, fmt_table
from .table6_scenarios import EXPERIMENTS, build as build_scenario
from .table8_exhaustive import balanced_iterations

ROOT = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_OUT = ROOT / "BENCH_search.json"

#: Table-6 experiments with golden bb plans (one per scenario shape).
SCENARIO_EXPS = (1, 4, 8)

#: fixed total population for the cross-device determinism digest: must
#: divide by island (32) x the largest swept device count.
DIGEST_POPULATION = 1024
DIGEST_STEPS = 24


def _best_of(fn, repeats: int) -> tuple[float, object]:
    """Min-of-N steady-state wall time + last result (the same protocol
    as bench_simulate, so the two artifacts compare symmetrically)."""
    best, out = float("inf"), None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _budget(space: int, population: int) -> int:
    """Annealing steps ∝ exhaustive-space size: ~4 evaluations per
    distinct candidate, clamped to a sane range (the stochastic search
    revisits states, so matching the exhaustive count would under-cover
    the space)."""
    return int(np.clip(round(4 * space / population), 48, 384))


def run_pairs(sched: Scheduler, pairs, population: int, seed: int,
              repeats: int) -> list[dict]:
    plat, model = sched.platform, sched.model
    rows = []
    for a, b in pairs:
        graphs = sched.graphs([a, b])
        its = balanced_iterations(plat, graphs)
        space = int(np.prod([len(enumerate_assignments(g, plat.names, 2))
                             for g in graphs]))
        tables = search_jax.build_tables(plat, graphs, model, 2,
                                         iterations=its)
        steps = _budget(space, population)
        kw = dict(objective="latency", seed=seed, population=population,
                  steps=steps)
        t0 = time.perf_counter()
        search_jax.anneal_search(tables, **kw)      # compile + run
        t_first = time.perf_counter() - t0
        t_search, out = _best_of(
            lambda: search_jax.anneal_search(tables, **kw), repeats)
        # compile attribution: an explicit AOT lower+compile of a fresh
        # executable, min-of-repeats — first_call_s - search_s is a
        # single sample and reads ~0 for every pair after the first in a
        # (w, gmax, amax) shape bucket (jit cache hit).  compile_seconds
        # measures internally (a "search.compile" trace span + the
        # search_compile_s gauge), so read the instrumented samples off
        # the tracer instead of re-timing the call from outside.
        tr = Tracer()
        prev = set_tracer(tr)
        try:
            for _ in range(max(1, repeats)):
                search_jax.compile_seconds(tables, objective="latency",
                                           population=population)
        finally:
            set_tracer(prev)
        t_compile = min(e["args"]["compile_s"] for e in tr.events()
                        if e["name"] == "search.compile")

        # scalar re-simulation is authoritative for the reported quality
        wls = [Workload(g, asg, iterations=it)
               for g, asg, it in zip(graphs, out.assignment, its)]
        obj = simulate(plat, wls, model,
                       record_timeline=False).objective("latency")
        bb = sched.solve(graphs, "latency", solver="bb", max_transitions=2,
                         iterations=its, evaluator="batch")
        gap = (obj - bb.objective) / abs(bb.objective)
        rows.append({
            "pair": [a, b], "iterations": its, "space": space,
            "population": out.population, "steps": out.steps,
            "evaluated": out.evaluated,
            "device_count": 1,
            "search_s": round(t_search, 4),
            "first_call_s": round(t_first, 4),
            "compile_s": round(t_compile, 4),
            "cands_per_s": round(out.evaluated / t_search, 1),
            "objective_ms": round(obj, 6),
            "bb_objective_ms": round(bb.objective, 6),
            "gap_rel": round(gap, 6),
        })
        print(f"  {a}+{b}: space={space} evaluated={out.evaluated} "
              f"{rows[-1]['cands_per_s']:.0f} cand/s "
              f"gap={gap:+.3%}")
    return rows


def run_scenarios(seed: int) -> list[dict]:
    """End-to-end solver comparison on the golden Table-6 shapes."""
    rows = []
    for no in SCENARIO_EXPS:
        plat_name, objective, spec, scenario, _pl, _pf = EXPERIMENTS[no]
        sched = Scheduler(plat_name)
        graphs, deps, its = build_scenario(sched.platform, spec, scenario)
        bb = sched.solve(graphs, objective, solver="bb", max_transitions=2,
                         iterations=its, depends_on=deps, evaluator="batch")
        t0 = time.perf_counter()
        sol = solver_anneal.solve(
            sched.platform, graphs, sched.model, objective=objective,
            max_transitions=2, iterations=its, depends_on=deps,
            seed=seed, population=1024, steps=192, evaluator="batch")
        t_anneal = time.perf_counter() - t0
        gap = (sol.objective - bb.objective) / abs(bb.objective)
        rows.append({
            "experiment": no, "platform": plat_name,
            "objective": objective, "scenario": scenario,
            "dnns": "+".join(str(s) for s in spec),
            "anneal_objective": round(sol.objective, 6),
            "bb_objective": round(bb.objective, 6),
            "gap_rel": round(gap, 6),
            "anneal_s": round(t_anneal, 4),
        })
        print(f"  exp{no} ({plat_name}, scenario {scenario}): "
              f"anneal={sol.objective:.4f} bb={bb.objective:.4f} "
              f"gap={gap:+.3%}")
    return rows


def _digest(out) -> str:
    """Content digest of a search incumbent (assignment + objective +
    winning chain): equal digests mean bit-identical outcomes."""
    blob = json.dumps([out.assignment, repr(out.objective), out.chain],
                      sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def sweep_worker(devices: int, per_device_population: int, seed: int,
                 n_pairs: int, steps: int, repeats: int) -> dict:
    """One device-sweep point, run inside a subprocess whose XLA_FLAGS
    emulate ``devices`` host devices.  Prints a single JSON dict."""
    avail = xla_env.device_count()
    if avail < devices:
        return {"devices": devices, "error":
                f"only {avail} device(s) visible (XLA_FLAGS not applied?)"}
    sched = Scheduler("agx-orin")
    plat, model = sched.platform, sched.model
    pairs = list(itertools.combinations(DNN_SET, 2))[:n_pairs]
    population = per_device_population * devices
    evaluated = 0
    wall = 0.0
    worst_gap = -np.inf
    for a, b in pairs:
        graphs = sched.graphs([a, b])
        its = balanced_iterations(plat, graphs)
        tables = search_jax.build_tables(plat, graphs, model, 2,
                                         iterations=its)
        kw = dict(objective="latency", seed=seed, population=population,
                  steps=steps, devices=devices)
        search_jax.anneal_search(tables, **kw)       # compile warm-up
        t, out = _best_of(
            lambda: search_jax.anneal_search(tables, **kw), repeats)
        evaluated += out.evaluated
        wall += t
        wls = [Workload(g, asg, iterations=it)
               for g, asg, it in zip(graphs, out.assignment, its)]
        obj = simulate(plat, wls, model,
                       record_timeline=False).objective("latency")
        bb = sched.solve(graphs, "latency", solver="bb", max_transitions=2,
                         iterations=its, evaluator="batch")
        worst_gap = max(worst_gap,
                        (obj - bb.objective) / abs(bb.objective))

    # determinism digest at a FIXED total population: must be identical
    # across device counts, select backends, and fan-outs.
    a, b = pairs[0]
    graphs = sched.graphs([a, b])
    its = balanced_iterations(plat, graphs)
    tables = search_jax.build_tables(plat, graphs, model, 2, iterations=its)
    dkw = dict(objective="latency", seed=seed,
               population=DIGEST_POPULATION, steps=DIGEST_STEPS,
               devices=devices)
    digest = _digest(search_jax.anneal_search(tables, **dkw))
    backend_ok = all(
        _digest(search_jax.anneal_search(tables, backend=bk, **dkw))
        == digest for bk in ("xla", "pallas_interpret"))
    fanout_ok = (devices == 1 or _digest(search_jax.anneal_search(
        tables, fanout="pmap", **dkw)) == digest)
    chunk_ok = True
    if devices == 1:
        # chunking exists only on the legacy (devices=None) path; its
        # incumbent must also match the mesh digest via migrate="island".
        leg = dict(dkw)
        leg.pop("devices")
        chunk_ok = (
            _digest(search_jax.anneal_search(tables, chunk=256, **leg))
            == _digest(search_jax.anneal_search(tables, chunk=1024, **leg)))
    return {
        "devices": devices,
        "per_device_population": per_device_population,
        "population": population,
        "steps": steps,
        "pairs": len(pairs),
        "evaluated": evaluated,
        "search_s": round(wall, 4),
        "cands_per_s": round(evaluated / wall, 1),
        "worst_gap_rel": round(float(worst_gap), 6),
        "digest": digest,
        "digest_backend_ok": bool(backend_ok),
        "digest_fanout_ok": bool(fanout_ok),
        "digest_chunk_ok": bool(chunk_ok),
    }


def run_device_sweep(device_counts, per_device_population: int, seed: int,
                     n_pairs: int, steps: int, repeats: int) -> list[dict]:
    """Fan the sweep points out over subprocesses (one per device count —
    the emulated-device flag is fixed at backend init, so each count
    needs its own process)."""
    points = []
    for d in sorted(device_counts):
        cmd = [sys.executable, "-m", "benchmarks.bench_search",
               "--sweep-worker", str(d),
               "--sweep-per-dev", str(per_device_population),
               "--sweep-pairs", str(n_pairs),
               "--sweep-steps", str(steps),
               "--seed", str(seed), "--repeats", str(repeats)]
        env = xla_env.subprocess_env(d)
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run(cmd, cwd=ROOT, env=env, text=True,
                              capture_output=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"device-sweep worker (devices={d}) failed:\n{proc.stderr}")
        point = json.loads(proc.stdout.strip().splitlines()[-1])
        if "error" in point:
            raise RuntimeError(f"device-sweep worker (devices={d}): "
                               f"{point['error']}")
        points.append(point)
        print(f"  devices={d}: {point['cands_per_s']:.0f} cand/s "
              f"(pop {point['population']}) digest={point['digest']} "
              f"gap={point['worst_gap_rel']:+.3%}")
    base = points[0]["cands_per_s"]
    for p in points:
        p["speedup_vs_1dev"] = round(p["cands_per_s"] / base, 3)
        p["digest_invariant"] = p["digest"] == points[0]["digest"]
    return points


def run(pairs_limit: int | None, population: int, seed: int,
        out_path: pathlib.Path, repeats: int = 2,
        device_sweep=None, sweep_per_dev: int = 1024,
        sweep_pairs: int = 2, sweep_steps: int = 64) -> dict:
    sched = Scheduler("agx-orin")
    pairs = list(itertools.combinations(DNN_SET, 2))
    if pairs_limit:
        pairs = pairs[:pairs_limit]
    print(f"Table-8 search sweep: {len(pairs)} pairs on agx-orin "
          f"(population={population}, budget ∝ space)")
    rows = run_pairs(sched, pairs, population, seed, repeats)
    print("Table-6 scenario quality (anneal vs bb):")
    scenarios = run_scenarios(seed)
    scaling = []
    if device_sweep:
        print(f"Device sweep (emulated host devices, "
              f"{sweep_per_dev} chains/device):")
        scaling = run_device_sweep(device_sweep, sweep_per_dev, seed,
                                   sweep_pairs, sweep_steps, repeats)

    total_eval = sum(r["evaluated"] for r in rows)
    total_wall = sum(r["search_s"] for r in rows)
    agg_cps = total_eval / total_wall
    worst_gap = max(r["gap_rel"] for r in rows + scenarios)

    jax_eval_cps = None
    sim_path = ROOT / "BENCH_simulate.json"
    if sim_path.exists():
        jax_eval_cps = json.loads(sim_path.read_text()).get(
            "jax_cands_per_s")

    result = {
        "benchmark": "device_resident_search",
        "platform": "agx-orin",
        "solver": "anneal",
        "max_transitions": 2,
        "pairs": len(rows),
        "population": population,
        "seed": seed,
        "repeats": max(1, repeats),
        "device_count": xla_env.device_count(),
        "host_cores": os.cpu_count(),
        "timing": "min over `repeats` steady-state runs per pair; "
                  "compile_s is an AOT lower+compile of a fresh "
                  "executable (min of repeats) — paid once per "
                  "(w, gmax, amax) shape bucket in real runs",
        "total_evaluated": total_eval,
        "search_cands_per_s": round(agg_cps, 1),
        #: plain-evaluator throughput from BENCH_simulate.json; the ratio
        #: is like-for-like on this host (both loops are op-dispatch
        #: bound on a single CPU core — accelerator deployments scale
        #: this with device parallelism).
        "jax_eval_cands_per_s": jax_eval_cps,
        "speedup_vs_jax_eval": (round(agg_cps / jax_eval_cps, 2)
                                if jax_eval_cps else None),
        "worst_gap_rel": round(worst_gap, 6),
        #: multi-device mesh scaling (one subprocess per emulated device
        #: count); empty unless --device-sweep is given.
        "scaling": scaling,
        "scenarios": scenarios,
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    print(fmt_table(
        ["pairs", "evaluated", "cand/s", "vs jax eval", "worst gap"],
        [[len(rows), total_eval, f"{agg_cps:.0f}",
          (f"{result['speedup_vs_jax_eval']}x"
           if result["speedup_vs_jax_eval"] else "-"),
          f"{worst_gap:+.3%}"]]))
    print(f"wrote {out_path}")
    emit("bench_search.candidate_throughput", total_wall * 1e6,
         f"search_cps={agg_cps:.0f};evaluated={total_eval};"
         f"worst_gap={worst_gap:.4f}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairs", type=int, default=None,
                    help="limit the sweep to the first N pairs "
                         "(default: all 45)")
    ap.add_argument("--population", type=int, default=1024,
                    help="annealing chains per pair (default 1024)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=2,
                    help="steady-state runs per pair; min recorded")
    ap.add_argument("--device-sweep", type=str, default=None,
                    help="comma-separated emulated device counts, e.g. "
                         "1,2,4,8 — each runs in a subprocess with "
                         "--xla_force_host_platform_device_count set")
    ap.add_argument("--sweep-per-dev", type=int, default=1024,
                    help="annealing chains per device in the sweep")
    ap.add_argument("--sweep-pairs", type=int, default=2,
                    help="Table-8 pairs timed per sweep point")
    ap.add_argument("--sweep-steps", type=int, default=64,
                    help="annealing steps per sweep-point search")
    ap.add_argument("--sweep-worker", type=int, default=None,
                    help=argparse.SUPPRESS)  # internal: one sweep point
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.sweep_worker is not None:
        point = sweep_worker(args.sweep_worker, args.sweep_per_dev,
                             args.seed, args.sweep_pairs, args.sweep_steps,
                             args.repeats)
        print(json.dumps(point))
        return point
    sweep = ([int(s) for s in args.device_sweep.split(",")]
             if args.device_sweep else None)
    if sweep and sorted(sweep)[0] != 1:
        ap.error("--device-sweep must include 1 (the speedup baseline)")
    return run(args.pairs, args.population, args.seed, args.out,
               repeats=args.repeats, device_sweep=sweep,
               sweep_per_dev=args.sweep_per_dev,
               sweep_pairs=args.sweep_pairs, sweep_steps=args.sweep_steps)


if __name__ == "__main__":
    main()

"""Observability overhead benchmark: tracing must be (nearly) free.

Replays one seeded bursty arrival trace through the virtual-time fleet
gateway (:mod:`repro.serve.fleet`) twice per repeat:

* ``disabled`` — the default :class:`~repro.obs.NullTracer` installed
  (every instrumentation point costs one attribute lookup);
* ``traced`` — a live :class:`~repro.obs.Tracer`, followed by the bulk
  per-request span export (``FleetGateway.export_trace``).

Gates (asserted, recorded in ``BENCH_obs.json``):

* replay overhead of enabled tracing < 3% wall-clock (min-of-repeats;
  asserted at >= ``GATE_MIN_REQUESTS`` requests — below that the replay
  is too short for the ratio to be meaningful, the number is recorded
  only);
* the disabled span path costs well under a microsecond per call;
* two identical virtual-clock replays export **byte-identical**
  Perfetto JSON (pinned on a load level with zero reschedules, so no
  wall-clock solver timings leak into span args);
* the exported trace is structurally valid Chrome-trace JSON.

    PYTHONPATH=src python -m benchmarks.bench_obs                 # 1M
    PYTHONPATH=src python -m benchmarks.bench_obs --requests 1000
"""
from __future__ import annotations

import argparse
import json
import pathlib
import tempfile
import time

from repro import configs
from repro.core.accelerators import tpu_pod_split
from repro.core.plan import ShardedPlanCache
from repro.obs import NULL_TRACER, Tracer, get_tracer, set_tracer
from repro.serve.fleet import (FleetConfig, FleetGateway, SLO, build_pool,
                               bursty_trace)
from repro.serve.gateway import GatewayConfig, TenantSpec

from .common import emit, fmt_table, timed

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_obs.json"

SPLITS = ((4, 12), (8, 8), (12, 4))
TENANTS = (("stablelm", "stablelm-1.6b"), ("llama", "llama3.2-3b"))
N_FLEET_TENANTS = 500
SEED = 7
BASE_RPS, BURST_RPS = 150.0, 1200.0
SLO_P99_MS = 400.0
#: overhead is a ratio of wall times — below this many requests the
#: replay finishes in milliseconds and the ratio is dominated by noise.
GATE_MIN_REQUESTS = 100_000
OVERHEAD_GATE_PCT = 3.0
#: the disabled tracer must cost no more than this per span call.
DISABLED_GATE_NS = 1_000.0
#: determinism replay: gentle load so the fleet never re-solves (a
#: fresh solve stamps wall-clock ``solve_s`` into span args, which
#: byte-identity cannot survive).
DETERMINISM_REQUESTS = 5_000
DETERMINISM_BURST_RPS = 300.0


def _build_pool(cache_root: pathlib.Path):
    specs = [TenantSpec(n, configs.get(a), max_slots=4, capacity=256,
                        prompt_len=64, max_new=16)
             for n, a in TENANTS]
    plats = [tpu_pod_split(a, b, name=f"v5e-{a}x{b}-split")
             for a, b in SPLITS]
    return build_pool(specs, plats, GatewayConfig(),
                      ShardedPlanCache(cache_root), slots=8)


def _replay(pool, trace, tracer,
            slo_p99_ms: float = SLO_P99_MS) -> tuple[dict, "FleetGateway"]:
    prev = set_tracer(tracer)
    try:
        cfg = FleetConfig(policy="slo", default_slo=SLO(p99_ms=slo_p99_ms))
        gw = FleetGateway(pool, n_tenants=trace.n_tenants, cfg=cfg,
                          capacity_hint=len(trace))
        with timed() as t:
            rep = gw.replay(trace)
        return {"t": t, "rep": rep}, gw
    finally:
        set_tracer(prev)


def bench_disabled_span() -> float:
    """ns per ``get_tracer().span(...)`` call with the null tracer."""
    assert get_tracer() is NULL_TRACER
    n = 200_000
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(n):
            with get_tracer().span("noop", "bench", i=1):
                pass
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e9


def validate_chrome(doc: dict) -> list[str]:
    """Structural problems with one Chrome-trace document ([] = valid)."""
    problems = []
    for key in ("traceEvents", "displayTimeUnit", "otherData"):
        if key not in doc:
            problems.append(f"missing top-level key {key!r}")
    for i, ev in enumerate(doc.get("traceEvents", [])):
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "C"):
            problems.append(f"traceEvents[{i}]: unknown ph {ph!r}")
            continue
        if "pid" not in ev or "name" not in ev:
            problems.append(f"traceEvents[{i}]: missing pid/name")
        if ph == "X" and not {"ts", "dur", "tid", "cat"} <= set(ev):
            problems.append(f"traceEvents[{i}]: X missing ts/dur/tid/cat")
        if ph == "i" and ev.get("s") != "t":
            problems.append(f"traceEvents[{i}]: instant missing s='t'")
    return problems


def run(n_requests: int, repeats: int, out_path: pathlib.Path) -> dict:
    trace = bursty_trace(BASE_RPS, BURST_RPS, n_requests,
                         n_tenants=N_FLEET_TENANTS, seed=SEED)
    with tempfile.TemporaryDirectory() as tmp:
        pool = _build_pool(pathlib.Path(tmp) / "plancache")

        disabled_ns = bench_disabled_span()
        assert disabled_ns < DISABLED_GATE_NS, \
            f"disabled span path costs {disabled_ns:.0f} ns/call"
        emit("bench_obs.disabled_span", disabled_ns / 1e3,
             f"ns_per_call={disabled_ns:.0f}")

        # warm-up: the first replay over a fresh pool re-solves on
        # monitor fires and mutates the shared pool plans — without it
        # the disabled arm would be measured against fresher state than
        # the traced arm ever sees.
        _replay(pool, trace, NULL_TRACER)

        base_s = traced_s = export_s = float("inf")
        events = spans = 0
        trace_bytes = 0
        for _ in range(repeats):
            out, _gw = _replay(pool, trace, NULL_TRACER)
            base_s = min(base_s, out["t"]["s"])

            tracer = Tracer()
            out, gw = _replay(pool, trace, tracer)
            traced_s = min(traced_s, out["t"]["s"])
            with timed() as t_exp:
                spans = gw.export_trace(tracer=tracer)
            export_s = min(export_s, t_exp["s"])
            events = len(tracer.events())
            trace_bytes = len(tracer.to_json()) + 1

        overhead_pct = (traced_s / base_s - 1.0) * 100.0
        gated = n_requests >= GATE_MIN_REQUESTS
        if gated:
            assert overhead_pct < OVERHEAD_GATE_PCT, \
                (f"enabled tracing adds {overhead_pct:.2f}% to the "
                 f"{n_requests}-request replay (gate {OVERHEAD_GATE_PCT}%)")

        doc = tracer.to_chrome()
        problems = validate_chrome(doc)
        assert not problems, f"invalid trace: {problems[:5]}"

        # byte-identity: two fresh gateways over the same pool, virtual
        # clock pinned, SLO relaxed so the fleet never re-solves (a
        # reschedule's fresh solve stamps wall-clock solve_s span args).
        dtrace = bursty_trace(BASE_RPS, DETERMINISM_BURST_RPS,
                              DETERMINISM_REQUESTS,
                              n_tenants=N_FLEET_TENANTS, seed=SEED)
        blobs = []
        for _ in range(2):
            tr = Tracer(clock=lambda: 0.0)
            out, gw = _replay(pool, dtrace, tr, slo_p99_ms=1e9)
            assert not out["rep"].reschedules, \
                "determinism replay re-solved despite the relaxed SLO"
            gw.export_trace(tracer=tr)
            blobs.append(tr.to_json())
        determinism_ok = blobs[0] == blobs[1]
        assert determinism_ok, "virtual-clock replays diverged byte-wise"

    rows = [
        {"mode": "disabled", "replay_s": round(base_s, 4),
         "replay_req_per_s": round(n_requests / base_s, 1),
         "events": 0, "exported_spans": 0},
        {"mode": "traced", "replay_s": round(traced_s, 4),
         "replay_req_per_s": round(n_requests / traced_s, 1),
         "events": events, "exported_spans": spans},
    ]
    emit("bench_obs.replay_disabled", base_s * 1e6,
         f"req_per_s={n_requests / base_s:.0f}")
    emit("bench_obs.replay_traced", traced_s * 1e6,
         f"overhead={overhead_pct:.2f}%;spans={spans}")

    result = {
        "benchmark": "obs_overhead",
        "requests": n_requests,
        "repeats": repeats,
        "seed": SEED,
        "trace_hash": trace.trace_hash()[:16],
        "disabled_ns_per_span": round(disabled_ns, 1),
        "replay_disabled_s": round(base_s, 4),
        "replay_traced_s": round(traced_s, 4),
        "overhead_pct": round(overhead_pct, 3),
        "overhead_gate_pct": OVERHEAD_GATE_PCT,
        "overhead_gated": gated,
        "export_s": round(export_s, 4),
        "exported_spans": spans,
        "trace_events": events,
        "trace_bytes": trace_bytes,
        "determinism_requests": DETERMINISM_REQUESTS,
        "determinism_ok": determinism_ok,
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    print()
    print(fmt_table(
        ["mode", "replay", "req/s", "events", "spans"],
        [[r["mode"], f"{r['replay_s']:.3f}s",
          f"{r['replay_req_per_s']:.0f}", r["events"],
          r["exported_spans"]] for r in rows]))
    print(f"tracing overhead {overhead_pct:+.2f}% "
          f"({'gated' if gated else 'recorded only'}); disabled span "
          f"{disabled_ns:.0f} ns/call; export {export_s:.3f}s for "
          f"{spans} spans; determinism_ok={determinism_ok}")
    print(f"wrote {out_path}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=1_000_000,
                    help="trace length (default: one million requests)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(args.requests, args.repeats, args.out)


if __name__ == "__main__":
    main()

"""Gateway throughput benchmark: contention-aware multi-tenant plans vs.
naive round-robin placement.

For several tenant mixes (2-3 heterogeneous LLMs, full-size configs) and pod
splits, plans the multi-tenant schedule through
:func:`repro.serve.gateway.plan_gateway` and reports simulated serving
throughput against the round-robin baseline, plus planning wall time (the
schedule-generation overhead a serving control plane would pay at tenant
churn).
"""
from __future__ import annotations

from repro import configs
from repro.core import Scheduler
from repro.core.accelerators import tpu_pod_split
from repro.serve.gateway import GatewayConfig, TenantSpec, plan_gateway

from .common import emit, fmt_table, timed


def _spec(name: str, arch: str, **kw) -> TenantSpec:
    return TenantSpec(name, configs.get(arch).reduced(),
                      plan_cfg=configs.get(arch),
                      max_slots=kw.pop("max_slots", 8),
                      capacity=kw.pop("capacity", 256),
                      prompt_len=kw.pop("prompt_len", 128),
                      max_new=kw.pop("max_new", 64))


MIXES = {
    "2lm-sym": ((8, 8), [("stablelm", "stablelm-1.6b"),
                         ("llama", "llama3.2-3b")]),
    "2lm-asym": ((4, 12), [("stablelm", "stablelm-1.6b"),
                           ("llama", "llama3.2-3b")]),
    "2lm-ssm": ((4, 12), [("rwkv", "rwkv6-7b"),
                          ("llama", "llama3.2-3b")]),
    "3lm-asym": ((4, 12), [("stablelm", "stablelm-1.6b"),
                           ("llama", "llama3.2-3b"),
                           ("rwkv", "rwkv6-7b")]),
}


def main() -> list[dict]:
    rows = []
    for mix, (chips, tenants) in MIXES.items():
        plat = tpu_pod_split(*chips, name=f"v5e-{chips[0]}+{chips[1]}")
        specs = [_spec(n, a) for n, a in tenants]
        gcfg = GatewayConfig(platform=plat)
        sched = Scheduler(plat)
        with timed() as t:
            plan = plan_gateway(specs, gcfg, scheduler=sched)
        # tenant churn that converges back to a known mix is a plan-cache
        # hit — the re-plan cost a control plane actually pays.
        with timed() as t_hit:
            plan_gateway(specs, gcfg, scheduler=sched)
        assert sched.cache.hits >= 1 and sched.solves == 1
        fps = plan.solution.result.throughput_fps
        rr = plan.round_robin.throughput_fps
        gain = 100 * (plan.speedup_vs_round_robin - 1)
        emit(f"gateway_{mix}", t["us"], f"fps={fps:.1f},rr={rr:.1f},"
             f"gain={gain:+.1f}%,replan_hit_us={t_hit['us']:.0f}")
        rows.append({
            "mix": mix, "chips": chips,
            "tenants": [n for n, _ in tenants],
            "haxconn_fps": fps, "round_robin_fps": rr,
            "gain_pct": gain, "plan_s": t["s"],
            "replan_cached_s": t_hit["s"],
            "solver": plan.plan.solver,
            "plan_hash": plan.plan.request_hash[:12],
            "optimal": plan.solution.optimal,
        })
    print()
    print(fmt_table(
        ["mix", "split", "haxconn fps", "round-robin fps", "gain",
         "plan time", "cached re-plan", "solver"],
        [[r["mix"], f"{r['chips'][0]}+{r['chips'][1]}",
          f"{r['haxconn_fps']:.1f}", f"{r['round_robin_fps']:.1f}",
          f"{r['gain_pct']:+.1f}%", f"{r['plan_s']:.2f}s",
          f"{r['replan_cached_s']:.3f}s", r["solver"]]
         for r in rows]))
    return rows


if __name__ == "__main__":
    main()

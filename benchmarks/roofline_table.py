"""Roofline report: reads dry-run artifacts and prints the per-cell terms.

Consumes the JSON records written by ``repro.launch.dryrun`` (one per
architecture × input shape × mesh) and reports the three roofline terms,
the dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPs usefulness ratio.
Skips gracefully (with a note) when the dry-run has not been executed yet.
"""
from __future__ import annotations

import json
import pathlib

from repro.obs import get_logger

from .common import emit, fmt_table

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts"
DRYRUN_DIR = ARTIFACTS / "dryrun"

log = get_logger(__name__)


def main() -> list[dict]:
    if not DRYRUN_DIR.exists():
        log.warning("roofline: no dry-run artifacts yet (run: "
                    "PYTHONPATH=src python -m repro.launch.dryrun)")
        emit("roofline.missing", 0.0, "run_dryrun_first")
        return []
    rows, out = [], []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if "roofline" not in rec:
            continue
        r = rec["roofline"]
        rows.append(rec)
        out.append([
            rec["arch"], rec["shape"], rec["mesh"],
            f"{r['t_compute_ms']:.2f}", f"{r['t_memory_ms']:.2f}",
            f"{r['t_collective_ms']:.2f}", r["bottleneck"],
            f"{r['model_flops_ratio']:.2f}",
            f"{r['roofline_fraction']:.2f}",
        ])
        emit(f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
             r["t_dominant_ms"] * 1e3,
             f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f}")
    if out:
        print("\n== Roofline terms per (arch x shape x mesh) ==")
        print(fmt_table(["arch", "shape", "mesh", "compute ms", "memory ms",
                         "collective ms", "bound", "useful", "frac"], out))
    return rows


if __name__ == "__main__":
    main()

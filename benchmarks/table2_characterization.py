"""Table 2: per-layer-group characterization of GoogleNet (Xavier AGX).

Reports the calibrated layer-group profile (GPU/DLA times, D/G ratio, G→D
transition cost, requested memory throughput) and checks the published
invariants: ratio spread 1.40–2.02x, post-pooling boundaries transition
cheaply, high-input groups demand more bandwidth.
"""
from __future__ import annotations

from repro.core import Scheduler
from repro.core.profiles import TABLE2_GOOGLENET

from .common import emit, fmt_table, timed


def main() -> list[dict]:
    sched = Scheduler("xavier-agx")
    plat = sched.platform
    with timed() as t:
        g = sched.graphs(["googlenet"])[0]
    rows = []
    out = []
    for grp, pub in zip(g, TABLE2_GOOGLENET):
        ratio = grp.time_on("DLA") / grp.time_on("GPU")
        tau = plat.transition_cost_ms(grp.out_bytes, "GPU", "DLA")
        rows.append(dict(group=grp.name, gpu_ms=grp.time_on("GPU"),
                         dla_ms=grp.time_on("DLA"), ratio=ratio,
                         trans_ms=tau, mem_thr=grp.demand_on("GPU"),
                         pub_trans_ms=pub[3], pub_mem_thr=pub[4]))
        out.append([grp.name, f"{grp.time_on('GPU'):.3f}",
                    f"{grp.time_on('DLA'):.3f}", f"{ratio:.2f}",
                    f"{tau:.3f}", f"{grp.demand_on('GPU')*100:.1f}%"])
    print("\n== Table 2: GoogleNet layer-group characterization (Xavier) ==")
    print(fmt_table(
        ["group", "GPU(ms)", "DLA(ms)", "D/G", "tau G2D(ms)", "MemThr"], out))
    ratios = [r["ratio"] for r in rows]
    spread = max(ratios) / min(ratios)
    emit("table2.characterize_googlenet", t["us"],
         f"ratio_spread={spread:.3f};paper=1.443")
    return rows


if __name__ == "__main__":
    main()

"""Benchmark driver: one module per paper table/figure + roofline report.

Prints ``name,us_per_call,derived`` CSV rows per benchmark (interleaved with
human-readable tables) and persists all row dicts to
``artifacts/bench_results.json`` for EXPERIMENTS.md generation.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table6 fig7  # subset
"""
from __future__ import annotations

import json
import pathlib
import sys
import time
import traceback

from repro.obs import configure_logging, get_logger

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts"

log = get_logger(__name__)

SUITES = [
    "table2_characterization",
    "table5_standalone",
    "table6_scenarios",
    "table7_overhead",
    "table8_exhaustive",
    "fig5_scenario1",
    "fig6_contention",
    "fig7_dynamic",
    "roofline_table",
    "serve_gateway",
]


def main(argv: list[str] | None = None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    configure_logging("info")
    selected = [s for s in SUITES if not args or any(a in s for a in args)]
    ARTIFACTS.mkdir(exist_ok=True)
    results: dict[str, object] = {}
    failures: list[str] = []
    print("name,us_per_call,derived")
    for name in selected:
        mod_name = f"benchmarks.{name}"
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            results[name] = mod.main()
        except Exception:
            failures.append(name)
            log.error("[FAIL] %s:\n%s", mod_name, traceback.format_exc())
        print(f"# {name} finished in {time.perf_counter() - t0:.1f}s\n")
    out = ARTIFACTS / "bench_results.json"
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"# results -> {out}")
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fleet gateway benchmark: SLO-aware routing vs round-robin at scale.

Replays one seeded million-request bursty arrival trace (hundreds of
tenants, open loop) through the virtual-time fleet gateway
(:mod:`repro.serve.fleet`) over a pool of heterogeneous solved SoC plans,
once per routing policy:

* ``round_robin`` — static tenant-hash placement over the pool (the
  baseline a contention-unaware fleet would run);
* ``slo`` — earliest-predicted-finish routing + SLO admission
  (:class:`~repro.serve.fleet.slo.AdmissionController`).

Reported per policy: sustained completions/s, p50/p99 end-to-end latency,
shed fraction and SLO violations.  The artifact additionally records the
sharded-PlanCache cold-start check: a second ``build_pool`` over the same
platforms from the same on-disk cache must perform **zero** solver
invocations.

    PYTHONPATH=src python -m benchmarks.bench_gateway             # 1M
    PYTHONPATH=src python -m benchmarks.bench_gateway --requests 1000

The trace is seeded and the replay is virtual-time, so every number except
the wall-clock throughput of the replay loop itself is bit-deterministic.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import tempfile

from repro import configs
from repro.core.accelerators import tpu_pod_split
from repro.core.plan import ShardedPlanCache
from repro.serve.fleet import (FleetConfig, FleetGateway, SLO, build_pool,
                               bursty_trace)
from repro.serve.gateway import GatewayConfig, TenantSpec

from .common import emit, fmt_table, timed

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_gateway.json"

#: pool pod splits — heterogeneous placements of the same tenant mix, so
#: per-class service times differ across plans and routing has a choice
#: that matters.
SPLITS = ((4, 12), (8, 8), (12, 4))
TENANTS = (("stablelm", "stablelm-1.6b"), ("llama", "llama3.2-3b"))
SLOTS = 8
N_FLEET_TENANTS = 500
SEED = 7
#: offered load ~ pool capacity: enough pressure that routing quality
#: shows in the tail without the run being pure shedding.
BASE_RPS, BURST_RPS = 150.0, 1200.0
SLO_P99_MS = 400.0


def _specs() -> list[TenantSpec]:
    # full-size configs: the fleet loop bills service from the solved
    # schedule and never instantiates the models.
    return [TenantSpec(n, configs.get(a), max_slots=4, capacity=256,
                       prompt_len=64, max_new=16)
            for n, a in TENANTS]


def _build_pool(cache_root: pathlib.Path):
    cache = ShardedPlanCache(cache_root)
    plats = [tpu_pod_split(a, b, name=f"v5e-{a}x{b}-split")
             for a, b in SPLITS]
    pool = build_pool(_specs(), plats, GatewayConfig(), cache, slots=SLOTS)
    return pool, sum(pp.scheduler.solves for pp in pool)


def run(n_requests: int, out_path: pathlib.Path) -> dict:
    trace = bursty_trace(BASE_RPS, BURST_RPS, n_requests,
                         n_tenants=N_FLEET_TENANTS, seed=SEED)
    with tempfile.TemporaryDirectory() as tmp:
        cache_root = pathlib.Path(tmp) / "plancache"
        with timed() as t_plan:
            pool, cold_solves = _build_pool(cache_root)
        # cold-start check: rebuilding the pool from the sharded disk
        # cache (fresh Schedulers, fresh in-memory caches) is pure loads.
        with timed() as t_boot:
            pool2, warm_solves = _build_pool(cache_root)
        del pool2
    assert warm_solves == 0, \
        f"sharded-cache boot performed {warm_solves} fresh solve(s)"

    rows = []
    for policy in ("round_robin", "slo"):
        cfg = FleetConfig(policy=policy,
                          default_slo=SLO(p99_ms=SLO_P99_MS))
        gw = FleetGateway(pool, n_tenants=N_FLEET_TENANTS, cfg=cfg,
                          capacity_hint=len(trace))
        with timed() as t:
            rep = gw.replay(trace)
        slo = rep.slo_report()
        rows.append({
            "policy": policy,
            "requests": rep.n_requests,
            "completed": rep.completed,
            "shed": rep.shed,
            "p50_ms": round(rep.p50_ms, 3),
            "p99_ms": round(rep.p99_ms, 3),
            "sustained_rps": round(rep.sustained_rps, 1),
            "slo_p99_violations": slo["p99_violations"],
            "served_tenants": slo["served_tenants"],
            "replay_s": round(t["s"], 3),
            "replay_req_per_s": round(rep.n_requests / t["s"], 1),
        })
        emit(f"bench_gateway.{policy}", t["us"],
             f"p99={rep.p99_ms:.1f}ms;completed={rep.completed};"
             f"shed={rep.shed};sustained={rep.sustained_rps:.1f}rps")

    rr = next(r for r in rows if r["policy"] == "round_robin")
    slo_row = next(r for r in rows if r["policy"] == "slo")
    assert slo_row["p99_ms"] < rr["p99_ms"], \
        (f"SLO routing must beat round-robin on p99: "
         f"{slo_row['p99_ms']} vs {rr['p99_ms']}")

    result = {
        "benchmark": "fleet_gateway",
        "splits": [list(s) for s in SPLITS],
        "tenant_mix": [a for _, a in TENANTS],
        "fleet_tenants": N_FLEET_TENANTS,
        "requests": n_requests,
        "seed": SEED,
        "trace_kind": "bursty",
        "trace_hash": trace.trace_hash()[:16],
        "base_rps": BASE_RPS,
        "burst_rps": BURST_RPS,
        "slo_p99_ms": SLO_P99_MS,
        "plan_cold_solves": cold_solves,
        "plan_cold_s": round(t_plan["s"], 3),
        "cache_boot_solves": warm_solves,
        "cache_boot_s": round(t_boot["s"], 3),
        "p99_speedup": round(rr["p99_ms"] / slo_row["p99_ms"], 2),
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    print()
    print(fmt_table(
        ["policy", "completed", "shed", "p50", "p99", "sustained",
         "replay"],
        [[r["policy"], r["completed"], r["shed"],
          f"{r['p50_ms']:.1f}ms", f"{r['p99_ms']:.1f}ms",
          f"{r['sustained_rps']:.0f} req/s", f"{r['replay_s']:.2f}s"]
         for r in rows]))
    print(f"slo vs round-robin p99: {result['p99_speedup']}x better; "
          f"cache boot {result['cache_boot_s']}s, "
          f"{result['cache_boot_solves']} solves")
    print(f"wrote {out_path}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=1_000_000,
                    help="trace length (default: one million requests)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(args.requests, args.out)


if __name__ == "__main__":
    main()

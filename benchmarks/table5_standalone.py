"""Table 5: standalone DNN runtimes on AGX Orin and Xavier AGX.

Our calibrated profiles reproduce the published totals exactly (they are the
calibration anchor); the benchmark verifies the round trip through layer
grouping + the simulator, i.e. that a standalone simulated inference of every
DNN equals the published number (no self-contention, no transitions).
"""
from __future__ import annotations

from repro.core import Scheduler
from repro.core.profiles import TABLE5
from repro.core.simulate import Workload, simulate

from .common import emit, fmt_table, timed


def main() -> list[dict]:
    rows, out = [], []
    worst = 0.0
    scheds = {name: Scheduler(name) for name in ("agx-orin", "xavier-agx")}
    with timed() as t:
        for dnn in sorted(TABLE5):
            row = {"dnn": dnn}
            for plat_name, cols in (("agx-orin", (0, 1)),
                                    ("xavier-agx", (2, 3))):
                sched = scheds[plat_name]
                plat, model = sched.platform, sched.model
                g = sched.graphs([dnn])[0]
                for acc, col in zip(("GPU", "DLA"), cols):
                    pub = TABLE5[dnn][col]
                    if acc not in g.accelerators:
                        row[f"{plat_name}.{acc}"] = None
                        continue
                    res = simulate(plat, [Workload(g, (acc,) * len(g))],
                                   model)
                    row[f"{plat_name}.{acc}"] = res.latency_ms
                    if pub is not None:
                        worst = max(worst, abs(res.latency_ms - pub) / pub)
            rows.append(row)
            out.append([dnn] + [
                "-" if row.get(k) is None else f"{row[k]:.2f}"
                for k in ("agx-orin.GPU", "agx-orin.DLA",
                          "xavier-agx.GPU", "xavier-agx.DLA")])
    print("\n== Table 5: standalone runtimes (ms), simulated ==")
    print(fmt_table(["DNN", "Orin GPU", "Orin DLA", "Xavier GPU",
                     "Xavier DLA"], out))
    emit("table5.standalone_roundtrip", t["us"],
         f"max_rel_err_vs_paper={worst:.2e}")
    return rows


if __name__ == "__main__":
    main()

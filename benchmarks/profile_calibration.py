"""Calibration-quality benchmark: fit residuals on the virtual SoC.

Runs the measured characterize → calibrate pipeline against both
generating contention-model classes on two SoC platforms and records, per
scenario: fit residuals (rmse / max relative error vs the *training*
samples), agreement with the *generating* model across the sampled
(own, external) grid, pipeline wall time, and the end-to-end objective
deviation of a Table-6-style solve from the measured bundle vs the plan
under the generating model.

Writes ``BENCH_profile.json`` (repo root); CI's scheduled lane uploads it
and the schema guard (:mod:`benchmarks.schema_guard`) pins its columns.

    PYTHONPATH=src python -m benchmarks.profile_calibration [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro import profiling
from repro.core import Scheduler
from repro.core.accelerators import PLATFORMS
from repro.core.contention import ProportionalShareModel
from repro.core.profiles import get_graph

from .common import emit, fmt_table

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_profile.json"

SCENARIOS = (
    # (platform, dnns, generating model kind, fit kind)
    ("xavier-agx", ("vgg19", "resnet101"), "piecewise", "piecewise"),
    ("xavier-agx", ("vgg19", "resnet101"), "proportional", "proportional"),
    ("agx-orin", ("inception", "resnet152"), "piecewise", "piecewise"),
)


def run_scenario(platform_name: str, dnns: tuple[str, ...],
                 true_kind: str, fit_kind: str, seed: int = 0) -> dict:
    platform = PLATFORMS[platform_name]()
    graphs = [get_graph(d, platform) for d in dnns]
    true_model = (ProportionalShareModel(capacity=1.0, sensitivity=3.0)
                  if true_kind == "proportional"
                  else profiling.paper_like_pccs())
    vsoc = profiling.VirtualSoC(platform, graphs, true_model, noise=0.003,
                                outlier_rate=0.05, seed=seed)
    t0 = time.perf_counter()
    bundle = profiling.run_pipeline(vsoc, fit_kind=fit_kind)
    pipeline_s = time.perf_counter() - t0

    fit = bundle.provenance["fit"]
    vs_truth = max(
        abs(bundle.model.slowdown(o, e) - vsoc.true_slowdown("GPU", o, e))
        / vsoc.true_slowdown("GPU", o, e)
        for o, e, _ in bundle.samples)

    plan = profiling.scheduler_from_bundle(bundle).solve(
        list(bundle.graphs), "latency", max_transitions=2, deadline_s=20.0)
    truth_plan = Scheduler(platform, model=true_model).solve(
        graphs, "latency", max_transitions=2, deadline_s=20.0)
    obj_rel = (abs(plan.objective - truth_plan.objective)
               / abs(truth_plan.objective))
    return {
        "platform": platform_name,
        "dnns": list(dnns),
        "generating_model": true_kind,
        "fit_kind": fit_kind,
        "n_samples": fit["n_samples"],
        "fit_rmse": fit["rmse"],
        "fit_max_rel_err": fit["max_rel_err"],
        "max_rel_err_vs_generating": vs_truth,
        "objective_rel_diff": obj_rel,
        "bundle_hash": bundle.bundle_hash(),
        "pipeline_s": round(pipeline_s, 4),
    }


def run(out_path: pathlib.Path) -> dict:
    rows = [run_scenario(*s) for s in SCENARIOS]
    data = {
        "benchmark": "profile_calibration",
        "timing": "one pipeline run per scenario (virtual SoC, seed 0)",
        "worst_fit_max_rel_err": max(r["fit_max_rel_err"] for r in rows),
        "worst_vs_generating": max(r["max_rel_err_vs_generating"]
                                   for r in rows),
        "worst_objective_rel_diff": max(r["objective_rel_diff"]
                                        for r in rows),
        "rows": rows,
    }
    out_path.write_text(json.dumps(data, indent=1))
    for r in rows:
        emit(f"profile_calibration.{r['platform']}.{r['generating_model']}",
             r["pipeline_s"] * 1e6,
             f"fit_max_rel={r['fit_max_rel_err']:.4f} "
             f"vs_gen={r['max_rel_err_vs_generating']:.4f} "
             f"obj_rel={r['objective_rel_diff']:.4f}")
    print(fmt_table(
        ["platform", "model", "samples", "fit rmse", "fit max-rel",
         "vs generating", "objective diff", "time"],
        [[r["platform"], r["generating_model"], r["n_samples"],
          f"{r['fit_rmse']:.4f}", f"{r['fit_max_rel_err']:.2%}",
          f"{r['max_rel_err_vs_generating']:.2%}",
          f"{r['objective_rel_diff']:.2%}", f"{r['pipeline_s']:.2f}s"]
         for r in rows]))
    print(f"wrote {out_path}")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    data = run(args.out)
    # the acceptance gate: calibration must stay within 5% of the
    # generating model — fail the build if it drifts.
    if data["worst_vs_generating"] > 0.05:
        print(f"ERROR: calibration deviates "
              f"{data['worst_vs_generating']:.2%} (> 5%) from the "
              f"generating model")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

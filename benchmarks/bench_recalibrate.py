"""Closed-loop recalibration benchmark: streaming re-fit vs frozen bundle.

Injects shared-memory *capacity drift* (a step followed by a ramp — the
effective bus capacity shrinking under thermal throttling / co-runner
churn) into a fleet replay and compares two arms over identical traffic
and identical ground truth:

* ``frozen``  — the seed behaviour: the offline :class:`ProfileBundle`'s
  contention model stays pinned for the whole replay; the §4.4 monitor /
  reschedule loop still runs.
* ``closed``  — the PR's closed loop: completion telemetry streams into a
  :class:`~repro.profiling.online.StreamingRecalibrator` (warm-started
  piecewise re-fits, versioned bundle lineage), published models are
  adopted into every pool plan, and tenants whose SLOs keep missing after
  re-solving are duty-cycled (:class:`~repro.serve.fleet.slo.
  TenantThrottle`).

Gates (asserted, so CI fails on regression):

1. the closed arm publishes at least ``MIN_REFITS`` re-fits whose lineage
   chain verifies back to the offline root bundle;
2. the re-fitted surface lands within ``ERR_BUDGET`` (5%) max relative
   error of the *post-drift* generating model at the observed telemetry
   coordinates, while the frozen surface does not;
3. the closed arm ends with strictly fewer per-tenant p99 SLO violations
   than the frozen arm.

    PYTHONPATH=src python -m benchmarks.bench_recalibrate            # full
    PYTHONPATH=src python -m benchmarks.bench_recalibrate --requests 4000

Trace, drift schedule and replay are all seeded/virtual-time, so every
number except wall-clock timings is bit-deterministic.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import tempfile

import numpy as np

from repro import configs, profiling
from repro.core.accelerators import tpu_pod_split, xavier_agx
from repro.core.profiles import get_graph
from repro.profiling import StreamingRecalibrator, verify_lineage
from repro.serve.fleet import (FleetConfig, FleetGateway, SLO, build_pool,
                               bursty_trace)
from repro.serve.gateway import GatewayConfig, TenantSpec

from .common import emit, fmt_table, timed

DEFAULT_OUT = (pathlib.Path(__file__).resolve().parents[1]
               / "BENCH_recalibrate.json")

SPLITS = ((1, 3), (2, 2))
TENANTS = (("stablelm", "stablelm-1.6b"), ("llama", "llama3.2-3b"))
SLOTS = 4
N_FLEET_TENANTS = 60
SEED = 7
#: burst rate is deliberately *below* the healthy pool's sustained
#: capacity: the only overload source in this benchmark must be the
#: injected capacity drift, or both arms violate on raw traffic alone
#: and the comparison measures nothing.
BASE_RPS, BURST_RPS = 60.0, 180.0
SLO_P99_MS = 2500.0

#: drift schedule, as fractions of the trace span: healthy until F_STEP,
#: capacity steps down, then ramps further down over [F_RAMP0, F_RAMP1]
#: and holds (scaling with the span keeps --requests N meaningful).
F_STEP, F_RAMP0, F_RAMP1 = 0.25, 0.45, 0.65
CAP_PRE, CAP_STEP, CAP_END = 1.0, 0.66, 0.55
#: antagonist demand levels cycled through the drift period — several ext
#: coordinates, so the re-fit is judged on a surface, not a single point.
EXT_LEVELS = (0.7, 0.9, 1.05)
DEMAND_PERIOD_MS = 1_000.0

MIN_REFITS = 2
ERR_BUDGET = 0.05


@dataclasses.dataclass(frozen=True)
class BusTruth:
    """Generating model of the drifting shared bus.

    Below capacity the bus is free; oversubscribed, *every* consumer
    stalls proportionally to the oversubscription, with heavier own
    demand stalling more (latency-bound small consumers still pay the
    row-conflict floor — the regime the proportional-share model, which
    sends ``slowdown -> 1`` as ``own -> 0``, cannot express).
    """

    capacity: float
    sensitivity: float = 1.5

    def slowdown(self, own: float, external: float) -> float:
        total = own + external
        if own <= 0.0 and external <= 0.0:
            return 1.0
        if total <= self.capacity:
            return 1.0
        over = total / self.capacity - 1.0
        weight = 0.6 + 0.4 * min(1.0, own / self.capacity)
        return 1.0 + self.sensitivity * over * weight


def capacity_at(t_ms: float, span_ms: float) -> float:
    """The drift schedule: step at F_STEP, ramp over [F_RAMP0, F_RAMP1]."""
    if t_ms < F_STEP * span_ms:
        return CAP_PRE
    if t_ms < F_RAMP0 * span_ms:
        return CAP_STEP
    if t_ms < F_RAMP1 * span_ms:
        frac = ((t_ms - F_RAMP0 * span_ms)
                / ((F_RAMP1 - F_RAMP0) * span_ms))
        return CAP_STEP + frac * (CAP_END - CAP_STEP)
    return CAP_END


def truth_at(t_ms: float, span_ms: float) -> BusTruth:
    return BusTruth(capacity=capacity_at(t_ms, span_ms))


def make_oracle(gw_box: dict, span_ms: float):
    """Ground-truth contention oracle: prices injected antagonist demand
    through the *time-varying* generating model (never through the
    gateway's belief model — that is the whole point of the benchmark)."""
    def oracle(pp, ext: float) -> np.ndarray:
        t = gw_box["gw"].now_ms if "gw" in gw_box else 0.0
        m = truth_at(t, span_ms)
        return np.array([m.slowdown(float(d), ext)
                         for d in pp.class_demand])
    return oracle


def offline_bundle() -> profiling.ProfileBundle:
    """The pre-drift characterization: a piecewise PCCS fitted on the
    virtual SoC while the bus is still healthy (capacity 1.0)."""
    plat = xavier_agx()
    vsoc = profiling.VirtualSoC(
        plat, [get_graph(d, plat) for d in ("vgg19", "resnet152")],
        model=BusTruth(capacity=CAP_PRE))
    return profiling.run_pipeline(vsoc, fit_kind="piecewise")


def _specs() -> list[TenantSpec]:
    return [TenantSpec(n, configs.get(a), max_slots=2, capacity=256,
                       prompt_len=64, max_new=16)
            for n, a in TENANTS]


def _build_pool(cache_root, model):
    from repro.core.plan import ShardedPlanCache
    cache = ShardedPlanCache(cache_root)
    gcfg = GatewayConfig(max_transitions=1, body_groups=1, model=model)
    plats = [tpu_pod_split(a, b, name=f"v5e-{a}x{b}-split")
             for a, b in SPLITS]
    return build_pool(_specs(), plats, gcfg, cache, slots=SLOTS,
                      deadline_s=5.0)


def demand_events(end_ms: float) -> list[tuple[float, int, float]]:
    """Periodic antagonist-demand switches over every plan: start at the
    capacity step, cycle ext levels, and keep firing through the ramp so
    the drifting truth is re-priced as it moves."""
    events = []
    k = 0
    t = F_STEP * end_ms
    while t <= end_ms:
        ext = EXT_LEVELS[k % len(EXT_LEVELS)]
        for p in range(len(SPLITS)):
            events.append((t, p, ext))
        k += 1
        t += DEMAND_PERIOD_MS
    return events


def run(n_requests: int, out_path: pathlib.Path,
        refit_steps: int = 800) -> dict:
    with timed() as t_bundle:
        bundle = offline_bundle()
    trace = bursty_trace(BASE_RPS, BURST_RPS, n_requests,
                         n_tenants=N_FLEET_TENANTS, seed=SEED)
    end_ms = float(trace.t_ms[-1])
    events = demand_events(end_ms)
    cfg = FleetConfig(default_slo=SLO(p99_ms=SLO_P99_MS),
                      slowdown_threshold=1.2, patience=8, cooldown=256,
                      reschedule_budget_s=0.1)

    rows = []
    arms = {}
    with tempfile.TemporaryDirectory() as tmp:
        cache_root = pathlib.Path(tmp) / "plancache"
        for arm in ("frozen", "closed"):
            pool = _build_pool(cache_root, bundle.model)
            box = {}
            recal = None
            arm_cfg = cfg
            if arm == "closed":
                recal = StreamingRecalibrator(
                    bundle, window=256, min_samples=128, min_new=128,
                    refit_steps=refit_steps)
                arm_cfg = dataclasses.replace(
                    cfg, throttle=True, throttle_duty=0.4,
                    throttle_margin=0.4, throttle_exit=0.05,
                    throttle_patience=12)
            gw = FleetGateway(pool, n_tenants=N_FLEET_TENANTS, cfg=arm_cfg,
                              capacity_hint=len(trace),
                              recalibrator=recal,
                              contention_oracle=make_oracle(box, end_ms))
            box["gw"] = gw
            with timed() as t:
                rep = gw.replay(trace, demand_events=events)
            slo = rep.slo_report()
            arms[arm] = (gw, rep, recal)
            rows.append({
                "arm": arm,
                "requests": rep.n_requests,
                "completed": rep.completed,
                "shed": rep.shed,
                "throttled": rep.throttled,
                "p50_ms": round(rep.p50_ms, 3),
                "p99_ms": round(rep.p99_ms, 3),
                "slo_p99_violations": slo["p99_violations"],
                "served_tenants": slo["served_tenants"],
                "reschedules": len(rep.reschedules),
                "recalibrations": len(rep.recalibrations),
                "throttle_events": len(rep.throttle_events),
                "replay_s": round(t["s"], 3),
            })
            emit(f"bench_recalibrate.{arm}", t["us"],
                 f"p99={rep.p99_ms:.1f}ms;violations={slo['p99_violations']};"
                 f"recal={len(rep.recalibrations)}")

    # ---- gates ----------------------------------------------------------
    _, rep_frozen, _ = arms["frozen"]
    _, rep_closed, recal = arms["closed"]
    truth_final = truth_at(end_ms, end_ms)

    assert recal.refits >= MIN_REFITS, \
        f"closed loop published only {recal.refits} re-fit(s)"
    verify_lineage(recal.lineage)
    assert recal.lineage[0].bundle_hash() == bundle.bundle_hash(), \
        "lineage root is not the offline bundle"

    refit_err = recal.max_rel_err_against(truth_final)
    # the frozen arm's staleness, measured at the same telemetry coords.
    stale = StreamingRecalibrator(bundle, window=recal.window)
    for own, ext, sl in recal._window.samples():
        stale.observe(own, ext, sl)
    frozen_err = stale.max_rel_err_against(truth_final)
    assert refit_err <= ERR_BUDGET, \
        (f"re-fit did not converge: {refit_err:.2%} max rel err vs "
         f"post-drift truth (budget {ERR_BUDGET:.0%})")
    assert refit_err < frozen_err, \
        (f"re-fit ({refit_err:.2%}) is no better than the frozen surface "
         f"({frozen_err:.2%})")

    viol_frozen = rep_frozen.slo_report()["p99_violations"]
    viol_closed = rep_closed.slo_report()["p99_violations"]
    assert viol_closed < viol_frozen, \
        (f"closed loop must end with strictly fewer SLO violations: "
         f"closed={viol_closed} vs frozen={viol_frozen}")

    result = {
        "benchmark": "fleet_recalibrate",
        "splits": [list(s) for s in SPLITS],
        "tenant_mix": [a for _, a in TENANTS],
        "fleet_tenants": N_FLEET_TENANTS,
        "requests": n_requests,
        "seed": SEED,
        "trace_hash": trace.trace_hash()[:16],
        "slo_p99_ms": SLO_P99_MS,
        "drift": {"span_ms": round(end_ms, 1),
                  "fractions": [F_STEP, F_RAMP0, F_RAMP1],
                  "capacity": [CAP_PRE, CAP_STEP, CAP_END],
                  "ext_levels": list(EXT_LEVELS)},
        "offline_bundle_hash": bundle.bundle_hash()[:16],
        "offline_fit_max_rel_err": round(
            bundle.provenance["fit"]["max_rel_err"], 4),
        "bundle_s": round(t_bundle["s"], 3),
        "refits": recal.refits,
        "lineage_depth": len(recal.lineage),
        "head_bundle_hash": recal.bundle.bundle_hash()[:16],
        "refit_max_rel_err": round(refit_err, 4),
        "frozen_max_rel_err": round(frozen_err, 4),
        "err_budget": ERR_BUDGET,
        "violations_frozen": viol_frozen,
        "violations_closed": viol_closed,
        "recalibration_events": [
            {"t_ms": round(t, 1), "bundle_hash": h[:16],
             "max_rel_err": round(e, 4)}
            for t, h, e in rep_closed.recalibrations],
        "rows": rows,
    }
    out_path.write_text(json.dumps(result, indent=1) + "\n")

    print()
    print(fmt_table(
        ["arm", "completed", "shed", "throttled", "p99", "violations",
         "recal", "replay"],
        [[r["arm"], r["completed"], r["shed"], r["throttled"],
          f"{r['p99_ms']:.0f}ms", r["slo_p99_violations"],
          r["recalibrations"], f"{r['replay_s']:.2f}s"]
         for r in rows]))
    print(f"re-fit err {refit_err:.2%} (frozen {frozen_err:.2%}, budget "
          f"{ERR_BUDGET:.0%}); violations {viol_closed} vs {viol_frozen}; "
          f"lineage depth {len(recal.lineage)}")
    print(f"wrote {out_path}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=40_000)
    ap.add_argument("--refit-steps", type=int, default=800,
                    help="Adam polish steps per streaming re-fit")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(args.requests, args.out, refit_steps=args.refit_steps)


if __name__ == "__main__":
    main()

"""Fig. 5 — Scenario 1: two instances of the same DNN, max throughput (Orin).

Multiple instances of one DNN process consecutive images concurrently.
Baselines: GPU-only (serial), naive GPU&DLA (one instance per accelerator),
Mensa-like greedy.  Paper claims up to 29% FPS over the best baseline, with
GoogleNet benefitting most (GPU only ~2x faster than DLA there) and
contention making naive GPU&DLA not always better than GPU-only.
"""
from __future__ import annotations

from repro.core import Scheduler

from .common import emit, fmt_table, timed

DNNS = ["googlenet", "inception", "resnet101", "resnet152", "vgg19"]
INSTANCES = 2
FRAMES = 4      # consecutive images per instance (steady state)


def main() -> list[dict]:
    sched = Scheduler("agx-orin")
    rows, out = [], []
    for dnn in DNNS:
        graphs = sched.graphs([dnn] * INSTANCES)
        its = [FRAMES] * INSTANCES
        base = {}
        for label, name in (("gpu_only", "fastest_only"),
                            ("gpu_dla", "naive_concurrent"),
                            ("mensa", "mensa")):
            _, res = sched.evaluate_baseline(name, graphs, iterations=its)
            base[label] = res.throughput_fps
        with timed() as t:
            plan = sched.solve(graphs, "throughput", solver="bb",
                               max_transitions=1, iterations=its)
        hax = plan.result.throughput_fps
        best_name = max(base, key=base.get)
        impr = 100 * (hax / base[best_name] - 1)
        rows.append(dict(dnn=dnn, **{f"fps_{k}": v for k, v in base.items()},
                         fps_hax=hax, best=best_name, impr=impr,
                         solver_s=t["s"]))
        out.append([dnn] + [f"{base[k]:.0f}" for k in base]
                   + [f"{hax:.0f}", f"{impr:+.0f}%"])
        emit(f"fig5.{dnn}", t["us"],
             f"fps_impr={impr:.1f}%;best_base={best_name}")
    print("\n== Fig 5: same-DNN concurrent instances, FPS (Orin) ==")
    print(fmt_table(["DNN", "GPU-only", "GPU&DLA", "Mensa", "HaX-CoNN",
                     "impr"], out))
    return rows


if __name__ == "__main__":
    main()

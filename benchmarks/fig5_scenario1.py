"""Fig. 5 — Scenario 1: two instances of the same DNN, max throughput (Orin).

Multiple instances of one DNN process consecutive images concurrently.
Baselines: GPU-only (serial), naive GPU&DLA (one instance per accelerator),
Mensa-like greedy.  Paper claims up to 29% FPS over the best baseline, with
GoogleNet benefitting most (GPU only ~2x faster than DLA there) and
contention making naive GPU&DLA not always better than GPU-only.
"""
from __future__ import annotations

from repro.core import api, solver_bb
from repro.core.baselines import fastest_only, mensa_like, naive_concurrent
from repro.core.simulate import simulate

from .common import emit, fmt_table, timed

DNNS = ["googlenet", "inception", "resnet101", "resnet152", "vgg19"]
INSTANCES = 2
FRAMES = 4      # consecutive images per instance (steady state)


def main() -> list[dict]:
    plat = api.resolve_platform("agx-orin")
    model = api.default_model(plat)
    rows, out = [], []
    for dnn in DNNS:
        graphs = api.resolve_graphs([dnn] * INSTANCES, plat)
        its = [FRAMES] * INSTANCES
        base = {}
        for name, fn in (("gpu_only", fastest_only),
                         ("gpu_dla", naive_concurrent),
                         ("mensa", mensa_like)):
            res = simulate(plat, fn(plat, graphs, iterations=its), model)
            base[name] = res.throughput_fps
        with timed() as t:
            sol = solver_bb.solve(plat, graphs, model, "throughput",
                                  max_transitions=1, iterations=its)
        hax = sol.result.throughput_fps
        best_name = max(base, key=base.get)
        impr = 100 * (hax / base[best_name] - 1)
        rows.append(dict(dnn=dnn, **{f"fps_{k}": v for k, v in base.items()},
                         fps_hax=hax, best=best_name, impr=impr,
                         solver_s=t["s"]))
        out.append([dnn] + [f"{base[k]:.0f}" for k in base]
                   + [f"{hax:.0f}", f"{impr:+.0f}%"])
        emit(f"fig5.{dnn}", t["us"],
             f"fps_impr={impr:.1f}%;best_base={best_name}")
    print("\n== Fig 5: same-DNN concurrent instances, FPS (Orin) ==")
    print(fmt_table(["DNN", "GPU-only", "GPU&DLA", "Mensa", "HaX-CoNN",
                     "impr"], out))
    return rows


if __name__ == "__main__":
    main()

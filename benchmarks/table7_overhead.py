"""Table 7 + §4 "Schedule generation": solver cost while the system runs.

The paper's Table 7 measures <2% inference slowdown from running Z3 on one
CPU core next to the accelerators; that co-run effect cannot be measured in
simulation, so this benchmark validates the *schedule generation* claims the
effect rests on: Z3 finds optimal schedules in under ~3 s per DNN pair
(~10 s for the 985-layer Inception-ResNet-v2), with a bounded number of
exact-simulator evaluations (the work actually stealing CPU cycles).
"""
from __future__ import annotations

from repro.core import Scheduler

from .common import emit, fmt_table, timed

PAIRS = [
    ("alexnet", "caffenet"), ("alexnet", "densenet"), ("alexnet", "googlenet"),
    ("alexnet", "inc-res-v2"), ("alexnet", "inception"),
    ("alexnet", "mobilenet"), ("alexnet", "resnet18"), ("alexnet", "resnet50"),
    ("alexnet", "resnet101"), ("alexnet", "resnet152"),
    ("alexnet", "vgg16"), ("alexnet", "vgg19"),
]


def main() -> list[dict]:
    sched = Scheduler("agx-orin")
    rows, out = [], []
    worst = 0.0
    for a, b in PAIRS:
        with timed() as t:
            plan = sched.solve([a, b], "latency", max_transitions=2,
                               deadline_s=30.0)
        sol = plan.solution
        worst = max(worst, t["s"])
        rows.append(dict(pair=f"{a}+{b}", solver_s=t["s"],
                         solver=plan.solver,
                         evaluated=sol.evaluated, optimal=sol.optimal))
        out.append([f"{a}+{b}", f"{t['s']:.2f}s ({plan.solver})",
                    sol.evaluated,
                    "opt" if sol.optimal else "timeout"])
        emit(f"table7.solve.{b}", t["us"],
             f"evaluated={sol.evaluated};optimal={sol.optimal}")
    print("\n== Table 7 proxy: Z3 schedule-generation cost (AlexNet + X) ==")
    print(fmt_table(["pair", "solver", "sims", "certificate"], out))
    print(f"worst-case solve: {worst:.2f}s (paper: <3s typical, ~10s for "
          f"985-layer nets)")
    return rows


if __name__ == "__main__":
    main()

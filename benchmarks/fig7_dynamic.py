"""Fig. 7 — D-HaX-CoNN: anytime convergence under CFG changes.

Replays the paper's dynamic scenario: the concurrent DNN set changes three
times (the designs of Table 6 exps 2, 5, 1); for each phase D-HaX-CoNN starts
from the best naive schedule and improves it as Z3 runs on a single core,
sampling the live objective at the paper's update points (25 ms, 100 ms,
250 ms, 500 ms, 1.5 s, ...).  Validates: monotone improvement, convergence to
the statically-computed oracle optimum, and slower convergence for the
3-network phase (more layer groups -> more transition candidates).
"""
from __future__ import annotations

from repro.core import Scheduler
from repro.core.dynamic import DHaXCoNN
from repro.core.profiles import chain

from .common import emit, fmt_table

PHASES = [
    ("exp2: resnet152+inception", ["resnet152", "inception"]),
    ("exp5: googlenet>resnet152 | fcn", None),   # built below (3 networks)
    ("exp1: vgg19+resnet152", ["vgg19", "resnet152"]),
]
CHECKPOINTS_S = (0.025, 0.1, 0.25, 0.5, 1.5, 4.0, 10.0)


def main() -> list[dict]:
    sched = Scheduler("xavier-agx")
    plat, model = sched.platform, sched.model
    rows = []
    for label, spec in PHASES:
        if spec is None:
            graphs = [chain(*sched.graphs(["googlenet", "resnet152"])),
                      sched.graphs(["fcn-resnet18"])[0]]
        else:
            graphs = sched.graphs(spec)
        d = DHaXCoNN(plat, graphs, model, "latency", max_transitions=2)
        elapsed = 0.0
        samples = [("init", d.best.objective)]
        for cp in CHECKPOINTS_S:
            if d.converged:
                break
            d.step(cp - elapsed)
            elapsed = cp
            samples.append((f"{cp:g}s", d.best.objective))
        # run toward convergence (bounded — the 3-network phase has a
        # large certified-optimality tail) to obtain the oracle estimate
        budget = 90.0
        while not d.converged and d.solver_time_s < budget:
            d.step(2.0)
        oracle = d.best.objective
        conv_time = d.solver_time_s
        rows.append(dict(phase=label, samples=samples, oracle=oracle,
                         converged_s=conv_time, certified=d.converged,
                         init=samples[0][1],
                         improvement=100 * (1 - oracle / samples[0][1])))
        emit(f"fig7.{label.split(':')[0]}", conv_time * 1e6,
             f"init={samples[0][1]:.2f};oracle={oracle:.2f};"
             f"impr={rows[-1]['improvement']:.0f}%;conv={conv_time:.2f}s")
    out = []
    for r in rows:
        traj = " -> ".join(f"{t}:{v:.2f}" for t, v in r["samples"])
        out.append([r["phase"], f"{r['init']:.2f}", f"{r['oracle']:.2f}",
                    f"{r['improvement']:.0f}%", f"{r['converged_s']:.2f}s"])
        print(f"  {r['phase']}: {traj}")
    print("\n== Fig 7: D-HaX-CoNN anytime convergence (Xavier) ==")
    print(fmt_table(["phase", "init (best naive)", "oracle opt",
                     "improvement", "converged"], out))
    return rows


if __name__ == "__main__":
    main()

"""Fig. 6 — contention slowdown of GoogleNet-on-GPU vs co-runners on DLA.

For each co-runner DNN (mapped entirely to the DLA of Xavier AGX), measures
the slowdown GoogleNet (entirely on GPU) experiences relative to its
standalone execution, then shows how much of that contention the HaX-CoNN
schedule removes (paper: memory contention reduced by up to 45%).
"""
from __future__ import annotations

from repro.core import Scheduler
from repro.core.simulate import Workload, simulate

from .common import emit, fmt_table, timed

CORUNNERS = ["caffenet", "resnet18", "resnet50", "resnet101", "resnet152",
             "inception", "vgg19"]


def main() -> list[dict]:
    sched = Scheduler("xavier-agx")
    plat, model = sched.platform, sched.model
    goog = sched.graphs(["googlenet"])[0]
    standalone = simulate(
        plat, [Workload(goog, ("GPU",) * len(goog))], model).makespan

    rows, out = [], []
    for other_name in CORUNNERS:
        other = sched.graphs([other_name])[0]
        if "DLA" not in other.accelerators:
            continue
        wls = [Workload(goog, ("GPU",) * len(goog)),
               Workload(other, ("DLA",) * len(other))]
        corun = simulate(plat, wls, model)
        goog_end = corun.finish_times[0]
        slowdown = goog_end / standalone
        with timed() as t:
            plan = sched.solve([goog, other], "latency",
                               max_transitions=2, deadline_s=20.0)
        # contention wall-ms under naive co-run vs under the HaX-CoNN schedule
        naive_cont = corun.contention_ms
        hax_cont = plan.result.contention_ms
        reduction = (100 * (1 - hax_cont / naive_cont)
                     if naive_cont > 1e-9 else 0.0)
        rows.append(dict(corunner=other_name, slowdown=slowdown,
                         naive_contention_ms=naive_cont,
                         hax_contention_ms=hax_cont, reduction=reduction))
        out.append([other_name, f"{slowdown:.2f}x", f"{naive_cont:.2f}",
                    f"{hax_cont:.2f}", f"{reduction:.0f}%"])
        emit(f"fig6.{other_name}", t["us"],
             f"goog_slowdown={slowdown:.2f}x;contention_reduction="
             f"{reduction:.0f}%")
    print("\n== Fig 6: GoogleNet@GPU slowdown vs co-runner@DLA (Xavier) ==")
    print(fmt_table(["co-runner", "GoogleNet slowdown", "naive cont (ms)",
                     "HaX-CoNN cont (ms)", "reduction"], out))
    mx = max(r["reduction"] for r in rows)
    print(f"max contention reduction: {mx:.0f}% (paper: up to 45%)")
    return rows


if __name__ == "__main__":
    main()

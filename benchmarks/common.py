"""Shared helpers for the paper-table benchmarks.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (us_per_call is
the solver/simulator wall time where that is the measured quantity) plus a
human-readable table, and returns a list of row dicts so ``run.py`` can
aggregate everything into bench_output.txt and EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Iterable


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
    box["us"] = box["s"] * 1e6


def fmt_table(headers: Iterable[str], rows: Iterable[Iterable[object]]) -> str:
    headers = list(headers)
    rows = [[str(c) for c in r] for r in rows]
    widths = [
        max([len(h)] + [len(r[i]) for r in rows if i < len(r)])
        for i, h in enumerate(headers)
    ]
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in rows:                      # rows may be ragged (triangular)
        out.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)

"""Table 8: exhaustive evaluation over every DNN pair on AGX Orin.

All 45 unordered pairs of the 10-network evaluation set run concurrently with
iteration balancing (§5.4: the faster DNN runs proportionally more iterations,
as in multi-sensor systems sampling at different frequencies).  For each pair
we report HaX-CoNN's throughput improvement over the best baseline, and check
the paper's aggregate claims: improvement on most pairs (paper: 35/45),
GPU-only correctly selected when layer-splitting cannot help (never-worse
guarantee), VGG-19 rows mostly favouring GPU-only.
"""
from __future__ import annotations

import itertools

from repro.core import Scheduler
from repro.core.profiles import DNN_SET
from repro.core.scheduler import failed

from .common import emit, fmt_table, timed


def balanced_iterations(plat, graphs) -> list[int]:
    times = [min(g.standalone_time(a) for a in g.accelerators) for g in graphs]
    slow = max(times)
    return [max(1, round(slow / t)) for t in times]


def run_pair(sched: Scheduler, a: str, b: str) -> dict:
    graphs = sched.graphs([a, b])
    its = balanced_iterations(sched.platform, graphs)
    # one vectorized sweep over every registered baseline (the haxconn row
    # below also searches through the batch evaluator by default).
    rows = sched.evaluate_baselines(graphs, iterations=its)
    base = {name: res.throughput_fps for name, res in rows.items()
            if not failed(res)}
    best_name = max(base, key=base.get)
    plan = sched.solve(graphs, "throughput", solver="bb",
                       max_transitions=1, iterations=its)
    impr = plan.result.throughput_fps / base[best_name]
    return dict(pair=(a, b), iters=its, best_baseline=best_name,
                base_fps=base[best_name], hax_fps=plan.result.throughput_fps,
                impr=impr,
                hax_uses_dsa=any("DLA" in w.assignment
                                 for w in plan.solution.workloads))


def main() -> list[dict]:
    sched = Scheduler("agx-orin")
    rows = []
    with timed() as t:
        for a, b in itertools.combinations(DNN_SET, 2):
            rows.append(run_pair(sched, a, b))
    improved = sum(1 for r in rows if r["impr"] > 1.005)
    never_worse = all(r["impr"] >= 1 - 1e-9 for r in rows)
    vgg_rows = [r for r in rows if "vgg19" in r["pair"]]
    vgg_improved = sum(1 for r in vgg_rows if r["impr"] > 1.005)

    # lower-triangular improvement matrix, like the paper's Table 8
    names = list(DNN_SET)
    idx = {n: i for i, n in enumerate(names)}
    cells = [["" for _ in names] for _ in names]
    for r in rows:
        i, j = sorted((idx[r["pair"][0]], idx[r["pair"][1]]), reverse=True)
        mark = f"{r['impr']:.2f}" if r["impr"] > 1.005 else "x"
        cells[i][j] = mark
    out = [[names[i]] + cells[i][: i + 1] for i in range(len(names))]
    print("\n== Table 8: HaX-CoNN/best-baseline throughput per pair (Orin) ==")
    print(fmt_table(["DNN"] + [n[:9] for n in names], out))
    print(f"pairs improved: {improved}/45 (paper: 35/45); never-worse: "
          f"{never_worse}; VGG19 pairs improved: {vgg_improved}/9 "
          f"(paper: 3/9)")
    emit("table8.exhaustive_pairs", t["us"],
         f"improved={improved}/45;paper=35/45;never_worse={never_worse};"
         f"vgg19_improved={vgg_improved}/9")
    return rows


if __name__ == "__main__":
    main()

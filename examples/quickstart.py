"""Quickstart: the paper's Fig. 1 case study in four lines of API.

Runs VGG-19 + ResNet101 concurrently on the Xavier AGX profile and shows
Case 1 (serial GPU), Case 2 (naive GPU&DLA), and Case 3 (HaX-CoNN optimal
layer-level schedule), then the same planner applied to two LLMs co-served
on a split TPU v5e pod.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import api


def soc_case_study():
    print("=" * 70)
    print("Fig. 1 case study: VGG-19 + ResNet101 on NVIDIA Xavier AGX")
    print("=" * 70)
    rows = api.compare(["vgg19", "resnet101"], platform="xavier-agx",
                       objective="latency", deadline_s=15.0)
    for name in ("fastest_only", "naive_concurrent", "mensa", "herald",
                 "h2h"):
        res = rows[name]
        if res is not None:
            print(f"  {name:18s} latency={res.latency_ms:6.2f} ms   "
                  f"fps={res.throughput_fps:6.1f}")
    sol = rows["haxconn"]
    print(f"  {'HaX-CoNN':18s} latency={sol.result.latency_ms:6.2f} ms   "
          f"fps={sol.result.throughput_fps:6.1f}   "
          f"(certified optimal: {sol.optimal})")
    for wl in sol.workloads:
        print(f"    {wl.graph.name:12s} -> {' '.join(wl.assignment)}")


def pod_case_study():
    print()
    print("=" * 70)
    print("Same planner, TPU pod: llama3.2-3b + qwen1.5-32b decode_32k "
          "on a split v5e pod")
    print("=" * 70)
    from repro import configs
    from repro.serve.concurrent import plan_concurrent_serving
    plan = plan_concurrent_serving(
        [configs.get("llama3.2-3b"), configs.get("qwen1.5-32b")],
        ["decode_32k", "decode_32k"],
        objective="latency", deadline_s=10.0)
    print(plan.summary())


if __name__ == "__main__":
    soc_case_study()
    pod_case_study()

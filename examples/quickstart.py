"""Quickstart: the paper's Fig. 1 case study through the Scheduler API.

Runs VGG-19 + ResNet101 concurrently on the Xavier AGX profile and shows
Case 1 (serial GPU), Case 2 (naive GPU&DLA), and Case 3 (HaX-CoNN optimal
layer-level schedule); serializes the winning Plan, reloads it from JSON
(a cache hit — no second solve), then applies the same planner to two LLMs
co-served on a split TPU v5e pod.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Plan, Scheduler
from repro.core.scheduler import failed


def soc_case_study():
    print("=" * 70)
    print("Fig. 1 case study: VGG-19 + ResNet101 on NVIDIA Xavier AGX")
    print("=" * 70)
    sched = Scheduler("xavier-agx")
    rows = sched.compare(["vgg19", "resnet101"], objective="latency",
                         deadline_s=15.0)
    for name in ("fastest_only", "naive_concurrent", "mensa", "herald",
                 "h2h"):
        res = rows[name]
        if failed(res):
            print(f"  {name:18s} infeasible: {res['error']['message']}")
        else:
            print(f"  {name:18s} latency={res.latency_ms:6.2f} ms   "
                  f"fps={res.throughput_fps:6.1f}")
    plan = rows["haxconn"]
    if failed(plan):
        raise SystemExit(f"solver failed: {plan['error']['message']}")
    sol = plan.solution
    print(f"  {'HaX-CoNN':18s} latency={sol.result.latency_ms:6.2f} ms   "
          f"fps={sol.result.throughput_fps:6.1f}   "
          f"(certified optimal: {sol.optimal}, solver: {plan.solver}, "
          f"{plan.solve_time_s:.2f}s)")
    for wl in sol.workloads:
        print(f"    {wl.graph.name:12s} -> {' '.join(wl.assignment)}")

    # the schedule is an artifact: persist, reload, and re-request — the
    # reloaded plan drives the scheduler's cache, so no second solve.
    blob = plan.to_json()
    sched2 = Scheduler("xavier-agx")
    sched2.cache.add(Plan.from_json(blob))
    again = sched2.solve(["vgg19", "resnet101"], "latency", deadline_s=15.0)
    print(f"  reloaded plan {again.request_hash[:12]} from JSON: "
          f"{again.assignments} (solver invocations: {sched2.solves})")


def pod_case_study():
    print()
    print("=" * 70)
    print("Same planner, TPU pod: llama3.2-3b + qwen1.5-32b decode_32k "
          "on a split v5e pod")
    print("=" * 70)
    from repro import configs
    from repro.serve.concurrent import plan_concurrent_serving
    plan = plan_concurrent_serving(
        [configs.get("llama3.2-3b"), configs.get("qwen1.5-32b")],
        ["decode_32k", "decode_32k"],
        objective="latency", deadline_s=10.0)
    print(plan.summary())


if __name__ == "__main__":
    soc_case_study()
    pod_case_study()

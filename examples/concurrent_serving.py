"""End-to-end driver: concurrent batched serving of two LLMs, HaX-CoNN-
scheduled (the paper's kind of workload — inference — as the assignment's
end-to-end driver).

Two reduced-config models (a dense llama-style LM and an RWKV-6 SSM) serve
batched requests for real on CPU through the continuous-batching engine;
the HaX-CoNN planner maps their layer groups onto the two virtual
accelerators of a split pod and the predicted timeline is compared against
every baseline.  Outputs are real tokens; timing is the simulated pod
schedule (this container has no TPU).

    PYTHONPATH=src python examples/concurrent_serving.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ShapeCell
from repro.models import build
from repro.serve.concurrent import plan_concurrent_serving
from repro.serve.engine import ServingEngine


def main():
    print("=" * 70)
    print("1) Plan: llama3.2-3b + rwkv6-7b co-served on a split v5e pod")
    print("=" * 70)
    cell = ShapeCell("serve_2k", 2048, 16, "decode")
    plan = plan_concurrent_serving(
        [configs.get("llama3.2-3b"), configs.get("rwkv6-7b")],
        [cell, cell], objective="throughput", iterations=[4, 4],
        deadline_s=10.0)
    print(plan.summary())

    print()
    print("=" * 70)
    print("2) Execute: batched requests through both engines (reduced "
          "configs, real compute)")
    print("=" * 70)
    rng = np.random.default_rng(0)
    engines = []
    for arch in ("llama3.2-3b", "rwkv6-7b"):
        cfg = configs.get(arch).reduced()
        model = build(cfg, backend="xla")
        params = model.init(jax.random.PRNGKey(hash(arch) % 2**31))
        engines.append((arch, cfg, ServingEngine(model, params,
                                                 max_slots=4, capacity=96)))
    t0 = time.perf_counter()
    reqs = {}
    for arch, cfg, eng in engines:
        reqs[arch] = [eng.submit(rng.integers(0, cfg.vocab, size=8),
                                 max_new=12) for _ in range(6)]
    # round-robin decode steps — both models advance "concurrently"
    active = True
    steps = 0
    while active:
        active = False
        for _, _, eng in engines:
            if eng.queue or eng.active:
                eng.step()
                active = True
        steps += 1
    wall = time.perf_counter() - t0
    for arch, _, eng in engines:
        done = eng.completed
        print(f"  {arch:14s}: {len(done)} requests served, "
              f"{sum(len(r.tokens) for r in done)} tokens, "
              f"sample output: {done[0].tokens}")
    print(f"  wall time (CPU, reduced configs): {wall:.2f}s over "
          f"{steps} engine rounds")
    print(f"  pod-schedule prediction: "
          f"{plan.solution.result.throughput_fps:.1f} inferences/s, "
          f"{100 * (plan.speedup_vs_best_baseline - 1):+.1f}% vs best "
          f"baseline")


if __name__ == "__main__":
    main()

"""Multi-tenant serving through the contention-aware gateway.

Two heterogeneous LLMs (stablelm-1.6b + llama3.2-3b) are served
*concurrently* by :class:`repro.serve.gateway.MultiTenantGateway`:

  1. each tenant's full-size config is characterized as a prefill->decode
     phase chain and the HaX-CoNN solver maps (model, phase) pairs onto an
     asymmetric pod split — beating the naive round-robin placement on
     simulated throughput;
  2. both reduced-config models then serve real batched requests on CPU
     under a shared KV-memory budget (admission control defers slots when
     the global working set would overflow);
  3. an injected slowdown on one tenant trips the §4.4 monitor and the
     gateway re-solves the schedule live.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import numpy as np

from repro import configs
from repro.core.accelerators import tpu_pod_split
from repro.serve.gateway import (GatewayConfig, MultiTenantGateway,
                                 TenantSpec, kv_bytes_per_token)


def main():
    print("=" * 70)
    print("1) Plan: stablelm-1.6b + llama3.2-3b on an asymmetric pod split")
    print("=" * 70)
    platform = tpu_pod_split(4, 12, name="v5e-4x12-split")
    specs = [
        TenantSpec("stablelm", configs.get("stablelm-1.6b").reduced(),
                   plan_cfg=configs.get("stablelm-1.6b"),
                   max_slots=2, capacity=48, prompt_len=128, max_new=8),
        TenantSpec("llama", configs.get("llama3.2-3b").reduced(),
                   plan_cfg=configs.get("llama3.2-3b"),
                   max_slots=2, capacity=48, prompt_len=128, max_new=8),
    ]
    # budget for ~3 of the 4 possible slots: admission throttles the rest
    budget = 3 * max(s.kv_bytes_per_slot for s in specs)
    gw = MultiTenantGateway(specs, GatewayConfig(
        platform=platform, memory_budget_bytes=budget,
        patience=2, cooldown=4))
    print(gw.plan.summary())
    art = gw.plan.plan
    print(f"  plan artifact {art.request_hash[:12]}: solver={art.solver}, "
          f"solve={art.solve_time_s:.2f}s — gw.plan.plan.save(path) "
          f"persists it for cold-start boots (--plan in repro.launch.serve)")
    assert gw.plan.speedup_vs_round_robin > 1.0, \
        "contention-aware plan must beat round-robin"

    print()
    print("=" * 70)
    print("2) Serve: real tokens, shared KV budget "
          f"({budget / 1024:.0f} KiB across all tenants)")
    print("=" * 70)
    rng = np.random.default_rng(0)
    for name, s in gw.specs.items():
        for _ in range(3):
            gw.submit(name, rng.integers(0, s.cfg.vocab, size=6))
    while gw.has_work and gw.total_steps < 500:
        # replayed measurement stream (in lieu of real SoC counters): a
        # co-runner appears on llama's mesh from step 6 on, 5x its nominal
        # step latency; stablelm stays on-prediction throughout.
        llama_ms = 5.0 if gw.total_steps >= 6 else 1.0
        gw.step(observed_ms={"stablelm": 1.0, "llama": llama_ms})
    for name, eng in gw.engines.items():
        done = eng.completed
        print(f"  {name:10s}: {len(done)} requests, "
              f"{sum(len(r.tokens) for r in done)} tokens, "
              f"sample output: {done[0].tokens}")
    print(f"  gateway steps: {gw.total_steps}, "
          f"deferred admissions (budget): {gw.deferred_admissions}")

    print()
    print("=" * 70)
    print("3) Dynamic loop: injected slowdown -> re-schedule events")
    print("=" * 70)
    for ev in gw.reschedules:
        print(f"  step {ev.step}: tenants={ev.tenants} "
              f"observed {ev.observed_factor:.2f}x slower -> re-solved "
              f"({'new assignment' if ev.changed else 'schedule confirmed'})")
    if not gw.reschedules:
        print("  (no deviation large enough — monitor stayed quiet)")


if __name__ == "__main__":
    main()

"""Measured profiles end-to-end: profile -> calibrate -> bundle -> solve.

Runs the whole repro.profiling pipeline on the deterministic virtual SoC
(CPU, well under a minute):

1. *Profile*: time every layer group of VGG-19 + ResNet101 on the virtual
   Xavier AGX (warmup/repetition/outlier-rejection discipline), reading
   the requested-memory-throughput counters — the paper's §3.2 one-time
   characterization, measured instead of copied from Table 2.
2. *Calibrate*: co-run each group against the streaming antagonist sweep
   and fit a monotone PCCS surface (PiecewiseModel) to the
   (own, external) -> slowdown samples by JAX least squares.
3. *Bundle*: pack platform + measured graphs + calibrated model into a
   content-hashed ProfileBundle, round-trip it through JSON.
4. *Schedule*: solve the Fig.-1-style VGG19+ResNet101 scenario straight
   from the bundle and compare with the plan under the generating model.

    PYTHONPATH=src python examples/profile_and_schedule.py
"""
import tempfile
import time

from repro import profiling
from repro.core import Scheduler
from repro.core.accelerators import xavier_agx
from repro.core.profiles import get_graph

t0 = time.time()
platform = xavier_agx()
truth_graphs = [get_graph(d, platform) for d in ("vgg19", "resnet101")]

print("=" * 70)
print("1. profile on the virtual SoC (generating model: paper-like PCCS)")
print("=" * 70)
vsoc = profiling.VirtualSoC(platform, truth_graphs, noise=0.003,
                            outlier_rate=0.05, seed=0)
measured = profiling.profile_graphs(vsoc)
for g in measured:
    truth = next(t for t in truth_graphs if t.name == g.name)
    err = max(abs(mg.time_on(a) - tg.time_on(a)) / tg.time_on(a)
              for mg, tg in zip(g.groups, truth.groups) for a in tg.times)
    print(f"  {g.name}: {len(g)} groups measured, "
          f"max standalone-time error vs truth {err:.2%}")

print("=" * 70)
print("2. co-run sweep + PCCS calibration")
print("=" * 70)
samples = profiling.corun_sweep(vsoc, measured)
result = profiling.fit_piecewise(samples)
print(f"  {result.summary()}")
worst = max(abs(result.model.slowdown(o, e) - vsoc.true_slowdown("GPU", o, e))
            / vsoc.true_slowdown("GPU", o, e) for o, e, _ in samples)
print(f"  max deviation from the *generating* model on the sampled grid: "
      f"{worst:.2%}")

print("=" * 70)
print("3. content-hashed ProfileBundle round-trip")
print("=" * 70)
bundle = profiling.ProfileBundle(
    platform=platform, graphs=measured, model=result.model,
    samples=tuple(samples),
    provenance={"fit": result.report.to_dict(), **vsoc.describe()})
with tempfile.NamedTemporaryFile(suffix=".json") as f:
    path = bundle.save(f.name)
    reloaded = profiling.ProfileBundle.load(path)
assert reloaded.bundle_hash() == bundle.bundle_hash()
print(bundle.summary())

print("=" * 70)
print("4. schedule from measured profiles vs generating ground truth")
print("=" * 70)
sched = profiling.scheduler_from_bundle(bundle)
plan = sched.solve(list(bundle.graphs), "latency", max_transitions=2,
                   deadline_s=20.0)
truth_plan = Scheduler(platform, model=profiling.paper_like_pccs()).solve(
    truth_graphs, "latency", max_transitions=2, deadline_s=20.0)
rel = abs(plan.objective - truth_plan.objective) / truth_plan.objective
print(plan.summary())
print(f"  generating-model objective: {truth_plan.objective:.4f} ms")
print(f"  measured-bundle objective:  {plan.objective:.4f} ms "
      f"(rel diff {rel:.2%})")
print(f"done in {time.time() - t0:.1f}s")

"""D-HaX-CoNN demo: anytime schedule improvement under CFG changes (§5.3).

Simulates an autonomous loop whose DNN set changes (discovery -> tracking
mode): for each phase, D-HaX-CoNN starts from the best naive schedule and
improves it while the loop keeps running, converging to the certified
optimum.

    PYTHONPATH=src python examples/dynamic_scheduling.py
"""
from repro.core import api
from repro.core.dynamic import DHaXCoNN

PHASES = [
    ("discovery: googlenet + resnet101", ["googlenet", "resnet101"]),
    ("tracking:  vgg19 + resnet152", ["vgg19", "resnet152"]),
    ("alert:     inception + resnet152", ["inception", "resnet152"]),
]


def main():
    plat = api.resolve_platform("xavier-agx")
    model = api.default_model(plat)
    for label, dnns in PHASES:
        graphs = api.resolve_graphs(dnns, plat)
        d = DHaXCoNN(plat, graphs, model, "latency", max_transitions=2)
        print(f"\n== CFG change -> {label}")
        print(f"   initial (best naive): {d.best.objective:7.2f} ms")
        budgets = [0.025, 0.1, 0.25, 0.5, 1.5]
        spent = 0.0
        for b in budgets:
            if d.converged:
                break
            d.step(b - spent)
            spent = b
            print(f"   after {b * 1e3:6.0f} ms solver time: "
                  f"{d.best.objective:7.2f} ms "
                  f"{'(converged, certified optimal)' if d.converged else ''}")
        while not d.converged:
            d.step(1.0)
        print(f"   oracle optimum: {d.best.objective:7.2f} ms   "
              f"(total solver time {d.solver_time_s:.2f}s, "
              f"{d.evaluated} exact evaluations)")


if __name__ == "__main__":
    main()

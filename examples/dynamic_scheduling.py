"""D-HaX-CoNN demo: anytime schedule improvement under CFG changes (§5.3).

Simulates an autonomous loop whose DNN set changes (discovery -> tracking
mode): for each phase, D-HaX-CoNN starts from the best naive schedule and
improves it while the loop keeps running, converging to the certified
optimum.

    PYTHONPATH=src python examples/dynamic_scheduling.py
"""
from repro.core import Scheduler
from repro.core.dynamic import DHaXCoNN

PHASES = [
    ("discovery: googlenet + resnet101", ["googlenet", "resnet101"]),
    ("tracking:  vgg19 + resnet152", ["vgg19", "resnet152"]),
    ("alert:     inception + resnet152", ["inception", "resnet152"]),
]


def main():
    sched = Scheduler("xavier-agx")
    plat, model = sched.platform, sched.model
    for label, dnns in PHASES:
        graphs = sched.graphs(dnns)
        d = DHaXCoNN(plat, graphs, model, "latency", max_transitions=2)
        print(f"\n== CFG change -> {label}")
        print(f"   initial (best naive): {d.best.objective:7.2f} ms")
        budgets = [0.025, 0.1, 0.25, 0.5, 1.5]
        spent = 0.0
        for b in budgets:
            if d.converged:
                break
            d.step(b - spent)
            spent = b
            print(f"   after {b * 1e3:6.0f} ms solver time: "
                  f"{d.best.objective:7.2f} ms "
                  f"{'(converged, certified optimal)' if d.converged else ''}")
        while not d.converged:
            d.step(1.0)
        print(f"   oracle optimum: {d.best.objective:7.2f} ms   "
              f"(total solver time {d.solver_time_s:.2f}s, "
              f"{d.evaluated} exact evaluations)")


if __name__ == "__main__":
    main()

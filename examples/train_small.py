"""Train a small LM end-to-end on CPU: data pipeline -> trainer ->
checkpoint -> restart, with a mid-run simulated preemption.

The paper's workload kind is inference (see concurrent_serving.py for the
serving driver); this example exercises the training substrate the dry-run
lowers at pod scale: microbatched grad accumulation, AdamW, warmup-cosine,
atomic checkpoints, bitwise restart.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

import jax

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced(
        n_layers=4, d_model=128, d_ff=512, vocab=512, microbatches=2)
    model = build(cfg, backend="xla")
    n = sum(x.size for x in jax.tree.leaves(model.abstract_params()))
    print(f"model: {cfg.name}  params={n / 1e6:.2f}M  "
          f"microbatches={cfg.microbatches}")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(model, data, ckpt_dir=ckpt_dir, ckpt_every=50)
        trainer.restore_or_init(jax.random.PRNGKey(0))

        half = args.steps // 2
        print(f"\ntraining to step {half}, then simulating a preemption...")
        trainer.run(half, log_every=max(10, half // 5),
                    on_metrics=lambda m: print(
                        f"  step {m['step']:4d}  loss={m['loss']:.4f}  "
                        f"gnorm={m['grad_norm']:.2f}  {m['wall_s']:.1f}s"))

        print("\n-- restart from checkpoint (new Trainer process) --")
        trainer2 = Trainer(model, data, ckpt_dir=ckpt_dir, ckpt_every=50)
        trainer2.restore_or_init(jax.random.PRNGKey(123))  # key ignored
        print(f"resumed at step {int(trainer2.state.step)}")
        hist = trainer2.run(args.steps, log_every=max(10, args.steps // 8),
                            on_metrics=lambda m: print(
                                f"  step {m['step']:4d}  "
                                f"loss={m['loss']:.4f}"))
        print(f"\nfinal loss {hist[-1]['loss']:.4f} after "
              f"{args.steps} steps (restarted at {half})")


if __name__ == "__main__":
    main()

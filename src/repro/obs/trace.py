"""Span-based structured tracer with Chrome-trace/Perfetto JSON export.

The tracer records *complete* spans (name, category, start, duration,
args) and *instant* events on named tracks, in memory, with zero
third-party dependencies.  Design constraints, in order:

1. **Disabled is free.**  The module-level default tracer is a
   :class:`NullTracer`; every instrumentation site goes through it and
   must cost no more than an attribute lookup plus a shared no-op
   context manager.  Hot loops (the fleet replay, the anneal chunk
   loop) stay hot.

2. **Deterministic export.**  The clock is injectable.  Wall-clock
   tracing uses ``time.perf_counter``; the fleet gateway instead
   records against its *virtual* millisecond clock, so two identical
   replays export byte-identical JSON (sorted keys, fixed separators,
   stable track ids, recording order preserved).  That makes traces
   CI-diffable artifacts, same as plans and profile bundles.

3. **Perfetto-loadable.**  :meth:`Tracer.to_chrome` emits the Chrome
   trace-event JSON object format (``{"traceEvents": [...]}`` with
   ``ph: "X"`` complete events and ``ph: "i"`` instants, timestamps in
   microseconds) which ``ui.perfetto.dev`` and ``chrome://tracing``
   both load directly.

Spans nest per thread: each thread carries its own span stack, and the
exported events carry that thread's stable track id, so concurrent
solver threads render as parallel tracks instead of interleaving.

Bulk ingestion: :meth:`Tracer.add_events` appends pre-built event
dicts in one locked call.  The fleet gateway derives its million
per-request queue/service spans *post hoc* from its flat NumPy record
arrays and hands them over in bulk — recording them live, one context
manager per request, would swamp the replay loop.
"""
from __future__ import annotations

import contextlib
import functools
import json
import threading
import time
from typing import Any, Callable, Iterator

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "instant",
    "trace",
]

#: process id used in every exported event — the tracer is in-process
#: only, and a fixed pid keeps exports byte-stable across runs.
_PID = 1


class Span:
    """Mutable handle for an open span: ``with tracer.span(...) as sp``.

    ``sp.set(key=value)`` attaches args after the span opened (e.g. the
    objective once the solver returns).  Plain dict under the hood so a
    closed span serializes without translation.
    """

    __slots__ = ("name", "cat", "t0", "args")

    def __init__(self, name: str, cat: str, t0: float,
                 args: dict[str, Any]) -> None:
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.args = args

    def set(self, **kwargs: Any) -> "Span":
        self.args.update(kwargs)
        return self


class NullTracer:
    """Disabled tracer: every operation is a near-zero no-op.

    All instrumentation sites call through this by default, so the
    overhead of shipping tracing in library code is one attribute
    lookup and a shared pre-built context manager.
    """

    enabled = False

    _NULL_SPAN = Span("", "", 0.0, {})
    _NULL_CTX = contextlib.nullcontext(_NULL_SPAN)

    def span(self, name: str, cat: str = "repro", **args: Any):
        return self._NULL_CTX

    def instant(self, name: str, cat: str = "repro", *,
                ts_ms: float | None = None, track: str | None = None,
                **args: Any) -> None:
        return None

    def complete(self, name: str, ts_ms: float, dur_ms: float,
                 cat: str = "repro", *, track: str | None = None,
                 **args: Any) -> None:
        return None

    def add_events(self, events) -> None:
        return None

    def counter_sample(self, name: str, ts_ms: float,
                       values: dict[str, float]) -> None:
        return None

    def trace(self, name: str | None = None, cat: str = "repro"):
        """Decorator form: returns the function unchanged."""
        if callable(name):  # bare @tracer.trace
            return name

        def deco(fn):
            return fn
        return deco


#: shared disabled tracer; also the initial global tracer.
NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer.

    Parameters
    ----------
    clock:
        Zero-arg callable returning *milliseconds* as a float.  Default
        is wall time from ``time.perf_counter``.  The fleet gateway
        passes its virtual clock so traces are deterministic.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] | None = None) -> None:
        self._wall = clock is None
        self._clock = clock or (lambda: time.perf_counter() * 1e3)
        self._lock = threading.Lock()
        self._events: list[dict[str, Any]] = []
        self._local = threading.local()
        # thread/track name -> stable small integer tid, in first-seen
        # order (deterministic for single-threaded / virtual-clock use).
        self._tids: dict[str, int] = {}

    # -- internals ---------------------------------------------------

    def _now(self) -> float:
        return self._clock()

    def _tid_locked(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
        return tid

    def _thread_track(self) -> str:
        name = getattr(self._local, "track", None)
        if name is None:
            t = threading.current_thread()
            name = "main" if t is threading.main_thread() else t.name
            self._local.track = name
        return name

    def _append(self, ev: dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    # -- recording API ----------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "repro",
             **args: Any) -> Iterator[Span]:
        """Record a complete event covering the ``with`` body."""
        sp = Span(name, cat, self._now(), dict(args))
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            t1 = self._now()
            track = self._thread_track()
            with self._lock:
                self._events.append({
                    "ph": "X", "name": sp.name, "cat": sp.cat,
                    "ts": round(sp.t0 * 1e3, 3),
                    "dur": round((t1 - sp.t0) * 1e3, 3),
                    "pid": _PID, "tid": self._tid_locked(track),
                    "args": sp.args,
                })

    def trace(self, name: str | None = None, cat: str = "repro"):
        """Decorator: wrap a function in a span named after it."""
        def deco(fn, span_name=None):
            label = span_name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(label, cat):
                    return fn(*a, **kw)
            return wrapper

        if callable(name):  # bare @tracer.trace
            return deco(name)
        return lambda fn: deco(fn, name)

    def instant(self, name: str, cat: str = "repro", *,
                ts_ms: float | None = None, track: str | None = None,
                **args: Any) -> None:
        """Record a zero-duration instant event (rendered as an arrow)."""
        ts = self._now() if ts_ms is None else ts_ms
        track = track or self._thread_track()
        with self._lock:
            self._events.append({
                "ph": "i", "name": name, "cat": cat,
                "ts": round(ts * 1e3, 3), "pid": _PID,
                "tid": self._tid_locked(track), "s": "t",
                "args": dict(args),
            })

    def complete(self, name: str, ts_ms: float, dur_ms: float,
                 cat: str = "repro", *, track: str | None = None,
                 **args: Any) -> None:
        """Record a complete event at explicit (clock-domain) times."""
        track = track or self._thread_track()
        with self._lock:
            self._events.append({
                "ph": "X", "name": name, "cat": cat,
                "ts": round(ts_ms * 1e3, 3),
                "dur": round(dur_ms * 1e3, 3),
                "pid": _PID, "tid": self._tid_locked(track),
                "args": dict(args),
            })

    def counter_sample(self, name: str, ts_ms: float,
                       values: dict[str, float]) -> None:
        """Record a Chrome counter-track sample (stacked area chart)."""
        with self._lock:
            self._events.append({
                "ph": "C", "name": name, "cat": "metrics",
                "ts": round(ts_ms * 1e3, 3), "pid": _PID,
                "tid": 0, "args": dict(values),
            })

    def add_events(self, events) -> None:
        """Bulk-append pre-built Chrome event dicts (one lock trip).

        Callers own the event shape; :meth:`track_id` hands out the
        stable tid for a named track.  Used by the fleet replay to
        ingest spans derived from its NumPy record arrays.
        """
        with self._lock:
            self._events.extend(events)

    def track_id(self, track: str) -> int:
        """Stable integer tid for a named track (registering it)."""
        with self._lock:
            return self._tid_locked(track)

    # -- export ------------------------------------------------------

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_chrome(self) -> dict[str, Any]:
        """Chrome trace-event object format (Perfetto-loadable)."""
        with self._lock:
            meta = [
                {"ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
                 "args": {"name": track}}
                for track, tid in self._tids.items()
            ]
            return {
                "traceEvents": meta + list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {
                    "producer": "repro.obs",
                    "clock": "wall_ms" if self._wall else "virtual_ms",
                },
            }

    def to_json(self, *, indent: int | None = None) -> str:
        """Deterministic JSON: sorted keys, fixed separators.

        With a virtual clock and identical inputs this is byte-stable
        across runs — the property the determinism tests pin.
        """
        seps = (",", ": ") if indent is not None else (",", ":")
        return json.dumps(self.to_chrome(), sort_keys=True,
                          indent=indent, separators=seps)

    def write(self, path) -> None:
        import pathlib

        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json() + "\n")


# -- module-level current tracer -------------------------------------

_current: NullTracer | Tracer = NULL_TRACER
_current_lock = threading.Lock()


def get_tracer() -> NullTracer | Tracer:
    """The process-wide current tracer (NullTracer unless configured)."""
    return _current


def set_tracer(tracer: NullTracer | Tracer | None):
    """Install ``tracer`` globally; ``None`` restores the null tracer.

    Returns the previous tracer so callers can restore it.
    """
    global _current
    with _current_lock:
        prev = _current
        _current = NULL_TRACER if tracer is None else tracer
    return prev


def span(name: str, cat: str = "repro", **args: Any):
    """``with obs.span("solve"):`` against the current global tracer."""
    return _current.span(name, cat, **args)


def instant(name: str, cat: str = "repro", **kwargs: Any) -> None:
    _current.instant(name, cat, **kwargs)


def trace(name: str | None = None, cat: str = "repro"):
    """Decorator resolving the global tracer *per call* (late-bound).

    Unlike ``tracer.trace`` this keeps working when the global tracer
    is swapped after import — the common case for library code.
    """
    def deco(fn, span_name=None):
        label = span_name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            t = _current
            if not t.enabled:
                return fn(*a, **kw)
            with t.span(label):
                return fn(*a, **kw)
        return wrapper

    if callable(name):
        return deco(name)
    return lambda fn: deco(fn, name)

"""repro.obs — zero-dependency observability: traces, metrics, timelines.

Three pillars (see ``docs/observability.md``):

* :mod:`repro.obs.trace` — span tracer with Chrome-trace/Perfetto
  export; clock-injectable so virtual-clock replays are byte-stable.
* :mod:`repro.obs.metrics` — counters/gauges/histograms registry with
  JSON snapshots and Prometheus text exposition; home of the canonical
  serving metric schemas.
* :mod:`repro.obs.timeline` — recorded Plan/simulation timelines as
  per-accelerator Gantt charts (Perfetto JSON + ASCII).

This module additionally owns the logger hierarchy: every module under
``src/repro/`` obtains its logger via :func:`get_logger`, which pins
names to the ``repro.<pkg>.<mod>`` convention, and CLIs call
:func:`configure_logging` exactly once.
"""
from __future__ import annotations

import json as _json
import logging
import sys

from .metrics import (  # noqa: F401
    ADMISSION_SCHEMA,
    Counter,
    GATEWAY_SCHEMA,
    Gauge,
    Histogram,
    MetricsRegistry,
    TENANT_SCHEMA,
    conform,
    get_registry,
    set_registry,
)
from .trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    get_tracer,
    instant,
    set_tracer,
    span,
    trace,
)

__all__ = [
    "ADMISSION_SCHEMA", "Counter", "GATEWAY_SCHEMA", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_TRACER", "NullTracer", "Span", "TENANT_SCHEMA",
    "Tracer", "configure_logging", "conform", "get_logger", "get_registry",
    "get_tracer", "instant", "set_registry", "set_tracer", "span", "trace",
]

_ROOT_LOGGER = "repro"


def get_logger(name: str) -> logging.Logger:
    """Logger pinned to the ``repro.<pkg>.<mod>`` hierarchy.

    Pass ``__name__``: package modules (``repro.core.scheduler``) map
    through unchanged, out-of-tree callers (``benchmarks.bench_search``,
    ``__main__``) are re-rooted under ``repro.`` so one
    :func:`configure_logging` call governs everything.
    """
    if name == "__main__" or not name:
        name = _ROOT_LOGGER
    elif name != _ROOT_LOGGER and not name.startswith(_ROOT_LOGGER + "."):
        name = f"{_ROOT_LOGGER}.{name}"
    return logging.getLogger(name)


class _JsonFormatter(logging.Formatter):
    """One JSON object per line — machine-tailable CLI logs."""

    def format(self, record: logging.LogRecord) -> str:
        doc = {
            "ts": round(record.created, 6),
            "level": record.levelname.lower(),
            "logger": record.name,
            "msg": record.getMessage(),
        }
        if record.exc_info:
            doc["exc"] = self.formatException(record.exc_info)
        return _json.dumps(doc, sort_keys=True, separators=(",", ":"))


def configure_logging(level: int | str = "info", *, json: bool = False,
                      stream=None) -> logging.Logger:
    """Configure the ``repro`` logger tree for CLI use (idempotent).

    Installs a single stream handler on the ``repro`` root logger —
    plain ``time level logger: msg`` lines, or JSON lines with
    ``json=True`` — replacing any handler a previous call installed.
    Library code never calls this; only ``launch/*`` entry points and
    benchmark mains do.
    """
    if isinstance(level, str):
        level = getattr(logging, level.upper())
    root = logging.getLogger(_ROOT_LOGGER)
    root.setLevel(level)
    for h in list(root.handlers):
        if getattr(h, "_repro_obs", False):
            root.removeHandler(h)
    handler = logging.StreamHandler(stream or sys.stderr)
    handler._repro_obs = True  # type: ignore[attr-defined]
    if json:
        handler.setFormatter(_JsonFormatter())
    else:
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)s %(name)s: %(message)s",
            datefmt="%H:%M:%S"))
    root.addHandler(handler)
    root.propagate = False
    return root

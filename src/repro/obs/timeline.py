"""Plan/simulation timelines as per-accelerator Gantt charts.

Converts the scalar simulator's recorded :class:`~repro.core.simulate.
SimResult` timeline (one :class:`Interval` per constant-slowdown span
of a layer group) into:

* **Chrome-trace/Perfetto JSON** — one track per accelerator, complete
  events per executed interval, contention intervals (slowdown > 1)
  flagged in a dedicated category, and inter-accelerator transitions
  rendered as spans bridging the source and destination groups — the
  paper's Fig. 5 schedule diagram, loadable at ``ui.perfetto.dev``.

* **ASCII** — a terminal Gantt (one row per accelerator, ``#`` busy,
  ``▒`` contended, ``·`` idle) for quick CLI inspection without a
  browser.

Pure functions over frozen dataclasses; no tracer required (the
timeline is derived from a recorded artifact, not observed live).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Sequence

__all__ = [
    "ascii_gantt",
    "plan_ascii",
    "plan_chrome",
    "timeline_events",
    "timeline_chrome",
    "write_chrome",
]

_PID = 1


def _names(result, workload_names: Sequence[str] | None) -> list[str]:
    n = 1 + max((iv.workload for iv in result.timeline), default=0)
    if workload_names is None:
        return [f"wl{i}" for i in range(n)]
    return [str(x) for x in workload_names]


def timeline_events(result, workload_names: Sequence[str] | None = None,
                    ) -> list[dict[str, Any]]:
    """Chrome trace events for a recorded simulation timeline.

    Tracks (tids) are the platform accelerators in first-execution
    order.  Every interval becomes a complete event; contended
    intervals (slowdown > 1) carry ``cat="contention"`` so Perfetto
    can color/filter them.  A transition — consecutive groups of the
    same workload iteration on *different* accelerators with a time
    gap — becomes a bridging span on the destination track.
    """
    names = _names(result, workload_names)
    tids: dict[str, int] = {}

    def tid(acc: str) -> int:
        t = tids.get(acc)
        if t is None:
            t = tids[acc] = len(tids) + 1
        return t

    events: list[dict[str, Any]] = []
    # last executed interval per (workload, iteration) to detect
    # transitions; timeline is start-ordered by construction.
    last: dict[tuple[int, int], Any] = {}
    for iv in result.timeline:
        contended = iv.slowdown > 1.0 + 1e-12
        key = (iv.workload, iv.iteration)
        prev = last.get(key)
        if (prev is not None and prev.group != iv.group
                and prev.acc != iv.acc and iv.start > prev.end + 1e-12):
            events.append({
                "ph": "X", "name": f"{names[iv.workload]} transition "
                                   f"{prev.acc}->{iv.acc}",
                "cat": "transition",
                "ts": round(prev.end * 1e3, 3),
                "dur": round((iv.start - prev.end) * 1e3, 3),
                "pid": _PID, "tid": tid(iv.acc),
                "args": {"workload": names[iv.workload],
                         "from": prev.acc, "to": iv.acc,
                         "group": iv.group},
            })
        last[key] = iv
        events.append({
            "ph": "X",
            "name": f"{names[iv.workload]}[g{iv.group}] it{iv.iteration}",
            "cat": "contention" if contended else "compute",
            "ts": round(iv.start * 1e3, 3),
            "dur": round((iv.end - iv.start) * 1e3, 3),
            "pid": _PID, "tid": tid(iv.acc),
            "args": {"workload": names[iv.workload], "group": iv.group,
                     "iteration": iv.iteration,
                     "slowdown": round(iv.slowdown, 6)},
        })
    meta = [{"ph": "M", "name": "thread_name", "pid": _PID, "tid": t,
             "args": {"name": acc}} for acc, t in tids.items()]
    return meta + events


def timeline_chrome(result, workload_names: Sequence[str] | None = None,
                    ) -> dict[str, Any]:
    """Full Chrome trace-event object for one simulation result."""
    return {
        "traceEvents": timeline_events(result, workload_names),
        "displayTimeUnit": "ms",
        "otherData": {
            "producer": "repro.obs.timeline",
            "clock": "schedule_ms",
            "makespan_ms": round(result.makespan, 6),
            "contention_ms": round(result.contention_ms, 6),
        },
    }


def _plan_result(plan):
    """The plan's simulation result with a recorded timeline.

    Solvers evaluate candidates with ``record_timeline=False`` (interval
    recording would dominate the search), so a :class:`Plan`'s stored
    result usually has an empty timeline — re-run the authoritative
    simulator over the winning assignment when that is the case.
    """
    res = plan.result
    if res.timeline:
        return res
    from ..core.simulate import simulate
    return simulate(plan.request.platform, plan.solution.workloads,
                    plan.request.model, record_timeline=True)


def plan_chrome(plan) -> dict[str, Any]:
    """Gantt trace for a solved :class:`~repro.core.plan.Plan`."""
    names = [wl.graph.name for wl in plan.solution.workloads]
    doc = timeline_chrome(_plan_result(plan), names)
    doc["otherData"].update(
        request_hash=plan.request_hash, solver=plan.solver,
        objective=round(plan.objective, 6))
    return doc


def write_chrome(doc: dict[str, Any], path) -> pathlib.Path:
    """Deterministic Perfetto-JSON write (sorted keys, fixed separators)."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, sort_keys=True, separators=(",", ":"))
                 + "\n")
    return p


def ascii_gantt(result, workload_names: Sequence[str] | None = None,
                width: int = 72) -> str:
    """Terminal Gantt: one row per accelerator over the makespan.

    ``#`` uncontended execution, ``▒`` contended (slowdown > 1),
    ``·`` idle.  A final legend row maps cells back to workloads where
    a single workload owns the whole cell.
    """
    names = _names(result, workload_names)
    span = max(result.makespan, 1e-9)
    accs: list[str] = []
    for iv in result.timeline:
        if iv.acc not in accs:
            accs.append(iv.acc)
    rows = {acc: ["·"] * width for acc in accs}
    for iv in result.timeline:
        lo = int(iv.start / span * width)
        hi = max(lo + 1, int(round(iv.end / span * width)))
        ch = "▒" if iv.slowdown > 1.0 + 1e-12 else "#"
        for c in range(lo, min(hi, width)):
            rows[iv.acc][c] = ch
    label_w = max((len(a) for a in accs), default=0)
    lines = [f"gantt 0..{result.makespan:.2f} ms   "
             f"(# compute  ▒ contended  · idle)"]
    for acc in accs:
        lines.append(f"{acc:>{label_w}} |{''.join(rows[acc])}|")
    lines.append(f"{'':>{label_w}}  workloads: "
                 + ", ".join(f"{i}={n}" for i, n in enumerate(names)))
    return "\n".join(lines)


def plan_ascii(plan, width: int = 72) -> str:
    names = [wl.graph.name for wl in plan.solution.workloads]
    return ascii_gantt(_plan_result(plan), names, width)

"""Counters/gauges/histograms registry with JSON + Prometheus export.

One registry replaces the repo's three hand-rolled ``metrics()`` dict
shapes (serving engine, multiplexing gateway, fleet gateway/admission
controller).  Two layers:

* **Series classes** — :class:`Counter`, :class:`Gauge`,
  :class:`Histogram`, each supporting labeled child series
  (``counter.labels(tenant="chat-3").inc()``), a JSON-able
  :meth:`snapshot`, and Prometheus text exposition.

* **Schemas** — the canonical per-provider metric shapes.  The
  serving stack's ``METRIC_KEYS`` is *derived* from
  :data:`TENANT_SCHEMA` here, so the engine, the multi-tenant
  gateway, the fleet report and the admission controller all conform
  to one schema by construction; the old flat dicts remain as thin
  views built by :func:`conform`.

No third-party dependencies; everything is plain dict/list under a
lock, cheap enough to live in serving paths.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Any, Iterable, Mapping

__all__ = [
    "ADMISSION_SCHEMA",
    "Counter",
    "GATEWAY_SCHEMA",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TENANT_SCHEMA",
    "conform",
    "get_registry",
    "set_registry",
]

# ---------------------------------------------------------------------------
# Canonical metric schemas.
#
# ``TENANT_SCHEMA`` is the single source of truth for the per-tenant
# serving shape: ``serve.engine.METRIC_KEYS`` is ``tuple(TENANT_SCHEMA)``
# and every provider (ServingEngine.metrics, FleetReport.tenant_metrics,
# MultiTenantGateway per-tenant rows) emits through ``conform`` so key
# order and completeness hold by construction.  Values document the
# metric kind + meaning for docs/observability.md.
# ---------------------------------------------------------------------------

TENANT_SCHEMA: dict[str, tuple[str, str]] = {
    "steps": ("counter", "decode steps executed for this tenant"),
    "active": ("gauge", "requests currently decoding"),
    "queue_depth": ("gauge", "requests admitted but not yet started"),
    "admitted": ("counter", "requests admitted past the KV budget"),
    "completed": ("counter", "requests fully decoded"),
    "deferred": ("counter", "admission deferrals (KV budget pressure)"),
    "tokens_out": ("counter", "decode tokens emitted"),
    "last_step_ms": ("gauge", "latency of the most recent decode step"),
    "mean_step_ms": ("gauge", "mean decode-step latency"),
}

GATEWAY_SCHEMA: dict[str, tuple[str, str]] = {
    "steps": ("counter", "gateway scheduling steps executed"),
    "kv_bytes_in_use": ("gauge", "KV-cache bytes currently allocated"),
    "deferred_admissions": ("counter", "admissions deferred at the gate"),
    "reschedules": ("counter", "§4.4 slowdown-triggered re-schedules"),
}

ADMISSION_SCHEMA: dict[str, tuple[str, str]] = {
    "kv_bytes_in_use": ("gauge", "KV bytes held by admitted requests"),
    "budget_bytes": ("gauge", "admission KV budget"),
    "shed": ("counter", "requests shed (rejected) at admission"),
    "deferred": ("counter", "requests deferred (queued) at admission"),
    "throttled": ("counter", "arrivals refused by the duty gate"),
    "duty": ("gauge", "per-tenant duty-cycle fractions in (0, 1]"),
}


def conform(schema: Mapping[str, tuple[str, str]],
            values: Mapping[str, Any], **extra: Any) -> dict[str, Any]:
    """Build a dict in exact schema order from ``values``.

    Missing keys raise ``KeyError`` — a provider that stops emitting a
    canonical metric fails loudly instead of drifting.  ``extra``
    appends provider-specific keys after the canonical block (the
    fleet gateway's ``tenants`` sub-dict, for example).
    """
    out = {k: values[k] for k in schema}
    out.update(extra)
    return out


# ---------------------------------------------------------------------------
# Series
# ---------------------------------------------------------------------------


def _label_key(labels: Mapping[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


def _fmt_labels(labels: Iterable[tuple[str, str]]) -> str:
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}" if inner else ""


def _fmt_value(v: float) -> str:
    if v != v:  # NaN
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class _Series:
    """Shared machinery: name/help, labeled children, thread safety."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._children: dict[tuple[tuple[str, str], ...], Any] = {}

    def labels(self, **labels: str):
        key = _label_key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._new_child()
            return child

    def _iter_children(self):
        with self._lock:
            return list(self._children.items())


class Counter(_Series):
    """Monotonic counter, optionally labeled."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._value = 0.0

    class _Child:
        __slots__ = ("value",)

        def __init__(self) -> None:
            self.value = 0.0

        def inc(self, amount: float = 1.0) -> None:
            self.value += amount

    def _new_child(self) -> "_Child":
        return Counter._Child()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind, "value": self._value}
        series = {_fmt_labels(k): c.value for k, c in self._iter_children()}
        if series:
            out["series"] = series
        return out

    def expose(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} counter"]
        children = self._iter_children()
        if not children:
            lines.append(f"{self.name} {_fmt_value(self._value)}")
        for key, child in children:
            lines.append(f"{self.name}{_fmt_labels(key)} "
                         f"{_fmt_value(child.value)}")
        return lines


class Gauge(_Series):
    """Point-in-time value, optionally labeled."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._value = 0.0

    class _Child:
        __slots__ = ("value",)

        def __init__(self) -> None:
            self.value = 0.0

        def set(self, value: float) -> None:
            self.value = float(value)

        def inc(self, amount: float = 1.0) -> None:
            self.value += amount

        def dec(self, amount: float = 1.0) -> None:
            self.value -= amount

    def _new_child(self) -> "_Child":
        return Gauge._Child()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind, "value": self._value}
        series = {_fmt_labels(k): c.value for k, c in self._iter_children()}
        if series:
            out["series"] = series
        return out

    def expose(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} gauge"]
        children = self._iter_children()
        if not children:
            lines.append(f"{self.name} {_fmt_value(self._value)}")
        for key, child in children:
            lines.append(f"{self.name}{_fmt_labels(key)} "
                         f"{_fmt_value(child.value)}")
        return lines


#: default histogram buckets (milliseconds-flavored; serving latencies).
DEFAULT_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, 2500.0, 5000.0)


class Histogram(_Series):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS) -> None:
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)  # +Inf tail
        self._sum = 0.0
        self._n = 0

    def _new_child(self) -> "Histogram":
        return Histogram(self.name, self.help, self.buckets)

    def observe(self, value: float) -> None:
        with self._lock:
            self._sum += value
            self._n += 1
            for i, edge in enumerate(self.buckets):
                if value <= edge:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Approximate quantile from the cumulative buckets."""
        with self._lock:
            if self._n == 0:
                return 0.0
            target = q * self._n
            seen = 0
            for i, edge in enumerate(self.buckets):
                seen += self._counts[i]
                if seen >= target:
                    return edge
            return self.buckets[-1] if self.buckets else 0.0

    def snapshot(self) -> dict[str, Any]:
        cum, total = [], 0
        for c in self._counts[:-1]:
            total += c
            cum.append(total)
        out: dict[str, Any] = {
            "kind": self.kind, "count": self._n, "sum": self._sum,
            "buckets": {_fmt_value(e): cum[i]
                        for i, e in enumerate(self.buckets)},
        }
        series = {_fmt_labels(k): c.snapshot()
                  for k, c in self._iter_children()}
        if series:
            out["series"] = series
        return out

    def _expose_one(self, labels: tuple[tuple[str, str], ...]) -> list[str]:
        lines = []
        total = 0
        for i, edge in enumerate(self.buckets):
            total += self._counts[i]
            le = labels + (("le", _fmt_value(edge)),)
            lines.append(f"{self.name}_bucket{_fmt_labels(le)} {total}")
        le = labels + (("le", "+Inf"),)
        lines.append(f"{self.name}_bucket{_fmt_labels(le)} {self._n}")
        lines.append(f"{self.name}_sum{_fmt_labels(labels)} "
                     f"{_fmt_value(self._sum)}")
        lines.append(f"{self.name}_count{_fmt_labels(labels)} {self._n}")
        return lines

    def expose(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        children = self._iter_children()
        if not children:
            lines.extend(self._expose_one(()))
        for key, child in children:
            lines.extend(child._expose_one(key))
        return lines


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class MetricsRegistry:
    """Named-series registry; idempotent creation, JSON + Prometheus out."""

    def __init__(self, namespace: str = "repro") -> None:
        self.namespace = namespace
        self._lock = threading.Lock()
        self._series: dict[str, _Series] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        full = f"{self.namespace}_{name}" if self.namespace else name
        with self._lock:
            s = self._series.get(full)
            if s is None:
                s = self._series[full] = cls(full, help, **kwargs)
            elif not isinstance(s, cls):
                raise TypeError(
                    f"metric {full!r} already registered as {s.kind}")
            return s

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def snapshot(self) -> dict[str, Any]:
        """JSON-able snapshot of every registered series."""
        with self._lock:
            series = dict(self._series)
        return {name: s.snapshot() for name, s in sorted(series.items())}

    def to_json(self, *, indent: int | None = None) -> str:
        seps = (",", ": ") if indent is not None else (",", ":")
        return json.dumps(self.snapshot(), sort_keys=True, indent=indent,
                          separators=seps)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one series per family)."""
        with self._lock:
            series = dict(self._series)
        lines: list[str] = []
        for _, s in sorted(series.items()):
            lines.extend(s.expose())
        return "\n".join(lines) + "\n"

    def write(self, path) -> None:
        import pathlib

        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json(indent=2) + "\n")

    def clear(self) -> None:
        with self._lock:
            self._series.clear()


_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _registry


def set_registry(registry: MetricsRegistry | None) -> MetricsRegistry:
    """Install a registry globally (``None`` → fresh default); returns prev."""
    global _registry
    prev = _registry
    _registry = MetricsRegistry() if registry is None else registry
    return prev

"""ProfileBundle: the measured-characterization artifact.

A schedule's quality is bounded by its characterization, so the
characterization deserves the same artifact treatment as the schedule
itself (:class:`~repro.core.plan.Plan`): a :class:`ProfileBundle` packs
the measured platform, the measured per-group graphs, the calibrated
contention model and the raw (own, external) → slowdown samples into one
versioned, **content-hashed** JSON document with provenance (executor,
backend/device, timer config, sample counts, fit residuals).

Loading recomputes the payload hash and refuses a mismatch — a
hand-edited or format-drifted bundle fails loudly instead of silently
mis-costing every schedule solved from it.  ``platform_from_bundle`` /
``scheduler_from_bundle`` close the loop: a
:class:`~repro.core.scheduler.Scheduler` solves directly from measured
profiles, no paper tables involved.

**Lineage.**  Online recalibration (:mod:`repro.profiling.online`)
republishes bundles as the platform drifts; every such bundle carries
``parent_hash`` — the content hash of the bundle its model was warm-started
from — inside the hashed payload, so a live surface is auditable back to
its offline ancestor and the chain itself is tamper-evident
(:meth:`ProfileBundle.derive`, :func:`verify_lineage`).  Payload fields
are frozen after construction: the content hash is cached on first use,
and a mutable payload would let ``save()`` emit a stale hash that
``from_dict`` then rejects as corruption.  Non-identity metadata
(``provenance``, ``created_at``) stays writable.
"""
from __future__ import annotations

import json
import pathlib
import time
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

from ..core import registry
from ..core.accelerators import Platform
from ..core.graph import DNNGraph
from ..core.plan import (canonical_hash, graph_from_dict, graph_to_dict,
                         platform_from_dict, platform_to_dict)
from .harness import Sample

FORMAT = 1


@dataclass
class ProfileBundle:
    """Measured platform + graphs + calibrated model, content-addressed."""

    platform: Platform
    graphs: tuple[DNNGraph, ...]
    #: the calibrated contention model (any registered codec kind).
    model: Any
    #: raw calibration samples, kept for re-fits and residual audits.
    samples: tuple[Sample, ...] = ()
    #: executor/backend/device/timer/residual metadata; not part of the
    #: content hash (it carries timestamps and wall-clock counts).
    provenance: dict = field(default_factory=dict)
    created_at: float = field(default_factory=time.time)
    #: content hash of the bundle this one was recalibrated from (online
    #: re-fit lineage); None for offline root bundles.  Part of the hashed
    #: payload when set, so the lineage chain is itself tamper-evident.
    parent_hash: str | None = None

    #: payload fields sealed after __post_init__ — the content hash is
    #: cached on first use and must never go stale against the payload.
    _PAYLOAD_FIELDS = frozenset(
        {"platform", "graphs", "model", "samples", "parent_hash"})

    def __post_init__(self):
        if not self.graphs:
            raise ValueError("bundle has no measured graphs")
        self.graphs = tuple(self.graphs)
        self.samples = tuple(tuple(float(x) for x in s)
                             for s in self.samples)
        names = set(self.platform.names)
        for g in self.graphs:
            if not names & set(g.accelerators):
                raise ValueError(
                    f"measured graph {g.name!r} covers no accelerator of "
                    f"platform {self.platform.name!r}")
        self.__dict__["_sealed"] = True

    def __setattr__(self, name: str, value) -> None:
        if name in self._PAYLOAD_FIELDS and self.__dict__.get("_sealed"):
            raise AttributeError(
                f"ProfileBundle payload is frozen: {name!r} participates "
                f"in the content hash; build a new bundle (see .derive()) "
                f"instead of mutating this one")
        super().__setattr__(name, value)

    # -- identity ---------------------------------------------------------
    def payload_dict(self) -> dict:
        """The hashed content: everything that affects a solve."""
        d = {
            "format": FORMAT,
            "platform": platform_to_dict(self.platform),
            "graphs": [graph_to_dict(g) for g in self.graphs],
            "model": registry.encode_model(self.model),
            "samples": [list(s) for s in self.samples],
        }
        # omitted when unset so pre-lineage format-1 hashes stay valid.
        if self.parent_hash is not None:
            d["parent_hash"] = self.parent_hash
        return d

    def bundle_hash(self) -> str:
        cached = self.__dict__.get("_hash")
        if cached is None:
            cached = canonical_hash(self.payload_dict())
            self.__dict__["_hash"] = cached
        return cached

    def derive(self, *, model: Any | None = None,
               samples: Sequence | None = None,
               provenance: Mapping[str, Any] | None = None,
               ) -> "ProfileBundle":
        """A child bundle with ``parent_hash`` pointing back at this one.

        The online recalibrator publishes every re-fit through here:
        platform and measured graphs carry over, the model (and usually
        the supporting sample window) are replaced, and the returned
        bundle's hash covers the lineage pointer.
        """
        return ProfileBundle(
            platform=self.platform,
            graphs=self.graphs,
            model=self.model if model is None else model,
            samples=self.samples if samples is None else tuple(samples),
            provenance=dict(provenance if provenance is not None
                            else self.provenance),
            parent_hash=self.bundle_hash(),
        )

    @property
    def graph_names(self) -> tuple[str, ...]:
        return tuple(g.name for g in self.graphs)

    def graph(self, name: str) -> DNNGraph:
        for g in self.graphs:
            if g.name == name:
                return g
        raise KeyError(
            f"no measured graph {name!r}; bundle has: "
            f"{', '.join(self.graph_names)}")

    def summary(self) -> str:
        prov = self.provenance
        rows = [f"profile-bundle {self.bundle_hash()[:12]} "
                f"platform={self.platform.name} "
                f"model={type(self.model).__name__} "
                f"samples={len(self.samples)}"]
        if "fit" in prov:
            f = prov["fit"]
            rows.append(f"  fit: rmse={f.get('rmse', float('nan')):.4f} "
                        f"max_rel={f.get('max_rel_err', float('nan')):.2%}")
        rows.append(f"  executor={prov.get('executor', '?')} "
                    f"backend={prov.get('jax_backend', 'n/a')} "
                    f"device={prov.get('device', 'n/a')}")
        for g in self.graphs:
            accs = ", ".join(f"{a}={g.standalone_time(a):.3f}ms"
                             for a in g.accelerators)
            rows.append(f"    {g.name}: {len(g)} groups ({accs})")
        return "\n".join(rows)

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> dict:
        return {**self.payload_dict(),
                "bundle_hash": self.bundle_hash(),
                "provenance": dict(self.provenance),
                "created_at": self.created_at}

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ProfileBundle":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"unsupported profile-bundle format {d.get('format')!r} "
                f"(this build reads format {FORMAT})")
        bundle = cls(
            platform=platform_from_dict(d["platform"]),
            graphs=tuple(graph_from_dict(g) for g in d["graphs"]),
            model=registry.decode_model(d["model"]),
            samples=tuple(tuple(s) for s in d["samples"]),
            provenance=dict(d.get("provenance", {})),
            created_at=d.get("created_at", 0.0),
            parent_hash=d.get("parent_hash"),
        )
        recomputed = bundle.bundle_hash()
        if recomputed != d["bundle_hash"]:
            raise ValueError(
                "profile bundle is corrupt or was produced by an "
                f"incompatible build: stored hash {d['bundle_hash'][:12]} "
                f"!= recomputed {recomputed[:12]}")
        return bundle

    @classmethod
    def from_json(cls, s: str) -> "ProfileBundle":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ProfileBundle":
        return cls.from_json(pathlib.Path(path).read_text())


def verify_lineage(chain: Sequence[ProfileBundle]) -> None:
    """Validate a root-first recalibration chain.

    Each bundle after the first must carry ``parent_hash`` equal to its
    predecessor's content hash (which :meth:`ProfileBundle.bundle_hash`
    recomputes over the payload, so a tampered ancestor breaks every
    descendant).  Raises ``ValueError`` on the first broken link.
    """
    for i in range(1, len(chain)):
        want = chain[i - 1].bundle_hash()
        got = chain[i].parent_hash
        if got != want:
            raise ValueError(
                f"broken bundle lineage at link {i}: parent_hash "
                f"{(got or 'none')[:12]} != ancestor {want[:12]}")


def platform_from_bundle(bundle: ProfileBundle | str | pathlib.Path
                         ) -> Platform:
    """The measured platform of a bundle (accepts a path for CLI use)."""
    if not isinstance(bundle, ProfileBundle):
        bundle = ProfileBundle.load(bundle)
    return bundle.platform


def scheduler_from_bundle(bundle: ProfileBundle | str | pathlib.Path,
                          **kwargs):
    """A :class:`~repro.core.scheduler.Scheduler` solving from measured
    profiles: the bundle's platform + its calibrated contention model.

    Schedule the bundle's *measured* graphs by passing them (or their
    names resolved via :meth:`ProfileBundle.graph`) to ``solve``::

        sched = scheduler_from_bundle("artifacts/profiles/orin.json")
        plan = sched.solve([b.graph("vgg19"), b.graph("resnet152")])
    """
    from ..core.scheduler import Scheduler

    if not isinstance(bundle, ProfileBundle):
        bundle = ProfileBundle.load(bundle)
    kwargs.setdefault("model", bundle.model)
    return Scheduler(bundle.platform, **kwargs)

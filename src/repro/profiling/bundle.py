"""ProfileBundle: the measured-characterization artifact.

A schedule's quality is bounded by its characterization, so the
characterization deserves the same artifact treatment as the schedule
itself (:class:`~repro.core.plan.Plan`): a :class:`ProfileBundle` packs
the measured platform, the measured per-group graphs, the calibrated
contention model and the raw (own, external) → slowdown samples into one
versioned, **content-hashed** JSON document with provenance (executor,
backend/device, timer config, sample counts, fit residuals).

Loading recomputes the payload hash and refuses a mismatch — a
hand-edited or format-drifted bundle fails loudly instead of silently
mis-costing every schedule solved from it.  ``platform_from_bundle`` /
``scheduler_from_bundle`` close the loop: a
:class:`~repro.core.scheduler.Scheduler` solves directly from measured
profiles, no paper tables involved.
"""
from __future__ import annotations

import json
import pathlib
import time
from dataclasses import dataclass, field
from typing import Any, Mapping

from ..core import registry
from ..core.accelerators import Platform
from ..core.graph import DNNGraph
from ..core.plan import (canonical_hash, graph_from_dict, graph_to_dict,
                         platform_from_dict, platform_to_dict)
from .harness import Sample

FORMAT = 1


@dataclass
class ProfileBundle:
    """Measured platform + graphs + calibrated model, content-addressed."""

    platform: Platform
    graphs: tuple[DNNGraph, ...]
    #: the calibrated contention model (any registered codec kind).
    model: Any
    #: raw calibration samples, kept for re-fits and residual audits.
    samples: tuple[Sample, ...] = ()
    #: executor/backend/device/timer/residual metadata; not part of the
    #: content hash (it carries timestamps and wall-clock counts).
    provenance: dict = field(default_factory=dict)
    created_at: float = field(default_factory=time.time)

    def __post_init__(self):
        if not self.graphs:
            raise ValueError("bundle has no measured graphs")
        self.graphs = tuple(self.graphs)
        self.samples = tuple(tuple(float(x) for x in s)
                             for s in self.samples)
        names = set(self.platform.names)
        for g in self.graphs:
            if not names & set(g.accelerators):
                raise ValueError(
                    f"measured graph {g.name!r} covers no accelerator of "
                    f"platform {self.platform.name!r}")

    # -- identity ---------------------------------------------------------
    def payload_dict(self) -> dict:
        """The hashed content: everything that affects a solve."""
        return {
            "format": FORMAT,
            "platform": platform_to_dict(self.platform),
            "graphs": [graph_to_dict(g) for g in self.graphs],
            "model": registry.encode_model(self.model),
            "samples": [list(s) for s in self.samples],
        }

    def bundle_hash(self) -> str:
        cached = self.__dict__.get("_hash")
        if cached is None:
            cached = canonical_hash(self.payload_dict())
            self.__dict__["_hash"] = cached
        return cached

    @property
    def graph_names(self) -> tuple[str, ...]:
        return tuple(g.name for g in self.graphs)

    def graph(self, name: str) -> DNNGraph:
        for g in self.graphs:
            if g.name == name:
                return g
        raise KeyError(
            f"no measured graph {name!r}; bundle has: "
            f"{', '.join(self.graph_names)}")

    def summary(self) -> str:
        prov = self.provenance
        rows = [f"profile-bundle {self.bundle_hash()[:12]} "
                f"platform={self.platform.name} "
                f"model={type(self.model).__name__} "
                f"samples={len(self.samples)}"]
        if "fit" in prov:
            f = prov["fit"]
            rows.append(f"  fit: rmse={f.get('rmse', float('nan')):.4f} "
                        f"max_rel={f.get('max_rel_err', float('nan')):.2%}")
        rows.append(f"  executor={prov.get('executor', '?')} "
                    f"backend={prov.get('jax_backend', 'n/a')} "
                    f"device={prov.get('device', 'n/a')}")
        for g in self.graphs:
            accs = ", ".join(f"{a}={g.standalone_time(a):.3f}ms"
                             for a in g.accelerators)
            rows.append(f"    {g.name}: {len(g)} groups ({accs})")
        return "\n".join(rows)

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> dict:
        return {**self.payload_dict(),
                "bundle_hash": self.bundle_hash(),
                "provenance": dict(self.provenance),
                "created_at": self.created_at}

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ProfileBundle":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"unsupported profile-bundle format {d.get('format')!r} "
                f"(this build reads format {FORMAT})")
        bundle = cls(
            platform=platform_from_dict(d["platform"]),
            graphs=tuple(graph_from_dict(g) for g in d["graphs"]),
            model=registry.decode_model(d["model"]),
            samples=tuple(tuple(s) for s in d["samples"]),
            provenance=dict(d.get("provenance", {})),
            created_at=d.get("created_at", 0.0),
        )
        recomputed = bundle.bundle_hash()
        if recomputed != d["bundle_hash"]:
            raise ValueError(
                "profile bundle is corrupt or was produced by an "
                f"incompatible build: stored hash {d['bundle_hash'][:12]} "
                f"!= recomputed {recomputed[:12]}")
        return bundle

    @classmethod
    def from_json(cls, s: str) -> "ProfileBundle":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ProfileBundle":
        return cls.from_json(pathlib.Path(path).read_text())


def platform_from_bundle(bundle: ProfileBundle | str | pathlib.Path
                         ) -> Platform:
    """The measured platform of a bundle (accepts a path for CLI use)."""
    if not isinstance(bundle, ProfileBundle):
        bundle = ProfileBundle.load(bundle)
    return bundle.platform


def scheduler_from_bundle(bundle: ProfileBundle | str | pathlib.Path,
                          **kwargs):
    """A :class:`~repro.core.scheduler.Scheduler` solving from measured
    profiles: the bundle's platform + its calibrated contention model.

    Schedule the bundle's *measured* graphs by passing them (or their
    names resolved via :meth:`ProfileBundle.graph`) to ``solve``::

        sched = scheduler_from_bundle("artifacts/profiles/orin.json")
        plan = sched.solve([b.graph("vgg19"), b.graph("resnet152")])
    """
    from ..core.scheduler import Scheduler

    if not isinstance(bundle, ProfileBundle):
        bundle = ProfileBundle.load(bundle)
    kwargs.setdefault("model", bundle.model)
    return Scheduler(bundle.platform, **kwargs)

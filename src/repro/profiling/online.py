"""Closed-loop online recalibration: streaming PCCS re-fit from telemetry.

PR 5's calibration is strictly offline: a :class:`ProfileBundle` is fitted
once, and a drifting platform (thermal throttling, co-runner churn, DVFS
policy changes) leaves every later re-solve pricing contention against a
stale surface.  MoCA-style adaptive execution (PAPERS.md) closes the loop:
the observed ``(own, external) → slowdown`` samples the runtime already
sees — the §4.4 :class:`~repro.core.dynamic.SlowdownMonitor` telemetry the
fleet loop records per completion — stream into an incremental re-fit, and
each re-fit publishes a new *versioned* bundle whose ``parent_hash`` chains
back to the offline ancestor.

* :class:`SampleWindow` — a bounded FIFO of recent telemetry samples
  (non-finite and sub-1 slowdowns are rejected at the door, so one torn
  counter read cannot poison a re-fit the way it used to poison the
  monitor).
* :class:`StreamingRecalibrator` — owns the live model: seeded from an
  offline bundle, it folds samples into the window and, once enough *new*
  evidence accumulated, re-fits.  Piecewise surfaces re-fit through
  :func:`~repro.profiling.calibrate.fit_piecewise`'s warm-start mode —
  knots and initial table come from the previous surface, so each re-fit
  is a cheap Adam polish, not a cold ``lstsq`` — and every publish is a
  :meth:`ProfileBundle.derive` child carrying lineage.

The fleet gateway (:mod:`repro.serve.fleet.loop`) drives this as its
second control axis: re-solve under the re-fitted model first, duty-cycle
the violating tenant (:class:`~repro.serve.fleet.slo.TenantThrottle`) when
re-solving alone cannot meet the SLO.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

from ..core.contention import PiecewiseModel
from ..obs import get_registry, get_tracer
from .bundle import ProfileBundle
from .calibrate import CalibrationResult, fit_piecewise, fit_proportional
from .harness import Sample


class SampleWindow:
    """Bounded FIFO of (own, ext, slowdown) telemetry samples.

    ``observe`` rejects non-finite values and clips slowdowns to >= 1 —
    telemetry is live wall-clock data, and the §4.4 monitor-poisoning bug
    showed what one NaN does to a stateful consumer.  ``new_since_fit``
    counts evidence accumulated since the last :meth:`mark_fitted`, the
    quantity re-fit scheduling keys on.
    """

    def __init__(self, maxlen: int = 512,
                 seed_samples: Sequence[Sample] = ()):
        if maxlen < 8:
            raise ValueError("window maxlen must be >= 8")
        self._q: deque[Sample] = deque(maxlen=maxlen)
        for s in seed_samples:
            self._q.append(tuple(float(x) for x in s))
        self.new_since_fit = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def observe(self, own: float, ext: float, slowdown: float) -> bool:
        """Fold one sample; returns False (and counts) a rejected one."""
        vals = (own, ext, slowdown)
        if not all(math.isfinite(v) for v in vals) or own < 0.0 \
                or ext < 0.0 or slowdown <= 0.0:
            self.rejected += 1
            return False
        self._q.append((float(own), float(ext), max(1.0, float(slowdown))))
        self.new_since_fit += 1
        return True

    def samples(self) -> tuple[Sample, ...]:
        return tuple(self._q)

    def mark_fitted(self) -> None:
        self.new_since_fit = 0


@dataclass
class RecalibrationEvent:
    """One published re-fit (telemetry / benchmark row)."""

    seq: int
    bundle_hash: str
    parent_hash: str
    n_samples: int
    rmse: float
    max_rel_err: float


@dataclass
class StreamingRecalibrator:
    """Incremental PCCS re-fit over a live telemetry window.

    Seeded from an offline :class:`ProfileBundle`; ``observe`` streams
    telemetry in, ``step`` re-fits and publishes once enough new evidence
    accumulated.  The published chain (``.lineage``) is root-first and
    every link is hash-verified by construction: each child is a
    :meth:`ProfileBundle.derive` of the previous head.

    ``fit_kind`` follows the seed bundle's model class by default:
    piecewise surfaces warm-start from the previous table (cheap polish,
    fixed knot geometry); proportional models re-fit their two parameters
    from the window.
    """

    bundle: ProfileBundle
    window: int = 512
    #: below this many window samples a re-fit is never attempted.
    min_samples: int = 24
    #: new samples since the last fit required before re-fitting again.
    min_new: int = 16
    #: Adam polish steps per streaming re-fit.  One scan-jitted polish of
    #: a 5x5 table runs in well under a second on this host; the warm
    #: start is what keeps knot geometry stable, not what shrinks the
    #: budget to nothing.
    refit_steps: int = 800
    lr: float = 0.05
    #: warm-start pull toward the previous table for unobserved knots.
    anchor_weight: float = 1e-4

    lineage: list[ProfileBundle] = field(init=False)
    events: list[RecalibrationEvent] = field(init=False)
    last_report: CalibrationResult | None = field(init=False, default=None)

    def __post_init__(self):
        # the window holds *live* evidence only: seeding it with the
        # offline bundle's samples would let stale pre-drift measurements
        # outvote fresh telemetry for a whole window length.  The offline
        # surface still informs every re-fit through the warm-start
        # anchor, which is the right weighting: it yields wherever the
        # live window actually has evidence.
        self._window = SampleWindow(self.window)
        self.lineage = [self.bundle]
        self.events = []
        if isinstance(self.bundle.model, PiecewiseModel):
            self._kind = "piecewise"
        else:
            self._kind = "proportional"

    # -- streaming ---------------------------------------------------------
    @property
    def model(self):
        """The live contention model (head of the lineage)."""
        return self.bundle.model

    @property
    def refits(self) -> int:
        return len(self.lineage) - 1

    def observe(self, own: float, ext: float, slowdown: float) -> bool:
        return self._window.observe(own, ext, slowdown)

    def ready(self) -> bool:
        return (len(self._window) >= self.min_samples
                and self._window.new_since_fit >= self.min_new)

    # -- re-fit ------------------------------------------------------------
    def refit(self) -> CalibrationResult:
        """Re-fit the live model from the current window (unconditional)."""
        samples = self._window.samples()
        if not samples:
            raise ValueError("no telemetry samples to re-fit from")
        if self._kind == "piecewise":
            result = fit_piecewise(
                samples, warm_start=self.bundle.model,
                steps=self.refit_steps, lr=self.lr,
                anchor_weight=self.anchor_weight)
        else:
            result = fit_proportional(samples, steps=max(self.refit_steps,
                                                         200))
        self.last_report = result
        return result

    def publish(self, result: CalibrationResult) -> ProfileBundle:
        """Derive + adopt a child bundle carrying the re-fitted model."""
        parent = self.bundle
        provenance = dict(parent.provenance)
        provenance["refit"] = {
            "seq": self.refits + 1,
            "kind": self._kind,
            "window": len(self._window),
            "rejected": self._window.rejected,
            **result.report.to_dict(),
        }
        child = parent.derive(model=result.model,
                              samples=self._window.samples(),
                              provenance=provenance)
        self.bundle = child
        self.lineage.append(child)
        self.events.append(RecalibrationEvent(
            seq=self.refits, bundle_hash=child.bundle_hash(),
            parent_hash=parent.bundle_hash(),
            n_samples=result.report.n_samples,
            rmse=result.report.rmse,
            max_rel_err=result.report.max_rel_err))
        self._window.mark_fitted()
        return child

    def step(self) -> ProfileBundle | None:
        """Re-fit + publish if enough new evidence accumulated, else None."""
        if not self.ready():
            return None
        parent_hash = self.bundle.bundle_hash()
        with get_tracer().span("recalibrate.refit", "recalibrate",
                               kind=self._kind, window=len(self._window),
                               parent=parent_hash[:12]) as sp:
            child = self.publish(self.refit())
            ev = self.events[-1]
            sp.set(seq=ev.seq, bundle=ev.bundle_hash[:12],
                   rmse=round(ev.rmse, 6),
                   max_rel_err=round(ev.max_rel_err, 6))
        reg = get_registry()
        reg.counter("recalibrations",
                    "streaming re-fit bundles published").inc()
        reg.gauge("recalibrate_max_rel_err",
                  "worst relative fit error of the latest published "
                  "re-fit").set(ev.max_rel_err)
        return child

    # -- audit -------------------------------------------------------------
    def max_rel_err_against(self, truth) -> float:
        """Worst relative error of the live model vs a reference model,
        evaluated at the window's observed (own, ext) points — the
        convergence number the drift benchmark gates on."""
        worst = 0.0
        for own, ext, _ in self._window.samples():
            want = truth.slowdown(own, ext)
            got = self.model.slowdown(own, ext)
            if want > 0:
                worst = max(worst, abs(got - want) / want)
        return worst

    def summary(self) -> str:
        head = self.bundle
        rows = [f"recalibrator kind={self._kind} window={len(self._window)}"
                f"/{self.window} refits={self.refits} "
                f"rejected={self._window.rejected}",
                f"  head {head.bundle_hash()[:12]} parent "
                f"{(head.parent_hash or 'offline-root')[:12]}"]
        if self.last_report is not None:
            rows.append("  last " + self.last_report.summary())
        return "\n".join(rows)

"""PCCS calibration: fit contention models from co-run slowdown samples.

The harness's :func:`~repro.profiling.harness.corun_sweep` emits
(own, external) → slowdown samples; this module fits the repo's
contention-model classes to them with a JAX least-squares optimizer:

* :func:`fit_piecewise` — PCCS proper.  The knot grid defaults to sample
  quantiles; table values are fitted by Adam on the *hat-basis bilinear*
  prediction (the same contraction the evaluators run,
  :func:`repro.kernels.ref.piecewise_slowdown`), with a monotonicity
  penalty on negative finite differences along both demand axes and a
  floor penalty at 1.  After convergence the table is *exactly* projected
  onto the constraint set (cummax along both axes, clip at 1), so the
  returned :class:`~repro.core.contention.PiecewiseModel` always
  validates — slowdown surfaces are physically monotone: more external
  traffic never speeds you up.
* :func:`fit_proportional` — the analytic 2-parameter model
  (capacity, sensitivity), positivity-constrained through softplus.

Both report residuals (:class:`FitReport`) of the *final, projected*
model against the input samples — the number the acceptance gate and
``BENCH_profile.json`` track.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.contention import (PiecewiseModel, ProportionalShareModel,
                               pccs_from_pairs)
from .harness import Sample


@dataclass(frozen=True)
class FitReport:
    """Residuals of a calibrated model against its training samples."""

    rmse: float
    max_abs_err: float
    #: max |pred - measured| / measured — the acceptance-gate number.
    max_rel_err: float
    n_samples: int
    steps: int
    loss_init: float
    loss_final: float

    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in
                ("rmse", "max_abs_err", "max_rel_err", "n_samples",
                 "steps", "loss_init", "loss_final")}


@dataclass(frozen=True)
class CalibrationResult:
    model: PiecewiseModel | ProportionalShareModel
    report: FitReport

    def summary(self) -> str:
        r = self.report
        return (f"{type(self.model).__name__} fitted on {r.n_samples} "
                f"samples: rmse={r.rmse:.4f} max_rel={r.max_rel_err:.2%} "
                f"({r.steps} steps, loss {r.loss_init:.3g} -> "
                f"{r.loss_final:.3g})")


def _as_arrays(samples: Sequence[Sample]):
    arr = np.asarray(samples, dtype=float)
    if arr.ndim != 2 or arr.shape[1] != 3 or not len(arr):
        raise ValueError(
            "samples must be a non-empty sequence of (own, ext, slowdown)")
    if (arr[:, 2] < 1.0 - 1e-9).any():
        raise ValueError("measured slowdowns must be >= 1")
    return arr[:, 0], arr[:, 1], np.maximum(1.0, arr[:, 2])


def default_knots(values: np.ndarray, n: int = 5) -> tuple[float, ...]:
    """Strictly increasing knot grid from sample quantiles.

    When the sweep used <= ``n`` distinct levels the knots *are* those
    levels (so the fit can interpolate the samples exactly); otherwise
    evenly spaced quantiles.
    """
    uniq = np.unique(np.round(values, 9))
    if len(uniq) <= n:
        knots = uniq
    else:
        knots = np.unique(np.quantile(uniq, np.linspace(0.0, 1.0, n)))
    if len(knots) < 2:   # degenerate sweep: widen to a valid 2-knot grid
        v = float(knots[0]) if len(knots) else 0.5
        knots = np.asarray([v * 0.5, v]) if v > 0 else np.asarray([0.0, 1.0])
    return tuple(float(k) for k in knots)


def _report(pred: np.ndarray, sd: np.ndarray, steps: int,
            loss0: float, loss1: float) -> FitReport:
    err = pred - sd
    return FitReport(
        rmse=float(np.sqrt(np.mean(err ** 2))),
        max_abs_err=float(np.max(np.abs(err))),
        max_rel_err=float(np.max(np.abs(err) / sd)),
        n_samples=int(len(sd)), steps=steps,
        loss_init=float(loss0), loss_final=float(loss1))


def _adam(value_and_grad, params, steps: int, lr: float):
    """Minimal Adam loop (no optax in the container)."""
    import jax
    import jax.numpy as jnp

    b1, b2, eps = 0.9, 0.999, 1e-8

    def step(carry, i):
        p, m, v = carry
        loss, g = value_and_grad(p)
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ ** 2, v, g)
        t = i + 1
        mhat = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vhat = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        p = jax.tree.map(
            lambda p_, mh, vh: p_ - lr * mh / (jnp.sqrt(vh) + eps),
            p, mhat, vhat)
        return (p, m, v), loss

    zeros = jax.tree.map(jnp.zeros_like, params)
    (params, _, _), losses = jax.lax.scan(
        step, (params, zeros, zeros), jnp.arange(steps))
    return params, losses


def fit_piecewise(samples: Sequence[Sample], *,
                  own_knots: Sequence[float] | None = None,
                  ext_knots: Sequence[float] | None = None,
                  n_knots: int = 5, steps: int = 300, lr: float = 0.01,
                  ridge: float = 1e-3,
                  monotonicity_weight: float = 100.0,
                  warm_start: PiecewiseModel | None = None,
                  anchor_weight: float = 1e-3) -> CalibrationResult:
    """Fit a monotone :class:`PiecewiseModel` surface by least squares.

    Given fixed knots the hat-basis prediction is *linear* in the table
    values, so the unconstrained optimum is one ``lstsq`` solve: the
    design matrix row of sample ``n`` is the outer product of its own/ext
    hat weights, Tikhonov-regularized toward the inverse-distance warm
    start so knots without sample support stay anchored instead of going
    to the minimum-norm zero.  Adam then polishes under the monotonicity
    penalty (only active when measurement noise makes the raw optimum
    non-monotone), and the result is exactly projected onto
    {monotone in both axes, >= 1}.

    **Warm-start mode** (``warm_start=<previous PiecewiseModel>``): the
    streaming re-fit path.  Knot grids and the initial table come from the
    previous surface — no design matrix, no ``lstsq`` — and Adam polishes
    from there, with a weak ``anchor_weight`` pull toward the previous
    table so knots the new sample window does not cover hold their
    calibrated values instead of drifting.  Each online re-fit is a cheap
    polish of the live surface, and knot geometry stays fixed across the
    whole recalibration lineage (refit tables stay comparable and plan
    caches keyed on the model keep their locality).
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.ref import _hat_weights, piecewise_slowdown

    own, ext, sd = _as_arrays(samples)
    if warm_start is not None:
        if own_knots is not None or ext_knots is not None:
            raise ValueError(
                "warm_start fixes the knot grids; do not pass "
                "own_knots/ext_knots alongside it")
        ok = np.asarray(warm_start.own_knots, dtype=float)
        ek = np.asarray(warm_start.ext_knots, dtype=float)
    else:
        ok = np.asarray(own_knots if own_knots is not None
                        else default_knots(own, n_knots), dtype=float)
        ek = np.asarray(ext_knots if ext_knots is not None
                        else default_knots(ext, n_knots), dtype=float)
    if (np.diff(ok) <= 0).any() or (np.diff(ek) <= 0).any():
        raise ValueError("knots must be strictly increasing")

    own_j = jnp.asarray(own)
    ext_j = jnp.asarray(ext)
    sd_j = jnp.asarray(sd)
    ok_j = jnp.asarray(ok)
    ek_j = jnp.asarray(ek)

    if warm_start is not None:
        anchor = np.asarray(warm_start.table, dtype=float)
        init = jnp.asarray(anchor)
    else:
        # anchor for unsupported knots: inverse-distance-weighted fill (the
        # pccs_from_pairs fitter the paper-calibrated profiles used).
        anchor = np.asarray(pccs_from_pairs(
            list(zip(own, ext, sd)), own_knots=tuple(ok), ext_knots=tuple(ek)
        ).table, dtype=float)
        # unconstrained optimum: ridge-regularized linear least squares.
        ho = _hat_weights(ok_j, own_j)                    # (N, K)
        he = _hat_weights(ek_j, ext_j)                    # (N, M)
        design = (ho[:, :, None] * he[:, None, :]).reshape(len(own), -1)
        a = jnp.concatenate(
            [design, np.sqrt(ridge) * jnp.eye(design.shape[1])])
        b = jnp.concatenate(
            [sd_j, np.sqrt(ridge) * jnp.asarray(anchor.ravel())])
        init, *_ = jnp.linalg.lstsq(a, b)
        init = init.reshape(len(ok), len(ek))

    anchor_j = jnp.asarray(anchor)

    def loss_fn(table):
        pred = piecewise_slowdown(own_j, ext_j, ok_j, ek_j, table)
        mse = jnp.mean((pred - sd_j) ** 2)
        # physical constraints as penalties; exact projection afterwards.
        neg_own = jnp.minimum(jnp.diff(table, axis=0), 0.0)
        neg_ext = jnp.minimum(jnp.diff(table, axis=1), 0.0)
        floor = jnp.minimum(table - 1.0, 0.0)
        pen = (jnp.sum(neg_own ** 2) + jnp.sum(neg_ext ** 2)
               + jnp.sum(floor ** 2))
        loss = mse + monotonicity_weight * pen
        if warm_start is not None:
            # weak pull toward the previous surface: unobserved knots keep
            # their calibrated values across streaming re-fits.
            loss = loss + anchor_weight * jnp.mean((table - anchor_j) ** 2)
        return loss

    init_np = np.asarray(init)
    already_feasible = warm_start is None and (
        (np.diff(init_np, axis=0) >= 0).all()
        and (np.diff(init_np, axis=1) >= 0).all()
        and (init_np >= 1.0).all())
    if already_feasible or steps <= 0:
        # the lstsq optimum is feasible: polishing could only trade fit
        # quality for nothing, so keep it exactly.
        table, losses = init, jnp.asarray([loss_fn(init)] * 2)
        steps = 0
    else:
        table, losses = _adam(jax.jit(jax.value_and_grad(loss_fn)),
                              init, steps, lr)
    # exact projection onto {monotone in both axes, >= 1}.
    tab = np.maximum(1.0, np.asarray(table))
    tab = np.maximum.accumulate(tab, axis=0)
    tab = np.maximum.accumulate(tab, axis=1)
    model = PiecewiseModel(tuple(ok), tuple(ek),
                           tuple(tuple(float(v) for v in row)
                                 for row in tab))
    pred = np.asarray([model.slowdown(o, e) for o, e in zip(own, ext)])
    return CalibrationResult(model, _report(
        pred, sd, steps, float(losses[0]), float(losses[-1])))


def proportional_predict(own, ext, capacity, sensitivity):
    """Vectorized :meth:`ProportionalShareModel.slowdown` (jnp arrays).

    The differentiable form the proportional fitter optimizes.  Must stay
    numerically identical to the scalar model on every input (including
    the own=0 and total==capacity boundaries) — the differential test in
    ``tests/test_profiling.py`` pins the two against each other, so a
    drift in either formula fails loudly instead of silently mis-fitting
    every proportional re-fit.
    """
    import jax.numpy as jnp

    total = own + ext
    bound = jnp.minimum(1.0, own / capacity)
    s = 1.0 + sensitivity * bound * (total / capacity - 1.0)
    return jnp.where(total <= capacity, 1.0, jnp.maximum(1.0, s))


def fit_proportional(samples: Sequence[Sample], *, steps: int = 400,
                     lr: float = 0.05) -> CalibrationResult:
    """Fit :class:`ProportionalShareModel`'s (capacity, sensitivity)."""
    import jax
    import jax.numpy as jnp

    own, ext, sd = _as_arrays(samples)
    own_j, ext_j, sd_j = jnp.asarray(own), jnp.asarray(ext), jnp.asarray(sd)

    def predict(cap, sens):
        return proportional_predict(own_j, ext_j, cap, sens)

    def loss_fn(p):
        cap = jax.nn.softplus(p[0])
        sens = jax.nn.softplus(p[1])
        return jnp.mean((predict(cap, sens) - sd_j) ** 2)

    # softplus^-1 of (1.0, 1.5): a neutral proportional-share start.
    p0 = jnp.asarray([0.5413, 1.2412])
    p, losses = _adam(jax.jit(jax.value_and_grad(loss_fn)), p0, steps, lr)
    cap = float(jax.nn.softplus(p[0]))
    sens = float(jax.nn.softplus(p[1]))
    model = ProportionalShareModel(capacity=cap, sensitivity=sens)
    pred = np.asarray([model.slowdown(o, e) for o, e in zip(own, ext)])
    return CalibrationResult(model, _report(
        pred, sd, steps, float(losses[0]), float(losses[-1])))


def fit(samples: Sequence[Sample], kind: str = "piecewise",
        **kwargs) -> CalibrationResult:
    """Dispatch by model kind (the CLI's ``--fit`` knob)."""
    if kind == "piecewise":
        return fit_piecewise(samples, **kwargs)
    if kind == "proportional":
        return fit_proportional(samples, **kwargs)
    raise ValueError(
        f"unknown fit kind {kind!r}; one of: piecewise, proportional")

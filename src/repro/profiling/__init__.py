"""Measured characterization & PCCS calibration (§4.1–4.2 as a pipeline).

Closes the characterize → calibrate → schedule loop the paper treats as
offline one-time work:

* :mod:`~repro.profiling.harness` — timed-execution harness (warmup /
  repetition / ``block_until_ready`` / MAD outlier rejection) over kernel
  workloads built from the repo's model configs, or over any executor
  exposing ``run_group``/``read_demand``.
* :mod:`~repro.profiling.probes` — controllable memory-traffic antagonist
  (streaming Pallas/XLA kernel, duty-cycled demand levels).
* :mod:`~repro.profiling.calibrate` — JAX least-squares fits of
  :class:`~repro.core.contention.PiecewiseModel` (monotone PCCS surface)
  and :class:`~repro.core.contention.ProportionalShareModel` from co-run
  samples, with residual reports.
* :mod:`~repro.profiling.bundle` — the content-hashed
  :class:`ProfileBundle` artifact + ``scheduler_from_bundle``.
* :mod:`~repro.profiling.virtual` — the deterministic virtual SoC that
  makes the whole loop runnable and differentially testable in CI.

One-call form (the CLI ``repro.launch.profile`` and the example use it)::

    from repro.core.accelerators import xavier_agx
    from repro.core.profiles import get_graph
    from repro import profiling

    plat = xavier_agx()
    vsoc = profiling.VirtualSoC(
        plat, [get_graph(d, plat) for d in ("vgg19", "resnet152")])
    bundle = profiling.run_pipeline(vsoc)
    sched = profiling.scheduler_from_bundle(bundle)
    plan = sched.solve(list(bundle.graphs))
"""
from __future__ import annotations

from typing import Sequence

from .bundle import (FORMAT, ProfileBundle, platform_from_bundle,
                     scheduler_from_bundle, verify_lineage)
from .calibrate import (CalibrationResult, FitReport, fit, fit_piecewise,
                        fit_proportional, proportional_predict)
from .online import (RecalibrationEvent, SampleWindow,
                     StreamingRecalibrator)
from .harness import (Executor, MeasuredGroup, Measurement, Sample,
                      TimerConfig, corun_sweep, graph_from_measurements,
                      measure_arch, measure_samples, measure_wallclock,
                      profile_graphs, reject_outliers)
from .probes import MemoryProbe, measure_peak_bandwidth, stream_once
from .virtual import VirtualSoC, paper_like_pccs

__all__ = [
    "FORMAT", "ProfileBundle", "platform_from_bundle",
    "scheduler_from_bundle", "verify_lineage",
    "CalibrationResult", "FitReport", "fit", "fit_piecewise",
    "fit_proportional", "proportional_predict",
    "RecalibrationEvent", "SampleWindow", "StreamingRecalibrator",
    "Executor", "MeasuredGroup", "Measurement", "Sample", "TimerConfig",
    "corun_sweep", "graph_from_measurements", "measure_arch",
    "measure_samples", "measure_wallclock", "profile_graphs",
    "reject_outliers",
    "MemoryProbe", "measure_peak_bandwidth", "stream_once",
    "VirtualSoC", "paper_like_pccs",
    "run_pipeline",
]


def run_pipeline(executor: Executor, *,
                 timer: TimerConfig = TimerConfig(),
                 ext_levels: Sequence[float] = (0.15, 0.3, 0.45, 0.6,
                                                0.75, 0.9, 1.05),
                 fit_kind: str = "piecewise",
                 **fit_kwargs) -> ProfileBundle:
    """profile → calibrate → bundle, in one call.

    Measures standalone profiles of every graph on ``executor``, co-runs
    them against the antagonist demand sweep, fits a contention model of
    ``fit_kind`` to the samples and packs everything (with provenance and
    residuals) into a :class:`ProfileBundle`.
    """
    measured = profile_graphs(executor, timer=timer)
    samples = corun_sweep(executor, measured, ext_levels=ext_levels,
                          timer=timer)
    result = fit(samples, fit_kind, **fit_kwargs)
    provenance = {
        "timer": timer.to_dict(),
        "ext_levels": [float(x) for x in ext_levels],
        "fit_kind": fit_kind,
        "fit": result.report.to_dict(),
    }
    if hasattr(executor, "describe"):
        provenance.update(executor.describe())
    return ProfileBundle(
        platform=executor.platform,
        graphs=measured,
        model=result.model,
        samples=tuple(samples),
        provenance=provenance,
    )

"""Timed-execution harness: measured characterization (§3.2, step 1).

Two measurement surfaces share one timing discipline (warmup, repetition,
``jax.block_until_ready``, MAD outlier rejection):

* **kernel workloads** — layer groups assembled from the repo's own model
  configs and kernels (:mod:`repro.kernels.ops` attention / decode
  attention / RG-LRU scan / RWKV-6 + the FFN matmuls), timed on whatever
  JAX backend is present (:func:`measure_arch`).  Group FLOPs/bytes come
  from the same analytic cost model :mod:`repro.models.graph_export` uses,
  so a measurement is a :class:`~repro.core.characterize.GroupCosts` plus
  a wall-time :class:`Measurement` instead of a roofline estimate.
* **executor targets** — anything implementing ``run_group``/
  ``read_demand`` per (graph, group, accelerator), i.e. the deterministic
  :class:`~repro.profiling.virtual.VirtualSoC` in CI and, on a real SoC, a
  device-runner shim (:func:`profile_graphs`, :func:`corun_sweep`).

``profile_graphs`` emits *measured* :class:`~repro.core.graph.DNNGraph`
profiles (median standalone times + mean demand counter readouts);
``corun_sweep`` co-runs every (group, accelerator) against a swept
antagonist demand and emits the (own, external) → slowdown samples PCCS
calibration consumes (:mod:`repro.profiling.calibrate`).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Protocol, Sequence

from ..core.accelerators import MS, Platform
from ..core.characterize import GroupCosts, roofline_time_ms
from ..core.graph import DNNGraph, LayerGroup

#: one (own demand, external demand, measured slowdown) calibration sample.
Sample = tuple[float, float, float]


# ---------------------------------------------------------------------------
# timing discipline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TimerConfig:
    """Repetition/outlier policy applied to every measurement."""

    #: discarded leading calls (jit compilation, cache warmup).
    warmup: int = 2
    #: timed calls per measurement.
    repeats: int = 7
    #: modified-z-score (MAD) threshold beyond which a sample is rejected.
    outlier_z: float = 3.5
    #: never reject below this many kept samples.
    min_kept: int = 3

    def __post_init__(self):
        if self.repeats < 1 or self.warmup < 0:
            raise ValueError("repeats must be >= 1 and warmup >= 0")
        if self.min_kept < 1:
            raise ValueError("min_kept must be >= 1")

    def to_dict(self) -> dict:
        return {"warmup": self.warmup, "repeats": self.repeats,
                "outlier_z": self.outlier_z, "min_kept": self.min_kept}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TimerConfig":
        return cls(**dict(d))


@dataclass(frozen=True)
class Measurement:
    """One repeated, outlier-rejected timing of a single quantity."""

    name: str
    kept_ms: tuple[float, ...]
    rejected_ms: tuple[float, ...] = ()

    @property
    def median_ms(self) -> float:
        return statistics.median(self.kept_ms)

    @property
    def mean_ms(self) -> float:
        return statistics.fmean(self.kept_ms)

    @property
    def std_ms(self) -> float:
        return statistics.pstdev(self.kept_ms) if len(self.kept_ms) > 1 \
            else 0.0

    @property
    def n_total(self) -> int:
        return len(self.kept_ms) + len(self.rejected_ms)


def reject_outliers(times_ms: Sequence[float], *, outlier_z: float = 3.5,
                    min_kept: int = 3) -> tuple[list[float], list[float]]:
    """Split samples into (kept, rejected) by modified z-score.

    The modified z-score ``0.6745 * (x - median) / MAD`` is robust to the
    very outliers it screens (preemptions, frequency ramps); when the MAD
    degenerates to 0 every sample is kept.  At most ``len - min_kept``
    samples are rejected, dropping the most extreme first.
    """
    times = [float(t) for t in times_ms]
    med = statistics.median(times)
    mad = statistics.median(abs(t - med) for t in times)
    if mad <= 0.0 or len(times) <= min_kept:
        return times, []
    scored = sorted(((abs(0.6745 * (t - med) / mad), i)
                     for i, t in enumerate(times)), reverse=True)
    reject_idx: set[int] = set()
    for z, i in scored:
        if z <= outlier_z or len(times) - len(reject_idx) <= min_kept:
            break
        reject_idx.add(i)
    kept = [t for i, t in enumerate(times) if i not in reject_idx]
    rejected = [t for i, t in enumerate(times) if i in reject_idx]
    return kept, rejected


def measurement_from_times(name: str, times_ms: Sequence[float],
                           timer: TimerConfig) -> Measurement:
    kept, rejected = reject_outliers(times_ms, outlier_z=timer.outlier_z,
                                     min_kept=timer.min_kept)
    return Measurement(name, tuple(kept), tuple(rejected))


def measure_samples(sample_fn: Callable[[], float], *,
                    timer: TimerConfig = TimerConfig(),
                    name: str = "") -> Measurement:
    """Measure a source that *returns* per-run milliseconds (an executor)."""
    for _ in range(timer.warmup):
        sample_fn()
    return measurement_from_times(
        name, [sample_fn() for _ in range(timer.repeats)], timer)


def measure_wallclock(fn: Callable[[], Any], *,
                      timer: TimerConfig = TimerConfig(),
                      name: str = "") -> Measurement:
    """Wall-clock timing of ``fn`` with async-dispatch discipline.

    Every call's result is passed through ``jax.block_until_ready`` before
    the clock stops, so asynchronously dispatched device work is charged
    to the call that launched it; warmup calls absorb jit compilation.
    """
    import jax

    for _ in range(timer.warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(timer.repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e3)  # s -> ms
    return measurement_from_times(name, times, timer)


# ---------------------------------------------------------------------------
# executor profiling: measured graphs + co-run slowdown samples
# ---------------------------------------------------------------------------

class Executor(Protocol):
    """A measurable target: the virtual SoC, or a real-device shim."""

    platform: Platform

    def graph_names(self) -> tuple[str, ...]: ...
    def group_count(self, name: str) -> int: ...
    def accelerators_of(self, name: str, gi: int) -> tuple[str, ...]: ...
    def run_group(self, name: str, gi: int, acc: str,
                  external: float = 0.0) -> float: ...
    def read_demand(self, name: str, gi: int, acc: str) -> float: ...
    def out_bytes(self, name: str, gi: int) -> float: ...


def profile_graphs(ex: Executor, *, timer: TimerConfig = TimerConfig(),
                   demand_reads: int = 5) -> tuple[DNNGraph, ...]:
    """Measured standalone characterization of every graph on ``ex``.

    Per (group, accelerator): ``timer.repeats`` standalone executions →
    outlier-rejected median time; ``demand_reads`` counter readouts →
    mean requested throughput.  Returns schedulable measured graphs.
    """
    graphs = []
    for name in ex.graph_names():
        groups = []
        for gi in range(ex.group_count(name)):
            times: dict[str, float] = {}
            demand: dict[str, float] = {}
            for acc in ex.accelerators_of(name, gi):
                m = measure_samples(
                    lambda a=acc: ex.run_group(name, gi, a),
                    timer=timer, name=f"{name}[{gi}]@{acc}")
                times[acc] = m.median_ms
                demand[acc] = statistics.fmean(
                    ex.read_demand(name, gi, acc)
                    for _ in range(max(1, demand_reads)))
            groups.append(LayerGroup(
                name=f"{name}-g{gi}", times=times, mem_demand=demand,
                out_bytes=ex.out_bytes(name, gi)))
        graphs.append(DNNGraph(name, tuple(groups)))
    return tuple(graphs)


def corun_sweep(ex: Executor, measured: Sequence[DNNGraph], *,
                ext_levels: Sequence[float] = (0.15, 0.3, 0.45, 0.6,
                                               0.75, 0.9, 1.05),
                timer: TimerConfig = TimerConfig(),
                ) -> list[Sample]:
    """Co-run every (group, accelerator) against the antagonist sweep.

    The antagonist (:mod:`repro.profiling.probes` on hardware; the
    ``external=`` knob of the virtual SoC) requests each level of the
    contention-domain capacity while the target group runs standalone-
    style repetitions; each pair yields one (own, external, slowdown)
    sample where slowdown = co-run median / measured standalone median.
    """
    by_name = {g.name: g for g in measured}
    samples: list[Sample] = []
    for name in ex.graph_names():
        mg = by_name[name]
        for gi in range(ex.group_count(name)):
            for acc in ex.accelerators_of(name, gi):
                own = mg.groups[gi].demand_on(acc)
                base = mg.groups[gi].time_on(acc)
                if own <= 0.0 or base <= 0.0:
                    continue
                for ext in ext_levels:
                    m = measure_samples(
                        lambda a=acc, e=ext: ex.run_group(name, gi, a, e),
                        timer=timer, name=f"{name}[{gi}]@{acc} ext={ext}")
                    samples.append((own, float(ext),
                                    max(1.0, m.median_ms / base)))
    return samples


# ---------------------------------------------------------------------------
# kernel workloads: measured GroupCosts from the repo's model substrate
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeasuredGroup:
    """One layer group's analytic costs plus its measured wall time."""

    costs: GroupCosts
    measurement: Measurement

    @property
    def ms(self) -> float:
        return self.measurement.median_ms


def _group_runner(cfg, span: Sequence[str], cell, backend: str):
    """A jit-able closure executing one group's layer kinds once."""
    import jax
    import jax.numpy as jnp

    from ..kernels import ops

    B = cell.global_batch
    S = 1 if cell.kind == "decode" else cell.seq_len
    kv_len = cell.seq_len
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    d, ff = cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    kinds_present = set(span)
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (d, ff), jnp.float32) * 0.02
    w2 = jax.random.normal(ks[2], (ff, d), jnp.float32) * 0.02
    # operand families are only materialized for layer kinds the span
    # actually contains — KV caches in particular scale with seq_len.
    if kinds_present & {"attn", "local"}:
        q = jax.random.normal(ks[3], (B, S, hq, dh), jnp.float32)
        kcache = jax.random.normal(ks[4], (B, kv_len, hkv, dh), jnp.float32)
        vcache = jax.random.normal(ks[5], (B, kv_len, hkv, dh), jnp.float32)
        lengths = jnp.full((B,), kv_len, jnp.int32)
    if "rglru" in kinds_present:
        a_gate = jax.nn.sigmoid(jax.random.normal(ks[6], (B, S, cfg.d_rnn)))
        b_in = jax.random.normal(ks[7], (B, S, cfg.d_rnn), jnp.float32)
    if "rwkv" in kinds_present:
        h_rwkv = cfg.n_heads or d // 64
        dh_rwkv = d // h_rwkv
        r = jax.random.normal(ks[3], (B, S, h_rwkv, dh_rwkv), jnp.float32)
        w_dec = jax.nn.sigmoid(jax.random.normal(
            ks[4], (B, S, h_rwkv, dh_rwkv)) + 2.0)
        u = jax.random.normal(ks[5], (h_rwkv, dh_rwkv), jnp.float32) * 0.3

    def run_kind(kind, h):
        if kind in ("attn", "local"):
            win = cfg.local_window if kind == "local" else None
            if cell.kind == "decode":
                o = ops.decode_attention(q, kcache, vcache, lengths,
                                         backend=backend)
            else:
                o = ops.attention(q, kcache[:, :S], vcache[:, :S],
                                  causal=True, window=win, backend=backend)
            h = h + o.reshape(B, S, -1).sum(-1, keepdims=True)
        elif kind == "rglru":
            hs, _ = ops.linear_scan(a_gate, b_in, backend=backend)
            h = h + hs.sum(-1, keepdims=True)
        elif kind == "rwkv":
            y, _ = ops.rwkv6(r, r * 0.3, r, w_dec, u, backend=backend)
            h = h + y.reshape(B, S, -1).sum(-1, keepdims=True)
        else:
            raise ValueError(f"unknown layer kind {kind!r}")
        # the FFN matmuls every block carries (rwkv folds its channel mix
        # into the same two-matmul shape in this cost model).
        return h + jnp.maximum(x @ w1, 0.0) @ w2

    def run_once():
        h = jnp.zeros((B, S, 1), jnp.float32)
        for kind in span:
            h = run_kind(kind, h)
        return h

    # one executable per group: warmup absorbs the compile, repeats time
    # steady-state device work only.
    return jax.jit(run_once)


def measure_arch(cfg, cell, *, backend: str = "auto",
                 timer: TimerConfig = TimerConfig(),
                 layers_per_group: int | None = None,
                 max_groups: int | None = None) -> list[MeasuredGroup]:
    """Measure a config's layer groups on the local JAX backend.

    Groups follow the same span structure as
    :func:`repro.models.graph_export.export_graph`; each group's kernels
    (attention / recurrence via :mod:`repro.kernels.ops` + the FFN
    matmuls) run under the harness timing discipline.  FLOPs/bytes reuse
    the analytic cost model, so the result pairs *measured* time with the
    same :class:`GroupCosts` the roofline path estimates from.
    """
    from ..models.graph_export import _layer_bytes, _layer_flops

    decode = cell.kind == "decode"
    tokens = cell.global_batch * (1 if decode else cell.seq_len)
    kinds = cfg.layer_kinds
    P = len(cfg.block_pattern)
    if layers_per_group is None:
        layers_per_group = max(P, (cfg.n_layers + 7) // 8 // P * P or P)
    out: list[MeasuredGroup] = []
    i = 0
    while i < len(kinds):
        if max_groups is not None and len(out) >= max_groups:
            break
        span = kinds[i:i + layers_per_group]
        fl = sum(_layer_flops(cfg, k, tokens, cell.seq_len) for k in span)
        by = sum(_layer_bytes(cfg, k, tokens, cell.seq_len, decode)
                 for k in span)
        costs = GroupCosts(
            name=f"L{i}-{i + len(span) - 1}", flops=fl, hbm_bytes=by,
            shared_bytes=by,
            out_bytes=tokens * cfg.d_model * 2)
        m = measure_wallclock(
            _group_runner(cfg, span, cell, backend),
            timer=timer, name=f"{cfg.name}:{costs.name}")
        out.append(MeasuredGroup(costs, m))
        i += len(span)
    return out


def graph_from_measurements(name: str, platform: Platform,
                            measured: Sequence[MeasuredGroup],
                            anchor: str | None = None,
                            domain: str | None = None) -> DNNGraph:
    """Schedulable graph from measured groups, anchored on one accelerator.

    The measured wall time pins the ``anchor`` accelerator column (default
    the platform's first); other accelerators are scaled by the ratio of
    their analytic roofline times — the same constrained-synthesis
    approach :mod:`repro.core.profiles` uses where the paper publishes
    totals but not per-group columns.  Demand is the achieved shared-path
    byte rate over the domain capacity (clipped like ``characterize``).
    """
    anchor = anchor or platform.names[0]
    if domain is None and platform.domains:
        domain = next(iter(platform.domains))
    dom_bw = platform.domain_bw.get(domain) if domain else None
    dom_members = platform.domains.get(domain, ()) if domain else ()
    groups = []
    for mg in measured:
        t_anchor_analytic = roofline_time_ms(
            mg.costs, platform.acc(anchor), domain_bw=dom_bw)
        times: dict[str, float] = {}
        demand: dict[str, float] = {}
        for acc in platform.accelerators:
            ratio = (roofline_time_ms(mg.costs, acc, domain_bw=dom_bw)
                     / t_anchor_analytic) if t_anchor_analytic > 0 else 1.0
            t_ms = mg.ms if acc.name == anchor else mg.ms * ratio
            times[acc.name] = t_ms
            if dom_bw and acc.name in dom_members and t_ms > 0:
                shared = (mg.costs.shared_bytes
                          if mg.costs.shared_bytes is not None
                          else mg.costs.hbm_bytes)
                demand[acc.name] = min(1.5, (shared / (t_ms * MS)) / dom_bw)
        groups.append(LayerGroup(
            name=mg.costs.name, times=times, mem_demand=demand,
            out_bytes=mg.costs.out_bytes,
            can_transition_after=mg.costs.can_transition_after,
            flops=mg.costs.flops, hbm_bytes=mg.costs.hbm_bytes))
    return DNNGraph(name, tuple(groups))


def local_device_provenance() -> dict:
    """Backend/device identity recorded in measured bundles."""
    import jax

    dev = jax.devices()[0]
    return {"jax_backend": jax.default_backend(),
            "device": f"{dev.platform}:{getattr(dev, 'device_kind', '?')}",
            "n_devices": jax.device_count()}

"""Controllable memory-traffic antagonist (§4.2's co-run counterpart).

PCCS calibration needs (own, external) → slowdown samples, which means
co-running the target layer group against an antagonist that requests a
*known, controllable* share of the contention domain's bandwidth.  This
module is that antagonist:

* :func:`stream_once` — one streaming pass over a buffer (reads 2
  operands, writes 1: a saxpy), dispatched across the repo-wide backend
  idiom (:mod:`repro.kernels.ops`): a Pallas kernel on TPU
  (``pallas``/``pallas_interpret``) or the identical jnp expression under
  jit elsewhere (``xla``); ``auto`` picks by ``jax.default_backend()``.
* :func:`measure_peak_bandwidth` — calibrate the probe itself: achieved
  bytes/s of back-to-back full-duty streaming, which anchors duty-cycled
  demand levels to fractions of *measured* capacity.
* :class:`MemoryProbe` — a background thread issuing streaming passes at
  a duty cycle: ``demand=0.6`` streams 60% of each period and idles 40%,
  so its requested throughput is ~0.6× the full-duty rate.  Used to sweep
  external demand against real kernel targets on hardware; the virtual
  SoC takes the demand level directly (its ``external=`` knob) so CI
  never depends on wall-clock co-scheduling.
"""
from __future__ import annotations

import functools
import threading
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .harness import TimerConfig, measure_wallclock

#: streaming traffic per pass: x (read) + y (read) + out (write).
_BYTES_PER_ELEM = 3 * 4          # float32


def _stream_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * jnp.float32(1.0000001) + y_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _pallas_stream(x, y, *, block: int, interpret: bool):
    n = x.shape[0]
    nb = pl.cdiv(n, block)
    pad = nb * block - n
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    out = pl.pallas_call(
        _stream_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                  pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        interpret=interpret,
    )(x.reshape(nb, block), y.reshape(nb, block))
    return out.reshape(nb * block)[:n]


@jax.jit
def _xla_stream(x, y):
    return x * jnp.float32(1.0000001) + y


def _auto() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def stream_once(x, y, *, backend: str = "auto", block: int = 4096):
    """One antagonist pass: reads ``x``/``y`` fully, writes their saxpy."""
    b = _auto() if backend == "auto" else backend
    if b == "xla":
        return _xla_stream(x, y)
    if b in ("pallas", "pallas_interpret"):
        return _pallas_stream(x, y, block=min(block, x.shape[0]),
                              interpret=(b == "pallas_interpret"))
    raise ValueError(f"unknown backend {b!r}")


def make_buffers(mbytes: float = 32.0):
    """Streaming operand pair sized so one pass moves ~``mbytes`` MB."""
    n = max(1024, int(mbytes * 1e6 / _BYTES_PER_ELEM))
    x = jnp.arange(n, dtype=jnp.float32) * jnp.float32(1e-6)
    return x, x + jnp.float32(1.0)


def stream_bytes(x) -> float:
    """Traffic one :func:`stream_once` pass over ``x`` moves (bytes)."""
    return float(x.size * _BYTES_PER_ELEM)


def measure_peak_bandwidth(*, mbytes: float = 32.0, backend: str = "auto",
                           timer: TimerConfig = TimerConfig(warmup=2,
                                                            repeats=5),
                           ) -> float:
    """Achieved bytes/s of full-duty streaming — the probe's own peak.

    Demand fractions handed to :class:`MemoryProbe` (and recorded in
    calibration samples) are relative to this measured rate, the same way
    the paper's "requested memory throughput (%)" is relative to measured
    EMC saturation, not the datasheet number.
    """
    x, y = make_buffers(mbytes)
    m = measure_wallclock(lambda: stream_once(x, y, backend=backend),
                          timer=timer, name=f"stream-{mbytes}MB")
    return stream_bytes(x) / (m.median_ms * 1e-3)


class MemoryProbe:
    """Duty-cycled background antagonist thread.

    ``demand`` in (0, 1] is the fraction of each ``period_ms`` window spent
    streaming; the rest idles, so requested throughput scales linearly
    with ``demand`` while the *burst* rate stays at the device's streaming
    peak — the same shape PCCS's microbenchmark antagonists have.
    """

    def __init__(self, demand: float = 1.0, *, mbytes: float = 8.0,
                 backend: str = "auto", period_ms: float = 5.0):
        if not 0.0 < demand <= 1.0:
            raise ValueError("demand must be in (0, 1]")
        self.demand = float(demand)
        self.backend = backend
        self.period_s = period_ms * 1e-3
        self._x, self._y = make_buffers(mbytes)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        #: streaming passes issued (for achieved-rate accounting).
        self.passes = 0

    def _loop(self):
        burst_s = self.period_s * self.demand
        while not self._stop.is_set():
            t0 = time.perf_counter()
            while time.perf_counter() - t0 < burst_s:
                jax.block_until_ready(
                    stream_once(self._x, self._y, backend=self.backend))
                self.passes += 1
                if self._stop.is_set():
                    return
            idle = self.period_s - (time.perf_counter() - t0)
            if idle > 0:
                self._stop.wait(idle)

    def __enter__(self) -> "MemoryProbe":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("probe already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._thread = None

    def bytes_per_pass(self) -> float:
        return stream_bytes(self._x)

"""Deterministic virtual SoC: a synthetic measurement target for CI.

The characterization→calibration pipeline (§4.1–4.2) needs something to
*measure*.  Real hardware measures itself; this container has one CPU
device.  The :class:`VirtualSoC` stands in: it "executes" layer groups of
ground-truth :class:`~repro.core.graph.DNNGraph` profiles on a
:class:`~repro.core.accelerators.Platform`, returning per-run wall times
synthesized from the group's standalone time, a *generating* contention
model (any :class:`~repro.core.contention.ContentionModel`) applied to the
co-running antagonist demand, and seeded measurement noise with occasional
preemption-style outliers.

Because the generator is the repo's own contention machinery, the whole
pipeline is differentially testable without hardware: calibrate a
:class:`~repro.core.contention.PiecewiseModel` from virtual co-run
measurements, then assert the fitted model reproduces the generating
model's slowdowns and that a schedule solved from the measured bundle
matches the plan solved from ground truth.

Determinism: one :class:`numpy.random.Generator` seeded at construction;
the same call sequence yields the same measurements bit-for-bit.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from ..core.accelerators import Platform
from ..core.contention import ContentionModel, PiecewiseModel
from ..core.graph import DNNGraph


def paper_like_pccs() -> PiecewiseModel:
    """A Fig.-6-shaped ground-truth PCCS surface (up to ~2.6x slowdown).

    Used as the default generating model of the virtual SoC: monotone in
    both demands, mild below half capacity, steep once combined demand
    oversubscribes the domain — the co-run slowdown magnitudes the paper
    reports (§5.2, up to ~70% performance loss).
    """
    return PiecewiseModel(
        own_knots=(0.1, 0.3, 0.5, 0.7, 0.9),
        ext_knots=(0.1, 0.3, 0.5, 0.7, 0.9),
        table=(
            (1.00, 1.02, 1.06, 1.12, 1.20),
            (1.02, 1.08, 1.18, 1.32, 1.50),
            (1.05, 1.15, 1.32, 1.55, 1.82),
            (1.08, 1.24, 1.48, 1.80, 2.18),
            (1.12, 1.34, 1.64, 2.05, 2.60),
        ))


class VirtualSoC:
    """Synthetic timed-execution target driven by a generating model.

    Implements the executor interface the harness profiles against:
    ``run_group`` (one timed execution under a given external antagonist
    demand), ``read_demand`` (the §3.2 requested-throughput counter
    readout) and ``out_bytes`` — all per (graph, group index, accelerator).

    ``noise`` is the relative σ of multiplicative Gaussian timing noise;
    ``outlier_rate`` injects occasional ``outlier_scale``× preemption
    spikes so the harness's outlier rejection has real work to do.
    """

    def __init__(self, platform: Platform,
                 graphs: Sequence[DNNGraph],
                 model: ContentionModel | Mapping[str, ContentionModel]
                 | None = None, *,
                 noise: float = 0.005,
                 outlier_rate: float = 0.0,
                 outlier_scale: float = 3.0,
                 seed: int = 0):
        self.platform = platform
        self.graphs: dict[str, DNNGraph] = {g.name: g for g in graphs}
        if len(self.graphs) != len(graphs):
            raise ValueError("duplicate graph names")
        model = paper_like_pccs() if model is None else model
        if hasattr(model, "slowdown"):
            self.models = {dom: model for dom in platform.domains} \
                or {"_": model}
            self._fallback = model
        else:
            self.models = dict(model)
            self._fallback = next(iter(self.models.values()))
        self.noise = float(noise)
        self.outlier_rate = float(outlier_rate)
        self.outlier_scale = float(outlier_scale)
        self.seed = int(seed)
        self._rng = np.random.default_rng(seed)
        #: executions performed (provenance: sample counts).
        self.runs = 0

    # -- executor interface -------------------------------------------------
    def graph_names(self) -> tuple[str, ...]:
        return tuple(self.graphs)

    def group(self, name: str, gi: int):
        return self.graphs[name].groups[gi]

    def group_count(self, name: str) -> int:
        return len(self.graphs[name])

    def accelerators_of(self, name: str, gi: int) -> tuple[str, ...]:
        return tuple(sorted(self.group(name, gi).times))

    def _domain_of(self, acc: str) -> str:
        for dom, members in self.platform.domains.items():
            if acc in members:
                return dom
        return "_"

    def true_slowdown(self, acc: str, own: float, external: float) -> float:
        """Generating-model slowdown (the quantity calibration recovers)."""
        if own <= 0.0 or external <= 0.0:
            return 1.0
        # an accelerator outside every domain contends through the
        # fallback model rather than crashing the sweep.
        model = self.models.get(self._domain_of(acc), self._fallback)
        return max(1.0, model.slowdown(own, external))

    def run_group(self, name: str, gi: int, acc: str,
                  external: float = 0.0) -> float:
        """One timed "execution": measured wall ms for this group on
        ``acc`` while the antagonist requests ``external`` of the domain
        capacity."""
        grp = self.group(name, gi)
        base = grp.time_on(acc)
        s = self.true_slowdown(acc, grp.demand_on(acc), external)
        t = base * s * max(0.5, 1.0 + self.noise * self._rng.standard_normal())
        if self.outlier_rate and self._rng.random() < self.outlier_rate:
            t *= self.outlier_scale
        self.runs += 1
        return t

    def read_demand(self, name: str, gi: int, acc: str) -> float:
        """Requested-throughput counter readout (noisy, >= 0)."""
        d = self.group(name, gi).demand_on(acc)
        return max(0.0, d * (1.0 + self.noise * self._rng.standard_normal()))

    def out_bytes(self, name: str, gi: int) -> float:
        return self.group(name, gi).out_bytes

    def describe(self) -> dict:
        """Provenance block for the bundle."""
        return {
            "executor": "virtual-soc",
            "platform": self.platform.name,
            "noise": self.noise,
            "outlier_rate": self.outlier_rate,
            "outlier_scale": self.outlier_scale,
            "seed": self.seed,
            "runs": self.runs,
            "generating_model": type(next(iter(self.models.values()))
                                     ).__name__,
        }

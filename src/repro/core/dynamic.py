"""D-HaX-CoNN: dynamic runtime adaptation of optimal schedule generation (§5.3).

Autonomous workload CFGs change at runtime (mode switches, new DNN sets).
Stalling for seconds while Z3 re-solves is not acceptable, so D-HaX-CoNN:

  1. starts from the best *naive* schedule (not Herald/H2H — they themselves
     take seconds, see the paper's footnote),
  2. runs the CEGAR solver in bounded wall-clock slices, replacing the live
     schedule whenever a better one is found,
  3. converges to (and certifies) the optimal schedule as the loop keeps
     running.

The solver state is kept warm across :meth:`step` calls — blocking clauses
and bound cuts persist, matching Z3's incremental model-based quantifier
instantiation behaviour described in the paper.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence

try:
    import z3
    HAVE_Z3 = True
except ImportError:  # pragma: no cover
    HAVE_Z3 = False

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
import dataclasses

from .lowering import (lower_surface, register_surface_lowering,
                       register_vectorized_slowdown, slowdown_array)
from .plan import Plan, ScheduleRequest
from .registry import (decode_model, encode_model,
                       register_contention_model)
from .simulate import Workload, simulate
from .solver_bb import Solution
from .solver_z3 import _EPS, _Encoding, _incumbent


@dataclass
class ImprovementEvent:
    solver_time_s: float
    objective: float
    assignments: list[tuple[str, ...]]


@dataclass
class DHaXCoNN:
    """Anytime scheduler for one workload CFG."""

    platform: Platform
    graphs: Sequence[DNNGraph]
    model: ContentionModel | Mapping[str, ContentionModel]
    objective: str = "latency"
    max_transitions: int | None = 3
    iterations: Sequence[int] | None = None
    depends_on: Sequence[int | None] | None = None

    best: Solution = field(init=False)
    converged: bool = field(init=False, default=False)
    solver_time_s: float = field(init=False, default=0.0)
    history: list[ImprovementEvent] = field(init=False)
    evaluated: int = field(init=False, default=0)

    def __post_init__(self):
        self._its = list(self.iterations or [1] * len(self.graphs))
        self._deps = list(self.depends_on or [None] * len(self.graphs))
        self.best = _incumbent(self.platform, self.graphs, self.model,
                               self.objective, self._its, self._deps)
        self.history = [ImprovementEvent(0.0, self.best.objective,
                                         self.best.assignments)]
        if HAVE_Z3:
            self._enc = _Encoding(self.platform, self.graphs, self._its,
                                  self.max_transitions, self._deps)
        else:  # degrade to a one-shot exhaustive fallback on first step
            self._enc = None

    # ------------------------------------------------------------------
    def step(self, budget_s: float) -> Solution:
        """Run the solver for at most ``budget_s`` seconds; return best."""
        if self.converged:
            return self.best
        t_end = time.perf_counter() + budget_s
        if self._enc is None:
            from . import solver_bb
            self.best = solver_bb.solve(
                self.platform, self.graphs, self.model, self.objective,
                self.max_transitions or 3, self._its, self._deps)
            self.converged = True
            return self.best
        enc = self._enc
        while True:
            now = time.perf_counter()
            if now >= t_end:
                break
            enc.s.push()
            enc.s.add(enc.bound_constraint(self.objective,
                                           self.best.objective))
            enc.s.set("timeout", max(1, int((t_end - now) * 1000)))
            r = enc.s.check()
            m = enc.s.model() if r == z3.sat else None
            enc.s.pop()
            self.solver_time_s += time.perf_counter() - now
            if r == z3.unsat:
                self.converged = True
                self.best.optimal = True
                break
            if r != z3.sat:
                break  # slice exhausted mid-search
            asgs = enc.extract(m)
            enc.block(asgs)
            wls = [Workload(g, a, iterations=it, depends_on=dep)
                   for g, a, it, dep in
                   zip(self.graphs, asgs, self._its, self._deps)]
            res = simulate(self.platform, wls, self.model,
                           record_timeline=False)
            self.evaluated += 1
            obj = res.objective(self.objective)
            if obj < self.best.objective - _EPS:
                self.best = Solution(wls, res, obj, self.objective,
                                     self.evaluated, False)
                self.history.append(ImprovementEvent(
                    self.solver_time_s, obj, self.best.assignments))
        return self.best

    # ------------------------------------------------------------------
    def current_workloads(self) -> list[Workload]:
        return self.best.workloads


# ---------------------------------------------------------------------------
# §4.4 runtime trigger: when *measured* step latency deviates from the
# schedule's *predicted* latency, the live schedule is stale (workload mix
# changed, thermal throttling, a co-runner the model did not know about) and
# the anytime solver should be given another slice.
# ---------------------------------------------------------------------------

@dataclass
class SlowdownMonitor:
    """Deviation detector over an observed/predicted latency stream.

    ``observe`` folds each measurement into an EWMA of the slowdown ratio
    ``observed / predicted``; once the smoothed ratio stays above
    ``threshold`` for ``patience`` consecutive observations the monitor
    fires (returns True) and then holds off for ``cooldown`` observations so
    one sustained deviation triggers one re-schedule, not a storm.  Ratios
    *below* 1 (running faster than predicted) never fire.
    """

    threshold: float = 1.5
    patience: int = 3
    cooldown: int = 16
    #: observations folded into the EWMA before firing is allowed — absorbs
    #: warmup noise (JIT compilation, cache population) after (re)start.
    warmup: int = 4
    alpha: float = 0.5            # EWMA weight of the newest observation

    ratio: float = field(init=False, default=1.0)
    strikes: int = field(init=False, default=0)
    fired: int = field(init=False, default=0)
    _holdoff: int = field(init=False, default=0)

    def __post_init__(self):
        self._holdoff = self.warmup

    def observe(self, observed_ms: float, predicted_ms: float) -> bool:
        # a single NaN/inf sample (torn timer read, dead counter) must not
        # poison the EWMA: NaN folded into ``ratio`` makes every later
        # ``ratio > threshold`` comparison False and the monitor goes
        # silently dead for the rest of the run.
        if (not math.isfinite(observed_ms)
                or not math.isfinite(predicted_ms)
                or predicted_ms <= 0.0 or observed_ms < 0.0):
            return False
        r = observed_ms / predicted_ms
        self.ratio = self.alpha * r + (1.0 - self.alpha) * self.ratio
        if self._holdoff > 0:
            self._holdoff -= 1
            return False
        if self.ratio > self.threshold:
            self.strikes += 1
        else:
            self.strikes = 0
        if self.strikes >= self.patience:
            self.strikes = 0
            self.fired += 1
            self._holdoff = self.cooldown
            return True
        return False

    def reset(self) -> None:
        """Forget history (call after the schedule actually changed)."""
        self.ratio = 1.0
        self.strikes = 0
        self._holdoff = self.cooldown


@dataclass(frozen=True)
class ScaledContentionModel:
    """Online recalibration: scale a base model's *excess* slowdown.

    When the monitor observes the system running ``factor``× slower than the
    schedule predicted, re-solving under ``ScaledContentionModel(base,
    factor)`` makes the solver price contention at the observed severity —
    the paper's feedback from measurement into schedule generation — without
    refitting the underlying PCCS surface.
    """

    base: ContentionModel
    factor: float = 1.0

    def slowdown(self, own: float, external: float) -> float:
        return 1.0 + self.factor * (self.base.slowdown(own, external) - 1.0)


register_contention_model(
    "scaled", ScaledContentionModel,
    encode=lambda m: {"factor": m.factor, "base": encode_model(m.base)},
    decode=lambda cfg: ScaledContentionModel(
        decode_model(cfg["base"]), cfg["factor"]))


def _scaled_surface(m: ScaledContentionModel):
    """Lower by folding the excess factor into the base surface — one
    registration point serves the NumPy batch path and the jax evaluator
    alike; scaled-of-scaled towers fold multiplicatively."""
    base = lower_surface(m.base)
    if base is None:
        return None   # no array-IR form (jax evaluator refuses; NumPy
        #               falls through to _scaled_vectorized below)
    return dataclasses.replace(base, factor=base.factor * m.factor)


def _scaled_vectorized(m: ScaledContentionModel, own, ext):
    # reached only when the base has no surface form (model_slowdown
    # dispatches surface-first): delegate to the base's vectorized path so
    # §4.4 rescaling never drops a third-party fast path to the
    # elementwise fallback.
    return 1.0 + m.factor * (slowdown_array(m.base, own, ext) - 1.0)


register_surface_lowering(ScaledContentionModel, _scaled_surface)
register_vectorized_slowdown(ScaledContentionModel, _scaled_vectorized)


#: largest severity ``quantize_severity`` emits.  An observed factor this
#: large means the prediction underflowed toward 0 (or the platform is
#: unusably degraded); pricing contention any steeper no longer changes
#: which schedule wins, and an unbounded factor would overflow
#: ``round(inf * 16.0)`` and crash the reschedule path.
MAX_SEVERITY = 64.0


def quantize_severity(factor: float) -> float:
    """Snap an observed slowdown factor to 1/16 steps in [1, MAX_SEVERITY].

    Severity resolution no schedule is sensitive to, but coarse enough
    that re-solves at recurring severities are plan-cache hits.  NaN maps
    to the neutral 1.0 (no measured deviation); +inf and anything beyond
    :data:`MAX_SEVERITY` clamp to the documented ceiling instead of
    raising ``OverflowError``.
    """
    if math.isnan(factor):
        return 1.0
    if factor >= MAX_SEVERITY:
        return MAX_SEVERITY
    return max(1.0, round(factor * 16.0) / 16.0)


def reschedule_plan(scheduler, graphs: Sequence[DNNGraph],
                    observed_factor: float, *,
                    objective: str = "latency",
                    max_transitions: int | None = 3,
                    iterations: Sequence[int] | None = None,
                    depends_on: Sequence[int | None] | None = None,
                    budget_s: float = 0.5) -> Plan:
    """§4.4 runtime re-solve, routed through ``Scheduler.resolve``.

    The monitor's observed severity rescales the scheduler's base contention
    model (:class:`ScaledContentionModel`) and the bounded re-solve goes
    through the normal resolve path, so repeated re-schedules at similar
    severity are plan-cache hits and every re-solve is logged/persisted
    uniformly with offline solves.  The continuously-valued EWMA factor is
    quantized (:func:`quantize_severity`) so recurring deviations actually
    share cache entries instead of minting a new plan per float; callers
    comparing an incumbent against the result must price the incumbent at
    the same quantized severity.
    """
    observed_factor = quantize_severity(observed_factor)
    model = ScaledContentionModel(scheduler.model, observed_factor)
    request = ScheduleRequest(
        graphs=tuple(graphs),
        platform=scheduler.platform,
        model=model,
        objective=objective,
        max_transitions=max_transitions,
        iterations=tuple(iterations or ()),
        depends_on=tuple(depends_on or ()),
        deadline_s=budget_s,
    )
    return scheduler.resolve(request)

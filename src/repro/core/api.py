"""DEPRECATED facade — thin shims over the Scheduler/Plan object API.

New code should use :class:`repro.core.Scheduler` directly:

    from repro.core import Scheduler
    sched = Scheduler("xavier-agx")
    plan = sched.solve(["vgg19", "resnet152"], objective="latency")
    print(plan.assignments, plan.result.latency_ms, plan.solver)

The free functions below keep the historical call shape (``schedule`` /
``evaluate_baseline`` / ``compare`` returning bare ``Solution`` /
``SimResult`` objects) and delegate to one *shared* Scheduler per
(platform, model), so repeated calls hit its plan cache.  They emit
:class:`DeprecationWarning` and will be removed once every caller has
migrated (see docs/api.md for the migration table).
"""
from __future__ import annotations

import warnings
from typing import Sequence

from .contention import ContentionModel
from .graph import DNNGraph
from .plan import PlanCache, platform_fingerprint
from .scheduler import (DEFAULT_POD_MODEL, DEFAULT_SOC_MODEL, Scheduler,
                        default_model, failed, resolve_graphs,
                        resolve_platform)
from .simulate import SimResult, Workload
from .solver_bb import Solution

__all__ = [
    "DEFAULT_POD_MODEL", "DEFAULT_SOC_MODEL",
    "resolve_platform", "default_model", "resolve_graphs", "failed",
    "schedule", "evaluate_baseline", "compare", "shared_scheduler",
]

_SCHEDULERS: dict[object, Scheduler] = {}


def shared_scheduler(platform: str | "Platform" = "agx-orin",
                     model: ContentionModel | None = None) -> Scheduler:
    """The process-wide Scheduler the deprecated shims delegate to."""
    plat = resolve_platform(platform)
    try:
        key = (platform_fingerprint(plat), model)
        hash(key)
    except TypeError:            # unhashable custom model: no sharing
        return Scheduler(plat, model)
    sched = _SCHEDULERS.get(key)
    if sched is None:
        # bounded: a long-lived process funnels every legacy call through
        # these shared schedulers, so their caches must not grow forever.
        sched = _SCHEDULERS[key] = Scheduler(
            plat, model, cache=PlanCache(max_entries=256))
    return sched


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.api.{old} is deprecated; use {new} "
        f"(see docs/api.md)", DeprecationWarning, stacklevel=3)


def schedule(
    dnns: Sequence[str | DNNGraph],
    platform="agx-orin",
    objective: str = "latency",
    model: ContentionModel | None = None,
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    deadline_s: float | None = None,
) -> Solution:
    """Deprecated: ``Scheduler(platform).solve(dnns, objective, ...)``."""
    _deprecated("schedule", "Scheduler.solve")
    plan = shared_scheduler(platform, model).solve(
        dnns, objective, max_transitions=max_transitions,
        iterations=iterations, depends_on=depends_on, deadline_s=deadline_s)
    return plan.solution


def evaluate_baseline(
    name: str,
    dnns: Sequence[str | DNNGraph],
    platform="agx-orin",
    model: ContentionModel | None = None,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
) -> tuple[list[Workload], SimResult]:
    """Deprecated: ``Scheduler(platform).evaluate_baseline(name, dnns)``."""
    _deprecated("evaluate_baseline", "Scheduler.evaluate_baseline")
    return shared_scheduler(platform, model).evaluate_baseline(
        name, dnns, iterations=iterations, depends_on=depends_on)


def compare(
    dnns: Sequence[str | DNNGraph],
    platform="agx-orin",
    objective: str = "latency",
    model: ContentionModel | None = None,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    deadline_s: float | None = 20.0,
) -> dict[str, object]:
    """Deprecated: ``Scheduler(platform).compare(dnns, objective, ...)``.

    Row shape is preserved except that a failing baseline is now a
    structured ``{"error": {"type", "message"}}`` dict instead of a silent
    ``None`` (check with :func:`repro.core.scheduler.failed`).  The
    ``"haxconn"`` row stays a bare :class:`Solution`, and — as before the
    redesign — a solver failure raises instead of appearing as a row.
    """
    _deprecated("compare", "Scheduler.compare")
    rows = shared_scheduler(platform, model).compare(
        dnns, objective, iterations=iterations, depends_on=depends_on,
        deadline_s=deadline_s)
    hax = rows["haxconn"]
    if failed(hax):
        err = hax["error"]
        raise RuntimeError(
            f"schedule solve failed ({err['type']}): {err['message']}")
    rows["haxconn"] = hax.solution
    return rows

"""High-level facade: one call from (DNNs, platform, objective) to a schedule.

    from repro.core import api
    sol = api.schedule(["vgg19", "resnet152"], platform="xavier-agx",
                       objective="latency")
    print(sol.assignments, sol.result.latency_ms)

Accepts either paper-profile DNN names or pre-built :class:`DNNGraph`s (e.g.
exported from a JAX model via :mod:`repro.models.graph_export`).
"""
from __future__ import annotations

from typing import Mapping, Sequence

from . import baselines as _baselines
from . import solver_z3
from .accelerators import PLATFORMS, Platform
from .contention import ContentionModel, ProportionalShareModel
from .graph import DNNGraph
from .profiles import get_graph
from .simulate import SimResult, Workload, simulate
from .solver_bb import Solution

#: calibrated default for the SoC EMC domains — reproduces the paper's
#: observed co-run slowdown magnitudes (up to ~70% performance loss, §5.2)
#: at the Table-2 demand levels.
DEFAULT_SOC_MODEL = ProportionalShareModel(capacity=1.0, sensitivity=3.0)
#: ICI over-subscription is served fairly by the fabric; no extra sensitivity.
DEFAULT_POD_MODEL = ProportionalShareModel(capacity=1.0, sensitivity=1.0)


def resolve_platform(platform: str | Platform) -> Platform:
    if isinstance(platform, Platform):
        return platform
    return PLATFORMS[platform]()


def default_model(platform: Platform) -> ContentionModel:
    return DEFAULT_POD_MODEL if "ICI" in platform.domains else DEFAULT_SOC_MODEL


def resolve_graphs(dnns: Sequence[str | DNNGraph],
                   platform: Platform) -> list[DNNGraph]:
    return [d if isinstance(d, DNNGraph) else get_graph(d, platform)
            for d in dnns]


def schedule(
    dnns: Sequence[str | DNNGraph],
    platform: str | Platform = "agx-orin",
    objective: str = "latency",
    model: ContentionModel | None = None,
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    deadline_s: float | None = None,
) -> Solution:
    """HaX-CoNN optimal contention-aware schedule (CEGAR + exact simulator)."""
    plat = resolve_platform(platform)
    graphs = resolve_graphs(dnns, plat)
    m = model or default_model(plat)
    return solver_z3.solve(plat, graphs, m, objective=objective,
                           max_transitions=max_transitions,
                           iterations=iterations, depends_on=depends_on,
                           deadline_s=deadline_s)


def evaluate_baseline(
    name: str,
    dnns: Sequence[str | DNNGraph],
    platform: str | Platform = "agx-orin",
    model: ContentionModel | None = None,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
) -> tuple[list[Workload], SimResult]:
    """Evaluate one named baseline under the exact contention simulator."""
    plat = resolve_platform(platform)
    graphs = resolve_graphs(dnns, plat)
    m = model or default_model(plat)
    wls = _baselines.BASELINES[name](plat, graphs, iterations=iterations,
                                     depends_on=depends_on)
    return wls, simulate(plat, wls, m)


def compare(
    dnns: Sequence[str | DNNGraph],
    platform: str | Platform = "agx-orin",
    objective: str = "latency",
    model: ContentionModel | None = None,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    deadline_s: float | None = 20.0,
) -> dict[str, object]:
    """HaX-CoNN vs. every baseline — the shape of the paper's Table 6 rows."""
    plat = resolve_platform(platform)
    rows: dict[str, object] = {}
    for name in _baselines.BASELINES:
        try:
            _, res = evaluate_baseline(name, dnns, plat, model,
                                       iterations, depends_on)
            rows[name] = res
        except (ValueError, KeyError):
            rows[name] = None
    sol = schedule(dnns, plat, objective, model, iterations=iterations,
                   depends_on=depends_on, deadline_s=deadline_s)
    rows["haxconn"] = sol
    return rows

"""Serializable schedule artifacts: ScheduleRequest -> Plan -> PlanCache.

HaX-CoNN's product is the *schedule*; this module makes it a first-class,
persistable object instead of an ephemeral in-process
:class:`~repro.core.solver_bb.Solution`:

* :class:`ScheduleRequest` — one validated description of a scheduling
  problem (graphs, platform, contention model, objective, transition
  budget, iterations, dependencies, solver choice, deadline).  Its
  canonical JSON form is content-hashed, so two requests describing the
  same problem share one hash regardless of where they were built.
* :class:`Plan` — a solved schedule plus provenance (request hash, solver
  entry that produced it, solve wall-time, platform fingerprint, creation
  time).  ``to_json``/``from_json`` round-trip the *entire* problem and
  solution, so a plan solved offline can be diffed, cached and loaded by
  the serving gateway with zero solver invocations.
* :class:`PlanCache` — content-addressed (by request hash) in-memory +
  optional on-disk store; ``artifacts/plans/`` is the conventional root.

Plans are versioned (``FORMAT``): loading verifies the stored request hash
against a recomputation from the deserialized request, so a hand-edited or
format-drifted artifact fails loudly instead of silently driving a stale
schedule.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import time
from dataclasses import dataclass, field
from typing import Any, Mapping

from . import registry
from .accelerators import Accelerator, Platform
from .contention import ContentionModel
from .graph import DNNGraph, LayerGroup
from .simulate import Interval, SimResult, Workload
from .solver_bb import Solution
from ..obs import get_logger, get_registry, get_tracer

log = get_logger(__name__)

FORMAT = 1
OBJECTIVES = ("latency", "throughput", "sum_inverse")


# ---------------------------------------------------------------------------
# canonical (de)serialization of the problem ingredients
# ---------------------------------------------------------------------------

def graph_to_dict(g: DNNGraph) -> dict:
    return {
        "name": g.name,
        "groups": [{
            "name": grp.name,
            "times": {a: float(t) for a, t in sorted(grp.times.items())},
            "mem_demand": {a: float(d)
                           for a, d in sorted(grp.mem_demand.items())},
            "out_bytes": float(grp.out_bytes),
            "can_transition_after": bool(grp.can_transition_after),
            "flops": float(grp.flops),
            "hbm_bytes": float(grp.hbm_bytes),
        } for grp in g.groups],
    }


def graph_from_dict(d: Mapping[str, Any]) -> DNNGraph:
    return DNNGraph(d["name"], tuple(
        LayerGroup(name=grp["name"], times=dict(grp["times"]),
                   mem_demand=dict(grp["mem_demand"]),
                   out_bytes=grp["out_bytes"],
                   can_transition_after=grp["can_transition_after"],
                   flops=grp["flops"], hbm_bytes=grp["hbm_bytes"])
        for grp in d["groups"]))


def platform_to_dict(p: Platform) -> dict:
    return {
        "name": p.name,
        "accelerators": [{
            "name": a.name, "peak_flops": a.peak_flops, "mem_bw": a.mem_bw,
            "transition_in_ms": a.transition_in_ms,
            "transition_out_ms": a.transition_out_ms, "n_chips": a.n_chips,
        } for a in p.accelerators],
        "transition_bw": p.transition_bw,
        "domains": {k: list(v) for k, v in sorted(p.domains.items())},
        "domain_bw": {k: float(v) for k, v in sorted(p.domain_bw.items())},
        "epsilon_ms": p.epsilon_ms,
    }


def platform_from_dict(d: Mapping[str, Any]) -> Platform:
    return Platform(
        name=d["name"],
        accelerators=tuple(Accelerator(**a) for a in d["accelerators"]),
        transition_bw=d["transition_bw"],
        domains={k: tuple(v) for k, v in d["domains"].items()},
        domain_bw=dict(d["domain_bw"]),
        epsilon_ms=d["epsilon_ms"],
    )


def canonical_hash(obj: Any) -> str:
    """Content hash of a JSON-serializable object (order-independent)."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def platform_fingerprint(p: Platform) -> str:
    return canonical_hash(platform_to_dict(p))


# ---------------------------------------------------------------------------
# ScheduleRequest
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleRequest:
    """One validated scheduling problem (replaces 8 loose kwargs).

    ``iterations``/``depends_on`` are normalized to per-graph tuples at
    construction, so equal problems hash equally however they were spelled.
    """

    graphs: tuple[DNNGraph, ...]
    platform: Platform
    model: ContentionModel
    objective: str = "latency"
    solver: str = registry.AUTO
    max_transitions: int | None = 3
    iterations: tuple[int, ...] = ()
    depends_on: tuple[int | None, ...] = ()
    deadline_s: float | None = None
    #: extra solver-entry knobs (e.g. anneal's population/devices/
    #: budget_ms), normalized to a sorted tuple of (name, value) pairs so
    #: equal requests hash equally however the mapping was spelled.
    solver_knobs: tuple[tuple[str, Any], ...] = ()

    def __post_init__(self):
        if not self.graphs:
            raise ValueError("request has no DNN graphs")
        object.__setattr__(self, "graphs", tuple(self.graphs))
        n = len(self.graphs)
        if self.objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {self.objective!r}; "
                f"one of {', '.join(OBJECTIVES)}")
        if self.solver != registry.AUTO:
            registry.get_solver(self.solver)   # raises with known names
        knobs = self.solver_knobs
        if isinstance(knobs, Mapping):
            knobs = tuple(knobs.items())
        knobs = tuple(sorted((str(k), v) for k, v in knobs))
        for k, v in knobs:
            if v is not None and not isinstance(v, (bool, int, float, str)):
                raise ValueError(
                    f"solver knob {k!r} has non-scalar value {v!r}; "
                    f"knobs must be JSON scalars")
        registry.validate_solver_knobs(self.solver, dict(knobs))
        object.__setattr__(self, "solver_knobs", knobs)
        its = tuple(self.iterations) or (1,) * n
        if len(its) != n:
            raise ValueError(
                f"iterations has {len(its)} entries for {n} graphs")
        if any(int(i) != i or i < 1 for i in its):
            raise ValueError("iterations must be positive integers")
        object.__setattr__(self, "iterations", tuple(int(i) for i in its))
        deps = tuple(self.depends_on) or (None,) * n
        if len(deps) != n:
            raise ValueError(
                f"depends_on has {len(deps)} entries for {n} graphs")
        for i, dep in enumerate(deps):
            if dep is not None and (dep < 0 or dep >= n or dep == i):
                raise ValueError(f"depends_on[{i}] = {dep} is invalid")
        for i in range(n):                   # fail fast on dependency cycles
            seen = {i}
            j = deps[i]
            while j is not None:
                if j in seen:
                    raise ValueError(
                        f"depends_on contains a cycle through graphs "
                        f"{sorted(seen)}")
                seen.add(j)
                j = deps[j]
        object.__setattr__(self, "depends_on", deps)
        if self.max_transitions is not None and self.max_transitions < 0:
            raise ValueError("max_transitions must be >= 0 or None")
        names = set(self.platform.names)
        for g in self.graphs:
            if not names & set(g.accelerators):
                raise ValueError(
                    f"graph {g.name!r} runs on no accelerator of platform "
                    f"{self.platform.name!r}")

    def to_dict(self) -> dict:
        d = {
            "graphs": [graph_to_dict(g) for g in self.graphs],
            "platform": platform_to_dict(self.platform),
            "model": registry.encode_model(self.model),
            "objective": self.objective,
            "solver": self.solver,
            "max_transitions": self.max_transitions,
            "iterations": list(self.iterations),
            "depends_on": list(self.depends_on),
            "deadline_s": self.deadline_s,
        }
        # only serialized when set: knob-free requests keep the hash (and
        # the on-disk cache keys) of every plan minted before this field.
        if self.solver_knobs:
            d["solver_knobs"] = dict(self.solver_knobs)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScheduleRequest":
        return cls(
            graphs=tuple(graph_from_dict(g) for g in d["graphs"]),
            platform=platform_from_dict(d["platform"]),
            model=registry.decode_model(d["model"]),
            objective=d["objective"],
            solver=d["solver"],
            max_transitions=d["max_transitions"],
            iterations=tuple(d["iterations"]),
            depends_on=tuple(d["depends_on"]),
            deadline_s=d["deadline_s"],
            solver_knobs=tuple(sorted(d.get("solver_knobs", {}).items())),
        )

    def request_hash(self) -> str:
        cached = self.__dict__.get("_hash")
        if cached is None:
            cached = canonical_hash(self.to_dict())
            object.__setattr__(self, "_hash", cached)
        return cached


# ---------------------------------------------------------------------------
# Solution (de)serialization — graphs referenced by request index
# ---------------------------------------------------------------------------

def _solution_to_dict(sol: Solution, request: ScheduleRequest) -> dict:
    graph_idx = {id(g): i for i, g in enumerate(request.graphs)}

    def wl_graph_index(wl: Workload) -> int:
        i = graph_idx.get(id(wl.graph))
        if i is not None:
            return i
        for j, g in enumerate(request.graphs):   # re-built equal graph
            if g == wl.graph:
                return j
        raise ValueError(
            f"workload graph {wl.graph.name!r} is not part of the request")

    return {
        "workloads": [{
            "graph": wl_graph_index(wl),
            "assignment": list(wl.assignment),
            "iterations": wl.iterations,
            "depends_on": wl.depends_on,
            "arrival_ms": wl.arrival_ms,
        } for wl in sol.workloads],
        "result": {
            "makespan": sol.result.makespan,
            "finish_times": list(sol.result.finish_times),
            "iteration_latencies": [list(l)
                                    for l in sol.result.iteration_latencies],
            "timeline": [[iv.start, iv.end, iv.workload, iv.iteration,
                          iv.group, iv.acc, iv.slowdown]
                         for iv in sol.result.timeline],
            "contention_ms": sol.result.contention_ms,
            "busy_ms": dict(sol.result.busy_ms),
        },
        "objective": sol.objective,
        "kind": sol.kind,
        "evaluated": sol.evaluated,
        "optimal": sol.optimal,
        "params": dict(getattr(sol, "params", {}) or {}),
    }


def _solution_from_dict(d: Mapping[str, Any],
                        request: ScheduleRequest) -> Solution:
    wls = [Workload(request.graphs[w["graph"]], tuple(w["assignment"]),
                    iterations=w["iterations"], depends_on=w["depends_on"],
                    arrival_ms=w["arrival_ms"])
           for w in d["workloads"]]
    r = d["result"]
    res = SimResult(
        makespan=r["makespan"],
        finish_times=list(r["finish_times"]),
        iteration_latencies=[list(l) for l in r["iteration_latencies"]],
        timeline=[Interval(*iv) for iv in r["timeline"]],
        contention_ms=r["contention_ms"],
        busy_ms=dict(r["busy_ms"]),
    )
    # absent in pre-anneal artifacts: exact solvers carry no params.
    return Solution(wls, res, d["objective"], d["kind"], d["evaluated"],
                    d["optimal"], params=dict(d.get("params", {})))


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclass
class Plan:
    """A solved schedule plus provenance — the deployable artifact."""

    request: ScheduleRequest
    solution: Solution
    #: registry entry that actually produced the solution ("z3"|"bb"|...).
    solver: str
    solve_time_s: float
    request_hash: str
    platform_fingerprint: str
    #: evaluator the solver searched with ("batch"|"scalar"); provenance
    #: only — the recorded result always comes from the scalar simulator,
    #: and the request hash is evaluator-independent.
    evaluator: str = "scalar"
    #: solver-specific provenance copied from ``Solution.params`` (e.g. the
    #: anneal entry's seed / steps / population); empty for exact solvers.
    #: Like ``evaluator``, never part of the request hash.
    solver_params: dict = field(default_factory=dict)
    created_at: float = field(default_factory=time.time)

    # -- convenience views ------------------------------------------------
    @property
    def assignments(self) -> list[tuple[str, ...]]:
        return self.solution.assignments

    @property
    def objective(self) -> float:
        return self.solution.objective

    @property
    def result(self) -> SimResult:
        return self.solution.result

    @property
    def optimal(self) -> bool:
        return self.solution.optimal

    def summary(self) -> str:
        res = self.solution.result
        seed = self.solver_params.get("seed")
        rows = [f"plan {self.request_hash[:12]} solver={self.solver} "
                + (f"seed={seed} " if seed is not None else "")
                + f"evaluator={self.evaluator} "
                f"objective={self.solution.kind}={self.objective:.4f} "
                f"optimal={self.optimal} solve={self.solve_time_s:.3f}s",
                f"  platform={self.request.platform.name} "
                f"lat={res.latency_ms:.3f}ms fps={res.throughput_fps:.1f}"]
        for wl in self.solution.workloads:
            rows.append(f"    {wl.graph.name}: {' '.join(wl.assignment)}")
        return "\n".join(rows)

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "format": FORMAT,
            "request": self.request.to_dict(),
            "solution": _solution_to_dict(self.solution, self.request),
            "solver": self.solver,
            "solve_time_s": self.solve_time_s,
            "request_hash": self.request_hash,
            "platform_fingerprint": self.platform_fingerprint,
            "evaluator": self.evaluator,
            "solver_params": dict(self.solver_params),
            "created_at": self.created_at,
        }

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Plan":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"unsupported plan format {d.get('format')!r} "
                f"(this build reads format {FORMAT})")
        request = ScheduleRequest.from_dict(d["request"])
        recomputed = request.request_hash()
        if recomputed != d["request_hash"]:
            raise ValueError(
                "plan artifact is corrupt or was produced by an "
                f"incompatible build: stored request hash "
                f"{d['request_hash'][:12]} != recomputed {recomputed[:12]}")
        return cls(
            request=request,
            solution=_solution_from_dict(d["solution"], request),
            solver=d["solver"],
            solve_time_s=d["solve_time_s"],
            request_hash=d["request_hash"],
            platform_fingerprint=d["platform_fingerprint"],
            # absent in pre-batch-evaluator artifacts: those searched scalar.
            evaluator=d.get("evaluator", "scalar"),
            # absent in pre-anneal artifacts: exact solvers have no params.
            solver_params=dict(d.get("solver_params", {})),
            created_at=d["created_at"],
        )

    @classmethod
    def from_json(cls, s: str) -> "Plan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Plan":
        return cls.from_json(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

class PlanCache:
    """Content-addressed plan store: in-memory, optionally disk-backed.

    ``root=None`` keeps plans in memory only (the default for library use);
    with a directory every solved plan is persisted as
    ``<root>/plan-<hash16>.json`` and later processes hit it cold.
    ``max_entries`` bounds the in-memory map with LRU eviction — set it
    for long-running control planes whose request stream is unbounded.
    Corrupt, truncated or format-drifted disk entries degrade to a miss
    (logged) instead of raising, so one bad artifact never wedges a boot.
    """

    def __init__(self, root: str | pathlib.Path | None = None,
                 max_entries: int | None = None):
        self.root = pathlib.Path(root) if root is not None else None
        self.max_entries = max_entries
        self._mem: dict[str, Plan] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._mem)

    def path_for(self, request_hash: str) -> pathlib.Path | None:
        if self.root is None:
            return None
        return self.root / f"plan-{request_hash[:16]}.json"

    def get(self, request_hash: str) -> Plan | None:
        tier = "mem"
        plan = self._mem.get(request_hash)
        if plan is not None:
            # LRU: a hit refreshes recency so hot plans survive eviction.
            self._mem.pop(request_hash)
            self._mem[request_hash] = plan
        else:
            path = self.path_for(request_hash)
            if path is not None and path.exists():
                tier = "disk"
                try:
                    plan = Plan.load(path)
                except (OSError, ValueError, TypeError, KeyError,
                        json.JSONDecodeError) as exc:
                    # a corrupt / truncated / undecodable artifact (e.g.
                    # solved with a codec-less model, or a writer that died
                    # mid-save) degrades to a miss — it must not poison the
                    # cache for every later process.
                    log.warning("ignoring unreadable plan cache file %s "
                                "(%s); re-solving", path, exc)
                    tier = "corrupt"
                    plan = None
                else:
                    if plan.request_hash != request_hash:
                        log.warning(
                            "cache file %s holds plan %s, not %s; ignoring",
                            path, plan.request_hash[:12], request_hash[:12])
                        tier = "wrong_hash"
                        plan = None
                if plan is not None:
                    self._insert(plan)
                if tier in ("corrupt", "wrong_hash"):
                    # rare by construction: worth a trace instant + counter
                    # so a degrading store is visible before it hurts p99.
                    get_tracer().instant("plan_cache.degrade", "cache",
                                         reason=tier, request=request_hash[:12])
                    get_registry().counter(
                        "plan_cache_degraded",
                        "disk plan-cache entries degraded to a miss").labels(
                            reason=tier).inc()
        if plan is None:
            self.misses += 1
            get_registry().counter(
                "plan_cache_misses", "plan cache lookups that missed").inc()
            return None
        self.hits += 1
        get_registry().counter(
            "plan_cache_hits", "plan cache lookups that hit").labels(
                tier=tier).inc()
        return plan

    def add(self, plan: Plan) -> None:
        """Insert without persisting (pre-loading a shipped artifact)."""
        self._insert(plan)

    def put(self, plan: Plan) -> pathlib.Path | None:
        self._insert(plan)
        path = self.path_for(plan.request_hash)
        if path is not None:
            plan.save(path)
        return path

    def _insert(self, plan: Plan) -> None:
        # re-insert at the recent end so _mem stays LRU-ordered (oldest
        # access first — Python dicts preserve insertion order).
        self._mem.pop(plan.request_hash, None)
        self._mem[plan.request_hash] = plan
        if self.max_entries is not None:
            while len(self._mem) > self.max_entries:     # LRU eviction
                self._mem.pop(next(iter(self._mem)))

    def clear(self) -> None:
        self._mem.clear()
        self.hits = self.misses = 0


class ShardedPlanCache(PlanCache):
    """Disk-backed :class:`PlanCache` sharded by request-hash prefix.

    A fleet control plane cold-starts hundreds of schedulers against one
    shared plan store; with a flat directory every process lists and locks
    the same inode.  Sharding by the first ``shard_chars`` hex digits of
    the request hash (``<root>/<prefix>/plan-<hash16>.json``) spreads
    concurrent readers/writers over ``16**shard_chars`` independent
    directories, and a lookup never scans an index — it is exactly one
    ``open()`` of a content-addressed path, so a cold boot stays
    O(load-a-JSON) per plan.

    ``max_disk_entries`` bounds the on-disk store: after every persist the
    owning shard is trimmed oldest-mtime-first to its share of the budget
    (``ceil(max_disk_entries / n_shards)``) — eviction never touches other
    shards, preserving the no-cross-shard-contention property.
    """

    def __init__(self, root: str | pathlib.Path,
                 max_entries: int | None = None,
                 shard_chars: int = 2,
                 max_disk_entries: int | None = None):
        if not 1 <= shard_chars <= 8:
            raise ValueError("shard_chars must be in [1, 8]")
        super().__init__(root=root, max_entries=max_entries)
        self.shard_chars = shard_chars
        self.max_disk_entries = max_disk_entries

    @property
    def n_shards(self) -> int:
        return 16 ** self.shard_chars

    def path_for(self, request_hash: str) -> pathlib.Path:
        shard = request_hash[:self.shard_chars]
        return self.root / shard / f"plan-{request_hash[:16]}.json"

    def put(self, plan: Plan) -> pathlib.Path | None:
        path = super().put(plan)
        if path is not None and self.max_disk_entries is not None:
            self._trim_shard(path.parent)
        return path

    def _trim_shard(self, shard_dir: pathlib.Path) -> None:
        budget = -(-self.max_disk_entries // self.n_shards)    # ceil
        try:
            entries = sorted(shard_dir.glob("plan-*.json"),
                             key=lambda p: p.stat().st_mtime)
        except OSError:                        # shard raced away: nothing to trim
            return
        for stale in entries[:max(0, len(entries) - budget)]:
            try:
                stale.unlink()
                get_registry().counter(
                    "plan_cache_evictions",
                    "persisted plans evicted by shard trimming").inc()
                log.info("evicted plan cache file %s (shard over budget)",
                         stale)
            except OSError:                    # concurrent eviction lost the race
                pass

    def disk_entries(self) -> int:
        """Total persisted plans across every shard (diagnostics only)."""
        if not self.root.exists():
            return 0
        return sum(1 for _ in self.root.glob("*/plan-*.json"))

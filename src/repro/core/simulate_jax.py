"""XLA evaluator: the lockstep timeline state machine under jax.jit + vmap.

This is the third interpretation of the lowered
:class:`~repro.core.lowering.ProblemSpec` IR (after the authoritative
scalar simulator and the NumPy lockstep loop): one candidate's Eq. 2-8
event machine is written as a ``lax.while_loop`` over a fixed-shape state
pytree, ``jax.vmap`` batches it across the candidate population, and
``jax.jit`` compiles the whole sweep into a single XLA executable — so
candidate evaluation scales with the accelerator instead of the Python
interpreter, and populations far beyond the Table-8 sweep's 137k
candidates can stay device-resident.

Key differences from :mod:`repro.core.simulate_batch`:

  * **finished-candidate masking instead of compaction** — a vmapped
    ``while_loop`` keeps every lane's state fixed once its own condition
    goes false; no dynamic shapes anywhere.  The host shards large
    populations into power-of-two chunks so each chunk's loop terminates
    at its *own* deepest candidate (the masking analogue of the NumPy
    path's compaction) and solver chunk-size jitter reuses a handful of
    compiled executables.
  * **scatter-free waves** — FIFO claims are resolved by per-rank argmin
    over (ready, index) and all accelerator-indexed accumulations go
    through one-hot contractions; the only gathers are group-table reads.
  * **surface-parameterized contention** — slowdowns are computed from the
    spec's lowered :class:`~repro.core.lowering.SlowdownSurface` parameters
    (proportional closed form in jnp; the PCCS piecewise surface through
    :mod:`repro.kernels.slowdown`, whose Pallas kernel engages for large
    flat batches on TPU and whose XLA contraction fuses into the loop body
    elsewhere).  A model with no lowered surface cannot run here — lower it
    (``repro.core.lowering.register_surface_lowering``) or use the
    ``batch``/``scalar`` evaluators, whose Python fallbacks accept any
    object with a scalar ``slowdown``.
  * **error codes, not exceptions** — a traced loop cannot raise;
    deadlock / unmodeled contention / guard exhaustion set per-candidate
    flags that are re-raised host-side after the run, matching the scalar
    simulator's exceptions.

By default the evaluator runs in float64 via the scoped
``jax.experimental.enable_x64`` context (bit-compatible with the NumPy
path to ~1e-9 and differentially pinned at 1e-5 by
``tests/test_simulate_differential.py``); ``precision="float32"`` halves
memory traffic for accelerator-resident search where ranking, not exact
latency, is consumed (event tolerances scale with the dtype).

The scalar simulator remains authoritative: ``evaluator="jax"`` call sites
inherit the same contract as the NumPy batch path — solvers re-simulate
their final incumbent through :func:`repro.core.simulate.simulate`.
"""
from __future__ import annotations

import functools
from typing import Any, Mapping, Sequence

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    HAVE_JAX = True
except ImportError:  # pragma: no cover - the container ships jax
    HAVE_JAX = False

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .lowering import (ProblemSpec, TOL as _TOL, lower_assignments,
                       lower_workloads)
from .simulate import Workload
from .simulate_batch import BatchTimeline, _empty_batch

#: host-side error codes surfaced by the traced loop.
_ERR_DEADLOCK = 1
_ERR_UNMODELED = 2
_ERR_GUARD = 4

#: default candidate-axis shard; chunks pad to the next power of two, so a
#: sweep of any size runs through ~log2 distinct compiled shapes.  16k is
#: the empirical sweet spot on the 2-core CPU reference box (see
#: BENCH_simulate.json); accelerator deployments may prefer larger shards.
DEFAULT_CHUNK = 16384


def _require_jax() -> None:
    if not HAVE_JAX:  # pragma: no cover
        raise RuntimeError(
            "evaluator 'jax' requires jax; install it or use "
            "evaluator='batch' / 'scalar'")


def _surface_params(surface) -> dict:
    """One surface's parameters as a jnp pytree (traced jit inputs).

    No explicit dtypes: the ambient precision context (``enable_x64`` or
    the process default) decides float64 vs float32.
    """
    p: dict[str, Any] = {"factor": jnp.asarray(float(surface.factor))}
    if surface.kind == "proportional":
        p["capacity"] = jnp.asarray(float(surface.capacity))
        p["sensitivity"] = jnp.asarray(float(surface.sensitivity))
    elif surface.kind == "piecewise":
        p["own_knots"] = jnp.asarray(np.asarray(surface.own_knots, float))
        p["ext_knots"] = jnp.asarray(np.asarray(surface.ext_knots, float))
        p["table"] = jnp.asarray(np.asarray(surface.table, float))
    else:
        raise ValueError(f"unknown surface kind {surface.kind!r}")
    return p


def _surface_eval(kind: str, params: Mapping[str, Any], own, ext):
    """jnp evaluation of one lowered surface (mirrors
    ``repro.core.lowering.surface_slowdown``)."""
    if kind == "proportional":
        cap = params["capacity"].astype(own.dtype)
        own_ = jnp.maximum(0.0, own)
        ext_ = jnp.maximum(0.0, ext)
        total = own_ + ext_
        s = 1.0 + params["sensitivity"].astype(own.dtype) \
            * jnp.minimum(1.0, own_ / cap) * (total / cap - 1.0)
        s = jnp.where((own_ == 0.0) | (total <= cap),
                      jnp.ones((), own.dtype), s)
    else:  # piecewise — the PCCS surface kernel (Pallas on TPU, XLA here)
        from repro.kernels.slowdown import piecewise_slowdown
        s = piecewise_slowdown(own, ext,
                               params["own_knots"].astype(own.dtype),
                               params["ext_knots"].astype(own.dtype),
                               params["table"].astype(own.dtype),
                               backend="auto")
    f = params["factor"].astype(own.dtype)
    return jnp.where(f == 1.0, s, 1.0 + f * (s - 1.0))


def make_event_machine(kinds: tuple[str, ...], max_it: int,
                       record: bool = True):
    """Build one candidate's Eq. 2-8 event machine as a traceable function.

    Returns ``one(acc, dur, dem, tau, ngroups, iters, dep, arrival,
    domshare, model_of_acc, surf_params)``.  With ``record=True`` (the
    evaluator path) it returns ``(finish, lat, contention, busy, err)``;
    with ``record=False`` it carries only the state the control flow needs
    and returns ``(finish, err)`` — the lean variant the device-resident
    search (:mod:`repro.core.search_jax`) evaluates millions of mutants
    through, where every objective derives from finish times alone.

    ``kinds`` (surface kinds, control flow) and ``max_it`` (iteration-
    latency depth / guard budget shape) must be static; shapes and dtypes
    re-specialize through jit as usual.
    """

    def one(acc, dur, dem, tau, ngroups, iters, dep, arrival,
            domshare, model_of_acc, surf_params):
        W = acc.shape[0]
        A = domshare.shape[0]
        dt = dur.dtype
        i32 = jnp.int32
        idx = jnp.arange(W)
        arange_a = jnp.arange(A)
        inf = jnp.asarray(jnp.inf, dt)
        zero = jnp.zeros((), dt)
        one_ = jnp.ones((), dt)
        # event tolerance scales with the working precision: lowering.TOL
        # matches the scalar/NumPy paths exactly; float32 cannot resolve
        # that, so completions/boundaries coalesce at ~1e-5 (ranking-grade).
        tol = jnp.asarray(_TOL if dt == jnp.dtype("float64") else 1e-5, dt)
        ngroups32 = ngroups.astype(i32)
        iters32 = iters.astype(i32)
        dep32 = dep.astype(i32)
        dep_row = jnp.clip(dep32, 0, W - 1)
        macc_of = model_of_acc.astype(i32)
        domshare_t = domshare.astype(dt)
        # scalar-simulator guard, per candidate.
        max_waves = (200000 + 200 * jnp.sum(ngroups32 * iters32)).astype(i32)

        def claim(t, cur_oh, group, ready, it, started, done, is_run,
                  it_start):
            """One FIFO claim sweep: eligible waiting workloads in
            (ready, index) order take their accelerator if free.  Pure
            recomputation — idempotent when nothing changed since the last
            sweep, which is what lets the idle jump re-claim in-wave.
            ``cur_oh`` is the (W, A) accelerator one-hot of ``cur_acc``,
            hoisted by the caller (it only changes at completions, so one
            wave's claims and slowdown step share a single build)."""
            dep_ok = (dep32 < 0) | done[dep_row] | (it[dep_row] > it)
            eligible = ~done & ~is_run & dep_ok & (ready <= t + tol)
            acc_busy = (cur_oh & is_run[:, None]).any(0)        # (A,)
            left = eligible
            for _ in range(W):   # static unroll: rank-r claim by argmin
                key = jnp.where(left, ready, inf)
                wr = jnp.argmin(key)            # first min -> FIFO tie by idx
                sel = idx == wr
                my_busy = (cur_oh & acc_busy[None, :]).any(1)   # (W,)
                claim_v = sel & left & ~my_busy  # at most one entry true
                is_run = is_run | claim_v
                acc_busy = acc_busy | (cur_oh & claim_v[:, None]).any(0)
                if record:   # iteration-start bookkeeping feeds lat only
                    fresh = claim_v & (group == 0) & ~started
                    it_start = jnp.where(fresh, t, it_start)
                    started = started | fresh
                left = left & ~sel
            return is_run, started, it_start

        state = dict(
            t=jnp.zeros((), dt),
            guard=jnp.zeros((), i32),
            group=jnp.zeros(W, i32),
            cur_acc=acc[:, 0].astype(i32),
            own=dem[:, 0].astype(dt),
            remaining=dur[:, 0].astype(dt),
            ready=arrival.astype(dt),
            it=jnp.zeros(W, i32),
            done=jnp.zeros(W, bool),
            is_run=jnp.zeros(W, bool),
            finish=jnp.zeros(W, dt),
            err=jnp.zeros((), i32),
        )
        if record:   # observability state the search ranking never reads
            state.update(
                it_start=arrival.astype(dt),
                started=jnp.zeros(W, bool),
                lat=jnp.full((W, max_it), jnp.nan, dt),
                contention=jnp.zeros((), dt),
                busy=jnp.zeros(A, dt),
            )

        def cond(s):
            return (~s["done"].all()) & (s["guard"] < max_waves)

        def body(s):
            t = s["t"]
            group, cur_acc, own = s["group"], s["cur_acc"], s["own"]
            remaining, ready = s["remaining"], s["ready"]
            it = s["it"]
            it_start, started = s.get("it_start"), s.get("started")
            done, is_run = s["done"], s["is_run"]
            err = s["err"]
            # accelerator one-hot of the wave; cur_acc only changes at
            # completions (step 5), so both claims and the slowdown step
            # share one build.
            cur_oh = cur_acc[:, None] == arange_a[None, :]      # (W, A)

            # 1) FIFO claims at the current time.
            is_run, started, it_start = claim(
                t, cur_oh, group, ready, it, started, done, is_run,
                it_start)
            any_run = is_run.any()

            # idle gap: jump to the next pending boundary and re-claim in
            # the same wave (the scalar simulator's `continue`, fused).
            pend = jnp.where(~done & (ready > t + tol), ready, inf)
            tmin = pend.min()
            idle = ~any_run
            dead = idle & ~jnp.isfinite(tmin)
            err = err | jnp.where(dead, _ERR_DEADLOCK, 0)
            done = done | dead      # poison-exit the lane; host re-raises
            t = jnp.where(idle & ~dead, tmin, t)
            is_run, started, it_start = claim(
                t, cur_oh, group, ready, it, started, done, is_run,
                it_start)
            any_run = is_run.any()

            # 2) per-interval slowdowns from the lowered surfaces.
            cur_ohf = cur_oh.astype(dt)
            own_eff = jnp.where(is_run, own, zero)
            acc_dem = (cur_ohf * own_eff[:, None]).sum(0)       # (A,)
            ext = (cur_ohf * (domshare_t @ acc_dem)[None, :]).sum(1)
            contended = is_run & (own > 0.0) & (ext > 0.0)
            macc = (cur_ohf * macc_of[None, :].astype(dt)).sum(1).astype(i32)
            slow = jnp.ones(W, dt)
            for mid, kind in enumerate(kinds):   # static unroll over models
                sv = _surface_eval(kind, surf_params[mid], own, ext)
                slow = jnp.where(contended & (macc == mid),
                                 jnp.maximum(one_, sv), slow)
            unmod = (contended & (macc < 0)).any()
            err = err | jnp.where(unmod, _ERR_UNMODELED, 0)
            done = done | unmod

            # 3) next event horizon: earliest running completion, capped by
            # ready boundaries strictly inside the interval.
            run_rem = jnp.where(is_run, remaining * slow, inf)
            horizon = t + run_rem.min()
            cap = jnp.where(~done & ~is_run & (ready > t + tol)
                            & (ready < horizon - tol), ready, inf).min()
            horizon = jnp.minimum(horizon, cap)
            horizon = jnp.where(any_run, horizon, t)
            span = horizon - t

            # 4) integrate the contention interval.
            prog = jnp.where(is_run, span / slow, zero)
            remaining = remaining - prog
            if record:
                contention = s["contention"] + jnp.sum(
                    jnp.where(is_run, span * (1.0 - 1.0 / slow), zero))
                busy = s["busy"] + (cur_ohf * prog[:, None]).sum(0)
            t = jnp.where(any_run, horizon, t)

            # 5) process completions.
            fin = is_run & (remaining <= tol)
            is_run = is_run & ~fin
            tau_cur = tau[idx, group].astype(dt)
            has_next = fin & (group + 1 < ngroups32)
            last = fin & ~has_next
            if record:
                lat = jnp.where(
                    last[:, None]
                    & (jnp.arange(max_it)[None, :] == it[:, None]),
                    (t - it_start)[:, None], s["lat"])
            it2 = it + last.astype(i32)
            if record:
                started = started & ~last
            fin_wl = last & (it2 >= iters32)
            done = done | fin_wl
            finish = jnp.where(fin_wl, t, s["finish"])
            restart = last & ~fin_wl
            new_group = jnp.where(has_next, group + 1,
                                  jnp.where(restart, 0, group))
            refresh = has_next | restart
            cur_acc = jnp.where(refresh, acc[idx, new_group].astype(i32),
                                cur_acc)
            own = jnp.where(refresh, dem[idx, new_group].astype(dt), own)
            remaining = jnp.where(refresh, dur[idx, new_group].astype(dt),
                                  remaining)
            ready = jnp.where(has_next, t + tau_cur,
                              jnp.where(restart, t, ready))

            nxt = dict(t=t, guard=s["guard"] + 1, group=new_group,
                       cur_acc=cur_acc, own=own, remaining=remaining,
                       ready=ready, it=it2, done=done, is_run=is_run,
                       finish=finish, err=err)
            if record:
                nxt.update(it_start=it_start, started=started, lat=lat,
                           contention=contention, busy=busy)
            return nxt

        out = jax.lax.while_loop(cond, body, state)
        err = out["err"] | jnp.where(out["done"].all(), 0, _ERR_GUARD)
        if record:
            return (out["finish"], out["lat"], out["contention"],
                    out["busy"], err)
        return out["finish"], err

    return one


@functools.lru_cache(maxsize=None)
def _compiled_run(kinds: tuple[str, ...], max_it: int):
    """Jitted population evaluator for one surface-kind layout: the full
    (recording) event machine under ``jax.vmap`` + ``jax.jit``."""
    one = make_event_machine(kinds, max_it, record=True)

    @jax.jit
    def run(acc, dur, dem, tau, ngroups, iters, dep, arrival,
            domshare, model_of_acc, surf_params):
        mapped = jax.vmap(
            lambda a, du, de, ta, ng, itr, dp, ar: one(
                a, du, de, ta, ng, itr, dp, ar,
                domshare, model_of_acc, surf_params))
        return mapped(acc, dur, dem, tau, ngroups, iters, dep, arrival)

    return run


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def _pad_rows(arr: np.ndarray, n_to: int) -> np.ndarray:
    if arr.shape[0] == n_to:
        return arr
    reps = np.repeat(arr[:1], n_to - arr.shape[0], axis=0)
    return np.concatenate([arr, reps], axis=0)


def unlowerable_models(spec: ProblemSpec) -> tuple[str, ...]:
    """Type names of the spec's contention models with no array-IR surface."""
    return tuple(type(m).__name__
                 for m, s in zip(spec.models, spec.surfaces) if s is None)


def simulate_spec(spec: ProblemSpec, *, precision: str = "x64",
                  chunk: int = DEFAULT_CHUNK) -> BatchTimeline:
    """Evaluate a lowered problem spec through the XLA event loop.

    ``precision="x64"`` (default) runs float64 inside a scoped
    ``enable_x64`` context; ``"float32"`` runs the process-default single
    precision (ranking-grade, cheaper on accelerators).  ``chunk`` shards
    the candidate axis: each shard's while_loop stops at its own deepest
    candidate instead of the global maximum, and shards pad to powers of
    two so arbitrary population sizes share compiled executables.
    """
    _require_jax()
    bad = unlowerable_models(spec)
    if bad:
        raise ValueError(
            f"evaluator 'jax' needs lowerable contention surfaces, but "
            f"{', '.join(sorted(set(bad)))} has no registered surface "
            f"lowering (repro.core.lowering.register_surface_lowering); "
            f"use evaluator='batch' or 'scalar' for this model")
    if precision not in ("x64", "float32"):
        raise ValueError(f"unknown precision {precision!r} "
                         f"(expected 'x64' or 'float32')")
    n = spec.n
    max_it = int(spec.iters.max())
    run = _compiled_run(tuple(s.kind for s in spec.surfaces), max_it)

    finish = np.zeros((n, spec.w))
    lat = np.full((n, spec.w, max_it), np.nan)
    contention = np.zeros(n)
    busy = np.zeros((n, spec.amax))
    err = np.zeros(n, dtype=np.int64)

    def call():
        surf = tuple(_surface_params(s) for s in spec.surfaces)
        domshare = jnp.asarray(spec.domshare)
        model_of_acc = jnp.asarray(spec.model_of_acc)
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            nb = _next_pow2(hi - lo)
            args = [jnp.asarray(_pad_rows(np.asarray(a[lo:hi]), nb))
                    for a in (spec.acc, spec.dur, spec.dem, spec.tau,
                              spec.ngroups, spec.iters, spec.dep,
                              spec.arrival)]
            fin, la, con, bu, er = run(*args, domshare, model_of_acc, surf)
            m = hi - lo
            finish[lo:hi] = np.asarray(fin)[:m]
            lat[lo:hi] = np.asarray(la)[:m]
            contention[lo:hi] = np.asarray(con)[:m]
            busy[lo:hi] = np.asarray(bu)[:m]
            err[lo:hi] = np.asarray(er)[:m]

    if precision == "x64":
        with enable_x64():
            call()
    else:
        call()

    if err.any():
        code = int(np.bitwise_or.reduce(err))
        if code & _ERR_UNMODELED:
            uncovered = [a for a, m in zip(spec.acc_names, spec.model_of_acc)
                         if m < 0]
            raise KeyError(f"no contention model covers accelerator(s) "
                           f"{uncovered!r}")
        if code & _ERR_DEADLOCK:
            raise RuntimeError("deadlock: nothing running, nothing pending")
        raise RuntimeError("jax simulator did not converge (event storm)")

    return BatchTimeline(
        makespan=finish.max(axis=1),
        finish_times=finish,
        iteration_latencies=lat,
        iterations=spec.iters.copy(),
        contention_ms=contention,
        busy_ms=busy,
        acc_names=spec.acc_names,
    )


# ---------------------------------------------------------------------------
# registry-shaped wrappers (the evaluator entry points)
# ---------------------------------------------------------------------------

def simulate_batch(
    platform: Platform,
    workloads_batch: Sequence[Sequence[Workload]],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
    precision: str = "x64",
) -> BatchTimeline:
    """Lower per-candidate Workload lists and evaluate them under XLA."""
    _require_jax()
    if len(workloads_batch) == 0:
        return _empty_batch(platform)
    return simulate_spec(lower_workloads(platform, workloads_batch, model,
                                         validate), precision=precision)


def simulate_assignments(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    assignments_batch: Sequence[Sequence[Sequence[str]]],
    model: ContentionModel | Mapping[str, ContentionModel],
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    validate: bool = True,
    precision: str = "x64",
) -> BatchTimeline:
    """Lower fixed-graph assignment vectors and evaluate them under XLA."""
    _require_jax()
    if len(assignments_batch) == 0:
        return _empty_batch(platform)
    return simulate_spec(lower_assignments(
        platform, graphs, assignments_batch, model, iterations=iterations,
        depends_on=depends_on, validate=validate), precision=precision)

"""Shared-resource contention modeling (§3.3).

The paper decouples contention estimation into (1) a one-time standalone
characterization of each layer's *requested memory throughput* and (2) a
processor-centric slowdown model (PCCS [67]) that maps

    slowdown = f(own requested throughput, external requested throughput)

without ever profiling layer *pairs*.  We implement two interchangeable
models:

* :class:`ProportionalShareModel` — the analytic default.  While total demand
  is below domain capacity nothing slows down; beyond capacity the domain
  serves requesters proportionally, and a layer's slowdown is weighted by the
  fraction of its runtime that is bandwidth-bound (its *memory-boundedness*,
  derived from the demand itself).  Piecewise-linear in (own, external),
  matching PCCS's model class.

* :class:`PiecewiseModel` — PCCS proper: an explicit piecewise-linear surface
  over (own, external) given as calibration knots, e.g. fitted from measured
  co-run slowdowns.  The paper-calibrated SoC platforms use this with knots
  chosen to reproduce the published co-run slowdowns (Fig. 6).

Both are pure functions — the exact timeline simulator calls them once per
contention interval (Eq. 7/8).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Protocol, Sequence


class ContentionModel(Protocol):
    def slowdown(self, own: float, external: float) -> float:
        """Multiplicative slowdown (>= 1) of a layer requesting ``own``
        (fraction of domain capacity) while other accelerators in the same
        domain request ``external`` in total."""
        ...


@dataclass(frozen=True)
class ProportionalShareModel:
    """Bandwidth-partitioning slowdown model.

    If own + external <= capacity: no slowdown.  Otherwise the requester's
    achieved bandwidth is its proportional share ``own / total`` of capacity,
    so its memory-bound phase dilates by ``total / capacity``; the
    compute-bound phase (fraction ``1 - boundedness``) is unaffected.
    ``boundedness`` defaults to the demand itself clipped to [0, 1]: a layer
    requesting 80% of domain bandwidth spends ~80% of its time on memory.
    """

    capacity: float = 1.0
    #: optional scaling of how strongly over-subscription converts to delay.
    sensitivity: float = 1.0

    def slowdown(self, own: float, external: float) -> float:
        own = max(0.0, own)
        external = max(0.0, external)
        if own == 0.0:
            return 1.0
        total = own + external
        if total <= self.capacity:
            return 1.0
        boundedness = min(1.0, own / self.capacity)
        dilation = total / self.capacity
        return 1.0 + self.sensitivity * boundedness * (dilation - 1.0)


@dataclass(frozen=True)
class PiecewiseModel:
    """PCCS-style explicit piecewise-linear slowdown surface.

    ``own_knots``/``ext_knots`` are strictly increasing axis grids and
    ``table[i][j]`` is the measured/calibrated slowdown at
    (own_knots[i], ext_knots[j]).  Bilinear interpolation inside the grid,
    clamped extension outside.
    """

    own_knots: tuple[float, ...]
    ext_knots: tuple[float, ...]
    table: tuple[tuple[float, ...], ...]

    def __post_init__(self):
        if len(self.table) != len(self.own_knots):
            raise ValueError("table rows must match own_knots")
        for row in self.table:
            if len(row) != len(self.ext_knots):
                raise ValueError("table cols must match ext_knots")
        for row in self.table:
            for v in row:
                if v < 1.0:
                    raise ValueError("slowdowns must be >= 1")

    @staticmethod
    def _locate(knots: Sequence[float], x: float) -> tuple[int, int, float]:
        if x <= knots[0]:
            return 0, 0, 0.0
        if x >= knots[-1]:
            return len(knots) - 1, len(knots) - 1, 0.0
        hi = bisect.bisect_right(knots, x)
        lo = hi - 1
        w = (x - knots[lo]) / (knots[hi] - knots[lo])
        return lo, hi, w

    def slowdown(self, own: float, external: float) -> float:
        if own <= 0.0 or external <= 0.0:
            return 1.0
        i0, i1, wi = self._locate(self.own_knots, own)
        j0, j1, wj = self._locate(self.ext_knots, external)
        t = self.table
        v0 = t[i0][j0] * (1 - wj) + t[i0][j1] * wj
        v1 = t[i1][j0] * (1 - wj) + t[i1][j1] * wj
        return v0 * (1 - wi) + v1 * wi


def estimate_blackbox_demand(gpu_demand: float, emc_util_gpu: float,
                             emc_util_dsa: float) -> float:
    """§3.3 four-step black-box DSA throughput estimation.

    DLAs (and other black-box DSAs) cannot be profiled with vendor counters.
    The paper observes EMC-utilization curves of GPU and DSA are proportional
    per layer, so a layer's DSA-side requested throughput is estimated by
    scaling its GPU-side throughput by the EMC utilization ratio.
    """
    if emc_util_gpu <= 0:
        raise ValueError("GPU EMC utilization must be positive")
    return gpu_demand * (emc_util_dsa / emc_util_gpu)


def pccs_from_pairs(pairs: Sequence[tuple[float, float, float]],
                    own_knots: Sequence[float] = (0.1, 0.3, 0.5, 0.7, 0.9),
                    ext_knots: Sequence[float] = (0.1, 0.3, 0.5, 0.7, 0.9),
                    ) -> PiecewiseModel:
    """Fit a :class:`PiecewiseModel` from (own, external, slowdown) samples.

    Nearest-sample fill per knot with inverse-distance weighting — adequate
    for the small calibration sets the paper uses (the model class matters,
    not the fitting algorithm).
    """
    table = []
    for ok in own_knots:
        row = []
        for ek in ext_knots:
            num = den = 0.0
            for own, ext, sd in pairs:
                d2 = (own - ok) ** 2 + (ext - ek) ** 2
                w = 1.0 / (d2 + 1e-6)
                num += w * sd
                den += w
            row.append(max(1.0, num / den))
        table.append(tuple(row))
    return PiecewiseModel(tuple(own_knots), tuple(ext_knots), tuple(table))

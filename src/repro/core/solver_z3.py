"""Optimal schedule generation with Z3 (§3.4–3.5).

Two encodings are provided:

* :func:`solve` (default) — **CEGAR loop**: Z3 searches the assignment space
  under sound *linear lower-bound* timing constraints (contention-free path
  time per DNN, Eq. 2 without C; per-accelerator load, the queueing bound
  implied by Eq. 9).  Every candidate Z3 proposes is evaluated **exactly** by
  the event-driven simulator (which integrates Eqs. 5/7/8 over contention
  intervals); the incumbent bound is tightened and the candidate blocked, so
  the UNSAT certificate at the end proves optimality of the incumbent w.r.t.
  the exact interval-based contention model.  This sidesteps the
  nonlinear-real fixed point of Eqs. 5/7 while keeping optimality.

* :func:`solve_monolithic` — the paper's Eqs. 1–11 written directly into Z3
  (start/end reals, Eq. 8 overlap cases as If-expressions, multiplication for
  Eq. 5).  Nonlinear real arithmetic: only practical for small instances;
  kept as the faithful reference encoding and cross-checked in tests.

The solver is *anytime*: ``deadline_s`` caps wall-clock; the incumbent is
always a valid schedule (initialized from the best naive baseline, §5.3), so
D-HaX-CoNN can interleave solving with execution.
"""
from __future__ import annotations

import time
from typing import Callable, Mapping, Sequence

try:
    import z3
    HAVE_Z3 = True
except ImportError:  # pragma: no cover - z3 is installed in CI
    HAVE_Z3 = False

from .accelerators import Platform
from .baselines import BASELINES
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import Workload, simulate
from .solver_bb import Solution

_EPS = 1e-6


class _Encoding:
    """Shared structural encoding: assignment ints + LB time expressions."""

    def __init__(self, platform: Platform, graphs: Sequence[DNNGraph],
                 iterations: Sequence[int], max_transitions: int | None,
                 depends_on: Sequence[int | None] | None = None):
        self.platform = platform
        self.graphs = graphs
        self.acc_names = list(platform.names)
        self.acc_idx = {a: k for k, a in enumerate(self.acc_names)}
        self.s = z3.Solver()
        self.x: list[list[z3.ArithRef]] = []
        for n, g in enumerate(graphs):
            row = []
            for i, grp in enumerate(g):
                v = z3.Int(f"x_{n}_{i}")
                allowed = [self.acc_idx[a] for a in self.acc_names
                           if a in grp.times]
                self.s.add(z3.Or([v == k for k in allowed]))
                row.append(v)
            self.x.append(row)
            # §3.1 legality: collapse illegal boundaries.
            for i in range(len(g) - 1):
                if not g[i].can_transition_after:
                    self.s.add(row[i] == row[i + 1])
            if max_transitions is not None:
                trans = z3.Sum([
                    z3.If(row[i] != row[i + 1], 1, 0)
                    for i in range(len(g) - 1)
                ])
                self.s.add(trans <= max_transitions)

        # Lower-bound completion time per DNN (Eq. 2 with C == 1).
        self.iterations = list(iterations)
        self.total_inferences = sum(iterations)
        deps = list(depends_on or [None] * len(graphs))
        path = []                     # single-iteration contention-free path
        for n, g in enumerate(graphs):
            terms = []
            for i, grp in enumerate(g):
                expr = z3.RealVal(0)
                for a in self.acc_names:
                    if a in grp.times:
                        expr = z3.If(self.x[n][i] == self.acc_idx[a],
                                     z3.RealVal(grp.time_on(a)), expr)
                terms.append(expr)
            for i in range(len(g) - 1):
                tau = z3.RealVal(0)
                for a in self.acc_names:
                    for b in self.acc_names:
                        if a == b:
                            continue
                        cost = platform.transition_cost_ms(g[i].out_bytes, a, b)
                        tau = z3.If(
                            z3.And(self.x[n][i] == self.acc_idx[a],
                                   self.x[n][i + 1] == self.acc_idx[b]),
                            z3.RealVal(cost), tau)
                terms.append(tau)
            path.append(z3.Sum(terms))
        self.T = []
        for n in range(len(graphs)):
            T = path[n] * z3.RealVal(iterations[n])
            # pipeline fill: consumer cannot start iteration 0 before the
            # producer chain finished its first iteration.
            m = deps[n]
            while m is not None:
                T = T + path[m]
                m = deps[m]
            self.T.append(T)

        # Per-accelerator load bound (queueing consequence of Eq. 9).
        self.load = []
        for a in self.acc_names:
            terms = []
            for n, g in enumerate(graphs):
                for i, grp in enumerate(g):
                    if a in grp.times:
                        terms.append(z3.If(
                            self.x[n][i] == self.acc_idx[a],
                            z3.RealVal(grp.time_on(a) * iterations[n]),
                            z3.RealVal(0)))
            self.load.append(z3.Sum(terms) if terms else z3.RealVal(0))

    def bound_constraint(self, objective: str, best: float):
        """Sound pruning constraint: LB(objective) must beat ``best``."""
        if objective == "latency":
            cs = [T < best - _EPS for T in self.T]
            cs += [ld < best - _EPS for ld in self.load]
            return z3.And(cs)
        if objective == "throughput":
            # obj = -1e3·N/makespan; makespan >= every path/load bound, so a
            # candidate can only beat ``best`` (< 0) if all bounds stay below
            # the constant 1e3·N/(-best).
            cap = 1e3 * self.total_inferences / (-best) - _EPS
            cs = [T < cap for T in self.T]
            cs += [ld < cap for ld in self.load]
            return z3.And(cs)
        if objective == "sum_inverse":
            # true obj = -Σ 1/T_n^exact >= -Σ 1/T_n^LB  (T_exact >= T_LB);
            # necessary condition to beat best: -Σ 1/T_LB < best.
            inv = [z3.RealVal(1) / T for T in self.T]
            return -z3.Sum(inv) < best - _EPS
        raise ValueError(objective)

    def extract(self, m) -> list[tuple[str, ...]]:
        out = []
        for n, g in enumerate(self.graphs):
            out.append(tuple(
                self.acc_names[m.evaluate(self.x[n][i]).as_long()]
                for i in range(len(g))))
        return out

    def block(self, asgs: list[tuple[str, ...]]):
        lits = []
        for n, asg in enumerate(asgs):
            for i, a in enumerate(asg):
                lits.append(self.x[n][i] != self.acc_idx[a])
        self.s.add(z3.Or(lits))


def _incumbent(platform, graphs, model, objective, iterations, depends_on):
    """Best baseline schedule — the CEGAR (and D-HaX-CoNN) starting point."""
    best = None
    for fn in BASELINES.values():
        try:
            wls = fn(platform, graphs, iterations=iterations,
                     depends_on=depends_on)
            res = simulate(platform, wls, model, record_timeline=False)
        except (ValueError, KeyError):
            continue
        obj = res.objective(objective)
        if best is None or obj < best.objective:
            best = Solution(wls, res, obj, objective, 0, optimal=False)
    if best is None:
        raise RuntimeError("no baseline produced a valid schedule")
    return best


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    deadline_s: float | None = None,
    on_improve: Callable[[Solution, float], None] | None = None,
) -> Solution:
    """CEGAR-optimal contention-aware schedule (the HaX-CoNN solver)."""
    if not HAVE_Z3:
        from . import solver_bb
        return solver_bb.solve(platform, graphs, model, objective,
                               max_transitions or 3, iterations, depends_on)
    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    t0 = time.perf_counter()
    best = _incumbent(platform, graphs, model, objective, its, deps)
    # Tighten the incumbent with a cheap single-transition exhaustive pass
    # (the paper's optimal schedules use one transition per DNN; a strong
    # incumbent lets the CEGAR bound prune most of the space immediately).
    try:
        from . import solver_bb
        quick = solver_bb.solve(platform, graphs, model, objective,
                                max_transitions=1, iterations=its,
                                depends_on=deps)
        if quick.objective < best.objective - _EPS:
            best = quick
            best.optimal = False
    except ValueError:
        pass
    enc = _Encoding(platform, graphs, its, max_transitions, deps)
    evaluated = 0
    optimal = False
    while True:
        if deadline_s is not None and time.perf_counter() - t0 > deadline_s:
            break
        enc.s.push()
        enc.s.add(enc.bound_constraint(objective, best.objective))
        if deadline_s is not None:
            remain = deadline_s - (time.perf_counter() - t0)
            enc.s.set("timeout", max(1, int(remain * 1000)))
        r = enc.s.check()
        if r == z3.sat:
            m = enc.s.model()
        enc.s.pop()
        if r == z3.unsat:
            optimal = True          # no unblocked assignment can beat best
            break
        if r != z3.sat:             # timeout / unknown
            break
        asgs = enc.extract(m)
        enc.block(asgs)
        wls = [Workload(g, a, iterations=it, depends_on=dep)
               for g, a, it, dep in zip(graphs, asgs, its, deps)]
        res = simulate(platform, wls, model, record_timeline=False)
        evaluated += 1
        obj = res.objective(objective)
        if obj < best.objective - _EPS:
            best = Solution(wls, res, obj, objective, evaluated, False)
            if on_improve is not None:
                on_improve(best, time.perf_counter() - t0)
    best.evaluated = evaluated
    best.optimal = optimal
    return best


def solve_monolithic(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel,
    objective: str = "latency",
    max_transitions: int | None = 2,
    timeout_s: float = 60.0,
) -> Solution:
    """The paper's Eqs. 1–11 encoded directly (nonlinear real arithmetic).

    Contention is encoded pairwise: layer i of DNN n overlapping layer j of
    DNN m (on different accelerators of a shared domain) dilates execution by
    the PCCS slowdown of the pair.  ``et = st + t * C`` with C from overlap
    fractions (Eq. 5/7); Eq. 8's case analysis is the max/min overlap form;
    Eq. 9 forbids same-accelerator overlap.  Small instances only.
    """
    if not HAVE_Z3:
        raise RuntimeError("z3 not available")
    if len(graphs) != 2:
        raise NotImplementedError("monolithic encoding: exactly 2 DNNs")
    its = [1] * len(graphs)
    enc = _Encoding(platform, graphs, its, max_transitions)
    s = enc.s
    acc_names = enc.acc_names

    st, et, dur = [], [], []
    for n, g in enumerate(graphs):
        st.append([z3.Real(f"st_{n}_{i}") for i in range(len(g))])
        et.append([z3.Real(f"et_{n}_{i}") for i in range(len(g))])
        dur.append([z3.Real(f"d_{n}_{i}") for i in range(len(g))])

    def t_expr(n, i):
        g = graphs[n]
        expr = z3.RealVal(0)
        for a in acc_names:
            if a in g[i].times:
                expr = z3.If(enc.x[n][i] == enc.acc_idx[a],
                             z3.RealVal(g[i].time_on(a)), expr)
        return expr

    def demand_expr(n, i):
        g = graphs[n]
        expr = z3.RealVal(0)
        for a in acc_names:
            if a in g[i].times:
                expr = z3.If(enc.x[n][i] == enc.acc_idx[a],
                             z3.RealVal(g[i].demand_on(a)), expr)
        return expr

    # chain constraints + transition costs (Eqs. 2-4).
    for n, g in enumerate(graphs):
        s.add(st[n][0] >= 0)
        for i in range(len(g)):
            s.add(dur[n][i] >= t_expr(n, i))
            s.add(et[n][i] == st[n][i] + dur[n][i])
            if i + 1 < len(g):
                tau = z3.RealVal(0)
                for a in acc_names:
                    for b in acc_names:
                        if a == b:
                            continue
                        c = platform.transition_cost_ms(g[i].out_bytes, a, b)
                        tau = z3.If(z3.And(enc.x[n][i] == enc.acc_idx[a],
                                           enc.x[n][i + 1] == enc.acc_idx[b]),
                                    z3.RealVal(c), tau)
                s.add(st[n][i + 1] == et[n][i] + tau)

    # Eq. 7/8: duration dilation from pairwise overlap, linearized per pair
    # with the slowdown sampled at the pair's demands (PCCS is evaluated
    # outside the solver — its inputs are assignment-dependent constants).
    eps = platform.epsilon_ms
    for i in range(len(graphs[0])):
        for j in range(len(graphs[1])):
            ov = z3.Real(f"ov_{i}_{j}")
            lo = z3.If(st[0][i] >= st[1][j], st[0][i], st[1][j])
            hi = z3.If(et[0][i] <= et[1][j], et[0][i], et[1][j])
            s.add(ov == z3.If(hi - lo > 0, hi - lo, z3.RealVal(0)))
            # Eq. 9: same accelerator -> no overlap beyond epsilon.
            s.add(z3.Implies(enc.x[0][i] == enc.x[1][j], ov <= eps))

    for n in range(2):
        m = 1 - n
        for i in range(len(graphs[n])):
            extra = []
            for j in range(len(graphs[m])):
                a_pairs = z3.RealVal(0)
                for a in acc_names:
                    for b in acc_names:
                        if a == b:
                            continue
                        dom = platform.shared_domain_of(a, b)
                        if dom is None:
                            continue
                        own = graphs[n][i].demand_on(a) \
                            if a in graphs[n][i].times else 0.0
                        ext = graphs[m][j].demand_on(b) \
                            if b in graphs[m][j].times else 0.0
                        sd = model.slowdown(own, ext)
                        a_pairs = z3.If(
                            z3.And(enc.x[n][i] == enc.acc_idx[a],
                                   enc.x[m][j] == enc.acc_idx[b]),
                            z3.RealVal(sd - 1.0), a_pairs)
                ovname = f"ov_{i}_{j}" if n == 0 else f"ov_{j}_{i}"
                extra.append(z3.Real(ovname) * a_pairs)
            # dur = t + Σ overlap·(s-1): wall-time extension of Eq. 5.
            s.add(dur[n][i] == t_expr(n, i) + z3.Sum(extra))

    obj = z3.Real("obj")
    if objective == "latency":
        s.add(obj >= et[0][-1], obj >= et[1][-1])
        s.add(z3.Or(obj == et[0][-1], obj == et[1][-1]))
    else:
        s.add(obj == -(z3.RealVal(1) / et[0][-1] + z3.RealVal(1) / et[1][-1]))

    opt_best = None
    s.set("timeout", int(timeout_s * 1000))
    # branch&bound on obj via successive tightening
    while s.check() == z3.sat:
        m_ = s.model()
        val = m_.evaluate(obj)
        num = float(val.numerator_as_long()) / float(val.denominator_as_long())
        asgs = enc.extract(m_)
        opt_best = (num, asgs)
        s.add(obj < z3.RealVal(num) - _EPS)
    if opt_best is None:
        raise RuntimeError("monolithic encoding UNSAT — no valid schedule")
    num, asgs = opt_best
    wls = [Workload(g, a) for g, a in zip(graphs, asgs)]
    # N.B. objective value re-reported from the exact simulator for
    # comparability with the CEGAR path.
    from .contention import ContentionModel as _CM  # noqa: F401
    res = simulate(platform, wls, model, record_timeline=False)
    return Solution(wls, res, res.objective(objective), objective, 0, True)

"""Accelerator and platform specifications.

A :class:`Platform` is a set of accelerators plus the *contention domains*
that tie them together.  On the paper's SoCs the single domain is the external
memory controller (EMC) shared by GPU and DLA/DSP; on a TPU pod a domain is
the shared ICI boundary between two submeshes (and optionally per-chip HBM
for co-resident streams).  The scheduler only ever sees accelerator names,
per-layer times/demands, transition costs and a contention model — so SoC and
pod platforms are interchangeable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping

MS = 1e-3
GB = 1e9


@dataclass(frozen=True)
class Accelerator:
    """One schedulable processing unit (DSA, GPU, or TPU submesh)."""

    name: str
    #: peak dense compute, FLOP/s (used by analytic characterization).
    peak_flops: float
    #: private memory bandwidth available to this accelerator, bytes/s.
    mem_bw: float
    #: fixed per-transition overhead entering/leaving this accelerator (ms).
    #: Models reformatting (SoC) / layout+dispatch latency (TPU).
    transition_in_ms: float = 0.0
    transition_out_ms: float = 0.0
    #: chips composing this accelerator (1 for an SoC DSA; >1 for a submesh).
    n_chips: int = 1


@dataclass(frozen=True)
class Platform:
    """Accelerator set + shared-resource topology + transition bandwidth."""

    name: str
    accelerators: tuple[Accelerator, ...]
    #: bandwidth of the shared path used by inter-accelerator transitions
    #: (EMC on the SoC, ICI bisection on the pod), bytes/s.
    transition_bw: float
    #: contention domains: domain name -> member accelerator names.  Layers
    #: running concurrently on accelerators of the same domain contend.
    domains: Mapping[str, tuple[str, ...]] = field(default_factory=dict)
    #: capacity of each contention domain's shared path, bytes/s (EMC
    #: bandwidth on the SoC, ICI boundary bandwidth on a pod).  Demand
    #: fractions in LayerGroup.mem_demand are relative to this.
    domain_bw: Mapping[str, float] = field(default_factory=dict)
    #: ε of Eq. 9 — tolerated same-accelerator overlap (ms).
    epsilon_ms: float = 0.05

    def __post_init__(self):
        names = [a.name for a in self.accelerators]
        if len(set(names)) != len(names):
            raise ValueError("duplicate accelerator names")
        for dom, members in self.domains.items():
            for m in members:
                if m not in names:
                    raise ValueError(f"domain {dom} references unknown acc {m}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self.accelerators)

    def acc(self, name: str) -> Accelerator:
        for a in self.accelerators:
            if a.name == name:
                return a
        raise KeyError(name)

    def shared_domain_of(self, a: str, b: str) -> str | None:
        """First contention domain containing both accelerators, if any."""
        for dom, members in self.domains.items():
            if a in members and b in members:
                return dom
        return None

    def transition_cost_ms(self, out_bytes: float, src: str, dst: str) -> float:
        """τ(L, src, OUT) + τ(L', dst, IN) of Eq. 2 for a given boundary."""
        if src == dst:
            return 0.0
        move = out_bytes / self.transition_bw / MS if self.transition_bw else 0.0
        return move + self.acc(src).transition_out_ms + self.acc(dst).transition_in_ms


# ---------------------------------------------------------------------------
# Paper platforms (Table 4).  peak_flops/mem_bw are the published specs; the
# calibrated profiles in profiles.py carry the actual per-layer timings, so
# these constants only matter for analytic (roofline) characterization.
# ---------------------------------------------------------------------------

def xavier_agx() -> Platform:
    return Platform(
        name="xavier-agx",
        accelerators=(
            Accelerator("GPU", peak_flops=11e12, mem_bw=136.5 * GB,
                        transition_out_ms=0.002, transition_in_ms=0.002),
            Accelerator("DLA", peak_flops=5.7e12, mem_bw=136.5 * GB,
                        transition_out_ms=0.004, transition_in_ms=0.004),
        ),
        transition_bw=136.5 * GB,
        domains={"EMC": ("GPU", "DLA")},
        domain_bw={"EMC": 136.5 * GB},
    )


def agx_orin() -> Platform:
    return Platform(
        name="agx-orin",
        accelerators=(
            Accelerator("GPU", peak_flops=42e12, mem_bw=204.8 * GB,
                        transition_out_ms=0.001, transition_in_ms=0.001),
            Accelerator("DLA", peak_flops=11e12, mem_bw=204.8 * GB,
                        transition_out_ms=0.002, transition_in_ms=0.002),
        ),
        transition_bw=204.8 * GB,
        domains={"EMC": ("GPU", "DLA")},
        domain_bw={"EMC": 204.8 * GB},
    )


def snapdragon_865() -> Platform:
    return Platform(
        name="snapdragon-865",
        accelerators=(
            Accelerator("GPU", peak_flops=1.8e12, mem_bw=34.1 * GB,
                        transition_out_ms=0.05, transition_in_ms=0.05),
            Accelerator("DSP", peak_flops=1.0e12, mem_bw=34.1 * GB,
                        transition_out_ms=0.08, transition_in_ms=0.08),
        ),
        transition_bw=34.1 * GB,
        domains={"EMC": ("GPU", "DSP")},
        domain_bw={"EMC": 34.1 * GB},
    )


# ---------------------------------------------------------------------------
# TPU v5e pod platforms: virtual accelerators = disjoint submeshes.
# ---------------------------------------------------------------------------

V5E_PEAK_FLOPS = 197e12      # bf16 / chip
V5E_HBM_BW = 819 * GB        # / chip
V5E_ICI_BW = 50 * GB         # / link


def tpu_pod_split(n_chips_a: int = 128, n_chips_b: int = 128,
                  name: str = "v5e-pod-split") -> Platform:
    """One pod split into two virtual accelerators sharing the ICI boundary.

    The split boundary of a (16,16) pod crossed by 16 links gives the shared
    domain capacity used by the contention model; transitions between
    submeshes reshard activations across the same boundary.
    """
    links = 16
    return Platform(
        name=name,
        accelerators=(
            Accelerator("MESH_A", peak_flops=n_chips_a * V5E_PEAK_FLOPS,
                        mem_bw=n_chips_a * V5E_HBM_BW, n_chips=n_chips_a,
                        transition_out_ms=0.01, transition_in_ms=0.01),
            Accelerator("MESH_B", peak_flops=n_chips_b * V5E_PEAK_FLOPS,
                        mem_bw=n_chips_b * V5E_HBM_BW, n_chips=n_chips_b,
                        transition_out_ms=0.01, transition_in_ms=0.01),
        ),
        transition_bw=links * V5E_ICI_BW,
        domains={"ICI": ("MESH_A", "MESH_B")},
        domain_bw={"ICI": links * V5E_ICI_BW},
        epsilon_ms=0.02,
    )


PLATFORMS: dict[str, Callable[[], Platform]] = {
    "xavier-agx": xavier_agx,
    "agx-orin": agx_orin,
    "snapdragon-865": snapdragon_865,
    "v5e-pod-split": tpu_pod_split,
}

"""Device-resident schedule search: annealing over the lowered array IR.

PR 4 made candidate *evaluation* device-resident
(:mod:`repro.core.simulate_jax`); the solver loop itself still generated
candidates on the host and round-tripped one population per batch.  This
module closes the loop: mutation, evaluation and selection all run inside
one ``lax.while_loop`` over frozen per-graph lookup tables, so the only
host<->device traffic per search is the initial tables down and the
per-chain incumbents back.

Structure:

* :class:`SearchTables` — the frozen device-side problem: per-graph
  (group, accelerator) duration/demand tables, legality masks, transition
  costs and the platform contention layout, built once from the same
  :func:`repro.core.lowering.graph_tables` the assignment lowering uses.
* :func:`anneal_search` — a population of chains walks the assignment
  space.  Each step every chain mutates one (workload, group) site to a
  random allowed accelerator (proposals that break transition legality
  revert to the current state), scores the mutant through the *lean*
  event machine (``make_event_machine(record=False)`` — identical event
  semantics to the jax evaluator, minus the observability state no
  ranking reads), and the population is selected by the Metropolis +
  incumbent kernel (:mod:`repro.kernels.search`).  Every
  ``exchange_every`` steps each island's best incumbent replaces its
  worst current member — the genetic/elitist migration that keeps deep
  islands from stagnating.

Determinism is by construction, not by luck:

* per-chain RNG streams are ``fold_in(fold_in(PRNGKey(seed),
  global_chain_index), step)`` — a chain's stream depends only on its
  global index, never on how the population was chunked across device
  calls;
* islands are fixed ``island``-sized slices of the global chain order and
  chunk boundaries must align to them (``chunk % island == 0``), so
  migration sees the same members regardless of chunking;
* uniform draws are taken in float32 in *both* precision modes, so the
  accept decisions of ``precision="float32"`` and ``"x64"`` diverge only
  where the objectives themselves do;
* the global winner is the (objective, chain index) lexicographic min —
  first-found wins ties.

The scalar simulator stays authoritative: this module reports the device
incumbent and its device objective; :mod:`repro.core.solver_anneal`
re-simulates the winner on the host scalar path before any
:class:`~repro.core.plan.Plan` is minted.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    HAVE_JAX = True
except ImportError:  # pragma: no cover - the container ships jax
    HAVE_JAX = False

try:  # shard_map is the primary fan-out; pmap is the fallback
    from jax.experimental.shard_map import shard_map as _shard_map
    from jax.sharding import Mesh as _Mesh
    from jax.sharding import PartitionSpec as _PSpec
    HAVE_SHARD_MAP = True
except ImportError:  # pragma: no cover - older jax
    HAVE_SHARD_MAP = False

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .lowering import _platform_tables, graph_tables
from .simulate_jax import _next_pow2, _surface_params, make_event_machine
from ..obs import get_registry, get_tracer

OBJECTIVES = ("latency", "throughput", "sum_inverse")
MIGRATIONS = ("auto", "island", "ring")
FANOUTS = ("auto", "shard_map", "pmap")

#: chains per island — the migration neighborhood.  Must divide both the
#: population and the chunk so islands never straddle a device call.
DEFAULT_ISLAND = 32
#: chains per device call; population shards into island-aligned chunks.
DEFAULT_CHUNK = 8192


def _require_jax() -> None:
    if not HAVE_JAX:  # pragma: no cover
        raise RuntimeError(
            "solver 'anneal' requires jax; install it or use "
            "solver='bb' / 'greedy'")


# ---------------------------------------------------------------------------
# SearchTables: the frozen device-side problem
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchTables:
    """Per-(workload, group, accelerator) lookup tables for one problem.

    ``gmax`` is padded to the next power of two so nearby graph depths
    share compiled executables; rows at ``i >= ngroups[m]`` are dead
    (``allowed`` all-False, never reached by the event machine).
    """

    acc_names: tuple[str, ...]
    w: int
    gmax: int
    amax: int
    dur_t: np.ndarray          # (w, gmax, A) ms; 0 where not allowed
    dem_t: np.ndarray          # (w, gmax, A) demand fraction
    allowed: np.ndarray        # (w, gmax, A) bool
    n_allowed: np.ndarray      # (w, gmax) int
    legal_after: np.ndarray    # (w, gmax) bool
    move_ms: np.ndarray        # (w, gmax) output move cost
    tau_pair: np.ndarray       # (A, A) fixed in+out transition cost
    ngroups: np.ndarray        # (w,) live groups per workload
    iters: np.ndarray          # (w,)
    dep: np.ndarray            # (w,) -1 = no dependency
    arrival: np.ndarray        # (w,) ms
    domshare: np.ndarray       # (A, A) contention-domain sharing
    model_of_acc: np.ndarray   # (A,) surface index, -1 = unmodeled
    models: tuple
    surfaces: tuple
    max_transitions: int

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(s.kind for s in self.surfaces)

    def decode(self, asg: np.ndarray) -> tuple[tuple[str, ...], ...]:
        """(w, gmax) index row -> per-workload accelerator-name tuples."""
        return tuple(
            tuple(self.acc_names[int(asg[m, i])]
                  for i in range(int(self.ngroups[m])))
            for m in range(self.w))

    def encode(self, assignments: Sequence[Sequence[str]]) -> np.ndarray:
        """Per-workload accelerator names -> a (w, gmax) index row."""
        idx = {a: j for j, a in enumerate(self.acc_names)}
        out = np.zeros((self.w, self.gmax), dtype=np.int32)
        for m, asg in enumerate(assignments):
            ng = int(self.ngroups[m])
            if len(asg) != ng:
                raise ValueError(
                    f"workload {m}: assignment has {len(asg)} groups, "
                    f"graph has {ng}")
            for i, a in enumerate(asg):
                out[m, i] = idx[a]
            if ng < self.gmax:
                out[m, ng:] = out[m, ng - 1]   # dead rows: repeat last acc
        return out

    def legal(self, asg: np.ndarray) -> bool:
        """Host mirror of the device legality predicate for one row."""
        for m in range(self.w):
            ng = int(self.ngroups[m])
            trans = 0
            for i in range(ng):
                if not self.allowed[m, i, int(asg[m, i])]:
                    return False
                if i + 1 < ng and asg[m, i] != asg[m, i + 1]:
                    if not self.legal_after[m, i]:
                        return False
                    trans += 1
            if trans > self.max_transitions:
                return False
        return True


def build_tables(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    max_transitions: int,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    arrival_ms: Sequence[float] | None = None,
) -> SearchTables:
    """Freeze one scheduling problem into device-search lookup tables."""
    acc_names, domshare, model_of_acc, models, surfaces = _platform_tables(
        platform, model)
    if any(s is None for s in surfaces):
        bad = sorted({type(m).__name__
                      for m, s in zip(models, surfaces) if s is None})
        raise ValueError(
            f"solver 'anneal' needs lowerable contention surfaces, but "
            f"{', '.join(bad)} has no registered surface lowering "
            f"(repro.core.lowering.register_surface_lowering); use "
            f"solver='bb' or 'greedy' for this model")
    w = len(graphs)
    if w == 0:
        raise ValueError("cannot search an empty problem")
    amax = len(acc_names)
    gmax = _next_pow2(max(len(g) for g in graphs))
    dur_t = np.zeros((w, gmax, amax))
    dem_t = np.zeros((w, gmax, amax))
    allowed = np.zeros((w, gmax, amax), dtype=bool)
    legal_after = np.zeros((w, gmax), dtype=bool)
    move_ms = np.zeros((w, gmax))
    tau_pair = np.zeros((amax, amax))
    ngroups = np.zeros(w, dtype=np.int64)
    for m, g in enumerate(graphs):
        ng = len(g)
        ngroups[m] = ng
        time_t, dem, legal, move, tp = graph_tables(platform, g)
        tau_pair = tp
        ok = ~np.isnan(time_t)
        if not ok.any(axis=1).all():
            i = int(np.flatnonzero(~ok.any(axis=1))[0])
            raise ValueError(
                f"graph {g.name!r}[{i}] runs on no accelerator of "
                f"platform {platform.name!r}")
        allowed[m, :ng] = ok
        dur_t[m, :ng] = np.nan_to_num(time_t)
        dem_t[m, :ng] = dem
        legal_after[m, :ng] = legal
        move_ms[m, :ng] = move
    its = np.asarray(list(iterations or [1] * w), dtype=np.int64)
    deps = np.asarray([-1 if d is None else int(d)
                       for d in (depends_on or [None] * w)], dtype=np.int64)
    arr = np.asarray(list(arrival_ms or [0.0] * w))
    return SearchTables(
        acc_names=acc_names, w=w, gmax=gmax, amax=amax,
        dur_t=dur_t, dem_t=dem_t, allowed=allowed,
        n_allowed=allowed.sum(axis=-1).astype(np.int64),
        legal_after=legal_after, move_ms=move_ms, tau_pair=tau_pair,
        ngroups=ngroups, iters=its, dep=deps, arrival=arr,
        domshare=domshare, model_of_acc=model_of_acc,
        models=models, surfaces=surfaces,
        max_transitions=int(max_transitions))


def _legal_rows(tables: SearchTables, asg: np.ndarray) -> np.ndarray:
    """Vectorized legality over a (P, w, gmax) batch of index rows."""
    w, gmax = tables.w, tables.gmax
    widx = np.arange(w)[None, :, None]
    gidx = np.arange(gmax)[None, None, :]
    live = gidx < tables.ngroups[None, :, None]
    ok = (tables.allowed[widx, gidx, asg] | ~live).all(axis=(1, 2))
    pair_live = (np.arange(1, gmax)[None, None, :]
                 < tables.ngroups[None, :, None])
    diff = (asg[:, :, 1:] != asg[:, :, :-1]) & pair_live
    ok &= ~(diff & ~tables.legal_after[None, :, :-1]).any(axis=(1, 2))
    ok &= (diff.sum(axis=2) <= tables.max_transitions).all(axis=1)
    return ok


def _scatter_population(tables: SearchTables, row: np.ndarray,
                        pop: int, seed: int) -> np.ndarray:
    """Diversify the initial population: chain 0 keeps ``row`` exactly
    (the never-regress anchor), every other chain takes a seeded random
    walk of legal single-site mutations so islands start in distinct
    basins instead of all climbing out of the same one.  Depends only on
    ``seed`` — chunking, backend, and precision cannot perturb it."""
    asg = np.repeat(row[None].astype(np.int32), pop, axis=0)
    if pop == 1:
        return asg
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x5eed]))
    sites = np.array([(m, i) for m in range(tables.w)
                      for i in range(int(tables.ngroups[m]))])
    for _ in range(max(4, 2 * len(sites))):
        pick = sites[rng.integers(0, len(sites), size=pop)]
        wi, gi = pick[:, 0], pick[:, 1]
        k = rng.integers(0, tables.n_allowed[wi, gi])
        acc = (np.cumsum(tables.allowed[wi, gi], axis=1)
               > k[:, None]).argmax(axis=1)
        prop = asg.copy()
        prop[np.arange(pop), wi, gi] = acc.astype(np.int32)
        ok = _legal_rows(tables, prop)
        asg[ok] = prop[ok]
    asg[0] = row
    return asg


def default_init(tables: SearchTables) -> np.ndarray:
    """A legal all-on-one-accelerator starting row: per workload, the
    everywhere-allowed accelerator with the smallest total duration."""
    out = np.zeros((tables.w, tables.gmax), dtype=np.int32)
    for m in range(tables.w):
        ng = int(tables.ngroups[m])
        everywhere = tables.allowed[m, :ng].all(axis=0)
        if not everywhere.any():
            raise ValueError(
                f"workload {m} has no accelerator allowed on every group; "
                f"pass an explicit init_assignment")
        total = np.where(everywhere, tables.dur_t[m, :ng].sum(axis=0),
                         np.inf)
        out[m, :] = int(np.argmin(total))
    return out


# ---------------------------------------------------------------------------
# the compiled search
# ---------------------------------------------------------------------------

def _make_run(w: int, gmax: int, amax: int, kinds: tuple[str, ...],
              obj_kind: str, island: int, backend: str,
              migrate: str = "island", ndev: int = 1,
              axis_name: str | None = None):
    """The (un-jitted) per-shard search program.

    ``migrate="island"`` is the legacy within-island elite fold;
    ``"ring"`` additionally donates each island's elite to the *next*
    island in the global island order at every exchange boundary — the
    cross-device seam travels by ``lax.ppermute`` over ``axis_name`` when
    the program runs as one shard of an ``ndev``-device mesh, and wraps
    locally when ``ndev == 1``.  All migration traffic is pure
    select/gather of already-computed values, so incumbents are
    bit-identical across device counts for a fixed total population.
    """
    from repro.kernels.search import anneal_select

    one = make_event_machine(kinds, 1, record=False)
    rows = jnp.arange(w)[:, None]
    cols = jnp.arange(gmax)[None, :]

    def run(tb, chain_idx, asg0, seed, n_steps, ex_every, t0, t1):
        dt = tb["dur_t"].dtype
        f32 = jnp.float32
        i32 = jnp.int32
        P = asg0.shape[0]
        nisl = P // island
        live = cols < tb["ngroups"][:, None]            # (w, gmax)
        iters_sum = jnp.sum(tb["iters"]).astype(dt)
        cum_live = jnp.cumsum(tb["ngroups"]).astype(i32)
        total_live = cum_live[-1]
        mt = jnp.asarray(tb["max_transitions"], i32)

        def gather(t, asg):
            return jnp.take_along_axis(t, asg[..., None], axis=-1)[..., 0]

        def legal_all(asg):
            alw = gather(tb["allowed"], asg)
            ok = jnp.all(alw | ~live)
            if gmax > 1:
                a0, a1 = asg[:, :-1], asg[:, 1:]
                moved = (a0 != a1) & live[:, 1:]
                ok &= jnp.all(~moved | tb["legal_after"][:, :-1])
                ok &= jnp.all(moved.sum(axis=1) <= mt)
            return ok

        def evaluate(asg):
            dur = gather(tb["dur_t"], asg)
            dem = gather(tb["dem_t"], asg)
            tau = jnp.zeros((w, gmax), dt)
            if gmax > 1:
                a0, a1 = asg[:, :-1], asg[:, 1:]
                moved = (a0 != a1) & live[:, 1:]
                tau = tau.at[:, :-1].set(jnp.where(
                    moved, tb["move_ms"][:, :-1] + tb["tau_pair"][a0, a1],
                    jnp.zeros((), dt)))
            finish, err = one(asg, dur, dem, tau, tb["ngroups"],
                              tb["iters"], tb["dep"], tb["arrival"],
                              tb["domshare"], tb["model_of_acc"], tb["surf"])
            if obj_kind == "latency":
                obj = jnp.max(finish)
            elif obj_kind == "throughput":
                mk = jnp.max(finish)
                obj = jnp.where(mk > 0, -1e3 * iters_sum / mk,
                                -jnp.asarray(jnp.inf, dt))
            else:  # sum_inverse
                obj = -jnp.sum(jnp.where(finish > 0, 1.0 / finish,
                                         jnp.zeros((), dt)))
            return jnp.where(err != 0, jnp.asarray(jnp.inf, dt), obj)

        def mutate(key, asg):
            ks, ka = jax.random.split(key)
            u = jax.random.randint(ks, (), 0, total_live)
            m = jnp.sum((u >= cum_live).astype(i32))
            prev = jnp.where(m > 0, cum_live[jnp.maximum(m - 1, 0)], 0)
            i = u - prev
            na = tb["n_allowed"][m, i]
            k = jax.random.randint(ka, (), 0, jnp.maximum(na, 1))
            hits = jnp.cumsum(tb["allowed"][m, i].astype(i32))
            a = jnp.argmax(hits > k).astype(asg.dtype)
            prop = jnp.where((rows == m) & (cols == i), a, asg)
            return jnp.where(legal_all(prop), prop, asg)

        base = jax.random.PRNGKey(seed)
        chain_keys = jax.vmap(
            lambda i: jax.random.fold_in(base, i))(chain_idx)

        obj0 = jax.vmap(evaluate)(asg0)
        state = dict(step=jnp.zeros((), i32), asg=asg0, obj=obj0,
                     best=asg0, best_obj=obj0)

        def cond(s):
            return s["step"] < n_steps

        def body(s):
            step = s["step"]
            keys = jax.vmap(
                lambda ck: jax.random.fold_in(ck, step))(chain_keys)
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            km, ku = ks[:, 0], ks[:, 1]       # mutation / accept draws
            prop = jax.vmap(mutate)(km, s["asg"])
            prop_obj = jax.vmap(evaluate)(prop)
            u = jax.vmap(
                lambda k: jax.random.uniform(k, (), f32))(ku).astype(dt)
            frac = step.astype(dt) / jnp.maximum(n_steps - 1, 1).astype(dt)
            temp = t0 * (t1 / t0) ** frac
            cur, curo, bst, bsto = anneal_select(
                s["asg"].reshape(P, w * gmax), prop.reshape(P, w * gmax),
                s["best"].reshape(P, w * gmax), s["obj"], prop_obj,
                s["best_obj"], u, temp, backend=backend,
                global_lanes=P * ndev)
            cur = cur.reshape(P, w, gmax)
            bst = bst.reshape(P, w, gmax)
            # elitist island migration: every ex_every steps the island's
            # best incumbent replaces its worst current member.
            do = (step + 1) % ex_every == 0
            obj_i = curo.reshape(nisl, island)
            bo_i = bsto.reshape(nisl, island)
            src = jnp.argmin(bo_i, axis=1)              # first-tie elite
            dst = jnp.argmax(obj_i, axis=1)             # worst current
            bst_i = bst.reshape(nisl, island, w, gmax)
            elite = jnp.take_along_axis(
                bst_i, src[:, None, None, None], axis=1)
            elite_obj = jnp.take_along_axis(bo_i, src[:, None], axis=1)
            repl = (jnp.arange(island)[None, :] == dst[:, None]) & do
            cur_i = jnp.where(repl[..., None, None],
                              elite, cur.reshape(nisl, island, w, gmax))
            obj_i = jnp.where(repl, elite_obj, obj_i)
            if migrate == "ring":
                # cross-island ring: island j's worst post-fold member is
                # replaced by the elite incumbent of island j-1 in the
                # *global* island order.  Only the seam (the last local
                # island's elite) crosses devices — a single ppermute —
                # so the injected values are identical however the global
                # island order is sharded.
                seam, seam_obj = elite[-1:], elite_obj[-1:]
                if axis_name is not None:
                    perm = [(i, (i + 1) % ndev) for i in range(ndev)]
                    seam = jax.lax.ppermute(seam, axis_name, perm)
                    seam_obj = jax.lax.ppermute(seam_obj, axis_name, perm)
                donor = jnp.concatenate([seam, elite[:-1]], axis=0)
                donor_obj = jnp.concatenate([seam_obj, elite_obj[:-1]],
                                            axis=0)
                dst2 = jnp.argmax(obj_i, axis=1)        # worst after fold
                repl2 = (jnp.arange(island)[None, :]
                         == dst2[:, None]) & do
                cur_i = jnp.where(repl2[..., None, None], donor, cur_i)
                obj_i = jnp.where(repl2, donor_obj, obj_i)
            return dict(step=step + 1, asg=cur_i.reshape(P, w, gmax),
                        obj=obj_i.reshape(P), best=bst, best_obj=bsto)

        out = jax.lax.while_loop(cond, body, state)
        return out["best_obj"], out["best"]

    return run


@functools.lru_cache(maxsize=None)
def _compiled_search(w: int, gmax: int, amax: int, kinds: tuple[str, ...],
                     obj_kind: str, island: int, backend: str):
    """One jitted device-resident search per (shape, kinds, objective,
    island, kernel-backend) layout; population size and dtype
    re-specialize through jit as usual."""
    return jax.jit(_make_run(w, gmax, amax, kinds, obj_kind, island,
                             backend))


@functools.lru_cache(maxsize=None)
def _compiled_mesh_search(w: int, gmax: int, amax: int,
                          kinds: tuple[str, ...], obj_kind: str,
                          island: int, backend: str, devices: int,
                          migrate: str, fanout: str):
    """The search fanned out over a 1-D device mesh.

    Returns ``(call_kind, fn)``: ``call_kind`` is ``"flat"`` when ``fn``
    takes the same globally-shaped arguments as the single-device run
    (jit / jit-of-shard_map) and ``"pmap"`` when the caller must reshape
    the sharded arguments to a leading ``devices`` axis.
    """
    if devices == 1:
        # one device needs no collective: the ring seam wraps locally.
        return "flat", jax.jit(_make_run(w, gmax, amax, kinds, obj_kind,
                                         island, backend, migrate=migrate))
    body = _make_run(w, gmax, amax, kinds, obj_kind, island, backend,
                     migrate=migrate, ndev=devices, axis_name="d")
    devs = jax.devices()[:devices]
    if fanout == "shard_map":
        mesh = _Mesh(np.array(devs), ("d",))
        sharded = _PSpec("d")
        repl = _PSpec()
        fn = _shard_map(
            body, mesh=mesh,
            in_specs=(repl, sharded, sharded, repl, repl, repl, repl, repl),
            out_specs=(sharded, sharded),
            # while_loop bodies have no replication rule; correctness of
            # the replicated outputs is by construction (pure per-shard).
            check_rep=False)
        return "flat", jax.jit(fn)
    fn = jax.pmap(body, axis_name="d", devices=devs,
                  in_axes=(None, 0, 0, None, None, None, None, None))
    return "pmap", fn


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchOutcome:
    """The device search's winner — device-reported, pre-authoritative."""

    assignment: tuple[tuple[str, ...], ...]
    objective: float            # device objective of the incumbent
    chain: int                  # global index of the winning chain
    evaluated: int              # event-machine evaluations performed
    population: int
    steps: int
    seed: int
    precision: str
    backend: str
    devices: int | None = None  # mesh width; None = legacy chunked path
    migrate: str = "island"     # resolved migration topology
    fanout: str | None = None   # resolved mesh fan-out (shard_map/pmap)


def _nearest_multiple(value: int, quantum: int) -> int:
    """The multiple of ``quantum`` nearest to ``value`` (>= quantum)."""
    lo = (value // quantum) * quantum
    hi = lo + quantum
    if lo < quantum:
        return hi
    return lo if (value - lo) <= (hi - value) else hi


def _validate_knobs(population: int, island: int, exchange_every: int,
                    steps: int, chunk: int | None, devices: int | None,
                    migrate: str, fanout: str) -> tuple[int | None, str, str]:
    """Fail fast on inconsistent knob combinations.

    Every rejection names the offending knob and the nearest legal value
    — nothing is silently rounded or truncated.  Returns the resolved
    ``(chunk, migrate, fanout)``.
    """
    if island < 1 or exchange_every < 1 or steps < 0 or population < 1:
        raise ValueError("population/steps/island/exchange_every must be "
                         "positive")
    if island > population:
        raise ValueError(
            f"island ({island}) exceeds population ({population}); "
            f"nearest legal value: island={population}")
    if population % island:
        raise ValueError(
            f"population ({population}) is not a multiple of island "
            f"({island}); nearest legal value: population="
            f"{_nearest_multiple(population, island)}")
    if migrate not in MIGRATIONS:
        raise ValueError(f"unknown migrate {migrate!r}; "
                         f"one of {', '.join(MIGRATIONS)}")
    if fanout not in FANOUTS:
        raise ValueError(f"unknown fanout {fanout!r}; "
                         f"one of {', '.join(FANOUTS)}")
    if devices is not None:
        if devices < 1:
            raise ValueError(f"devices ({devices}) must be >= 1")
        avail = jax.device_count()
        if devices > avail:
            raise ValueError(
                f"devices ({devices}) exceeds the {avail} visible jax "
                f"device(s); nearest legal value: devices={avail} "
                f"(emulate more host devices with "
                f"repro.core.xla_env.apply(devices=N) before jax "
                f"initializes)")
        quantum = island * devices
        if population % quantum:
            raise ValueError(
                f"population ({population}) is not a multiple of "
                f"island*devices ({quantum}); nearest legal value: "
                f"population={_nearest_multiple(population, quantum)}")
        if fanout == "shard_map" and not HAVE_SHARD_MAP:
            raise ValueError("fanout='shard_map' is unavailable in this "
                             "jax; nearest legal value: fanout='pmap'")
    else:
        if fanout != "auto":
            raise ValueError(
                f"fanout ({fanout!r}) requires devices=N (the mesh "
                f"path); nearest legal value: fanout='auto'")
        if migrate == "ring":
            raise ValueError(
                "migrate='ring' requires devices=N: the ring spans the "
                "global island order, which the legacy chunked path "
                "processes in separate device calls; nearest legal "
                "value: migrate='island'")
    if chunk is not None:
        if chunk < 1:
            raise ValueError(f"chunk ({chunk}) must be >= 1")
        if chunk % island:
            raise ValueError(
                f"chunk ({chunk}) must be a multiple of island "
                f"({island}): islands may not straddle device calls; "
                f"nearest legal value: chunk="
                f"{_nearest_multiple(chunk, island)}")
        if chunk > population:
            raise ValueError(
                f"chunk ({chunk}) exceeds population ({population}); "
                f"nearest legal value: chunk={population}")
    mig = migrate if migrate != "auto" else (
        "ring" if devices is not None else "island")
    fo = fanout
    if devices is not None and fo == "auto":
        fo = "shard_map" if HAVE_SHARD_MAP else "pmap"
    return chunk, mig, fo


def anneal_search(
    tables: SearchTables,
    *,
    objective: str = "latency",
    seed: int = 0,
    population: int = 1024,
    steps: int = 128,
    island: int = DEFAULT_ISLAND,
    exchange_every: int = 16,
    chunk: int | None = None,
    precision: str = "float32",
    backend: str = "auto",
    devices: int | None = None,
    migrate: str = "auto",
    fanout: str = "auto",
    init_assignment: np.ndarray | Sequence[Sequence[str]] | None = None,
    init_objective: float | None = None,
) -> SearchOutcome:
    """Run the device-resident annealing/genetic search over ``tables``.

    ``population`` chains (a multiple of ``island``) run ``steps``
    temperature steps each; ``chunk`` bounds the chains per device call
    and must be island-aligned (default: one full-population call, capped
    at :data:`DEFAULT_CHUNK`).  ``precision="float32"`` ranks in single
    precision (the default — cheap, and the selection order is what
    matters); ``"x64"`` evaluates in float64 inside a scoped
    ``enable_x64``.  ``backend`` selects the selection-kernel dispatch
    (``pallas`` / ``pallas_interpret`` / ``xla`` / ``auto``).

    ``devices=N`` fans the population out over a 1-D mesh of the first N
    visible jax devices (``fanout``: ``shard_map`` with a ``pmap``
    fallback) with ``migrate="ring"`` cross-device elite migration; the
    incumbent is then bit-identical for a fixed ``(seed, population,
    island, exchange_every)`` at *any* device count dividing the island
    count.  ``devices=None`` keeps the legacy sequential-chunk path
    (``migrate="island"``) byte-for-byte.

    The same ``(seed, population, steps, island, exchange_every)`` always
    explores the same chains and returns the bit-identical incumbent
    regardless of ``chunk``, ``fanout`` and selection-kernel backend.
    Inconsistent knob combinations raise ``ValueError`` naming the knob
    and the nearest legal value.
    """
    _require_jax()
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {', '.join(OBJECTIVES)}")
    if precision not in ("x64", "float32"):
        raise ValueError(f"unknown precision {precision!r} "
                         f"(expected 'x64' or 'float32')")
    chunk, migrate, fanout_r = _validate_knobs(
        population, island, exchange_every, steps, chunk, devices,
        migrate, fanout)
    pop = population
    if chunk is None:
        chunk = max(island, min((DEFAULT_CHUNK // island) * island, pop))

    if init_assignment is None:
        asg_row = default_init(tables)
    elif isinstance(init_assignment, np.ndarray):
        asg_row = np.asarray(init_assignment, dtype=np.int32)
        if asg_row.shape != (tables.w, tables.gmax):
            raise ValueError(
                f"init_assignment shape {asg_row.shape} != "
                f"{(tables.w, tables.gmax)}")
    else:
        asg_row = tables.encode(init_assignment)
    if not tables.legal(asg_row):
        raise ValueError("init_assignment is not a legal schedule "
                         "(allowed accelerators / transition budget)")

    # temperature scale: the initial objective when the caller knows it,
    # else a contention-free serial-latency proxy — only the *scale*
    # matters, the schedule is geometric between t0 and t1.
    if init_objective is not None and np.isfinite(init_objective):
        scale = abs(float(init_objective))
    else:
        scale = float(max(
            float(tables.iters[m]) * tables.dur_t[m, :, :].max(axis=-1).sum()
            for m in range(tables.w)))
    scale = max(scale, 1e-6)
    t0, t1 = 0.1 * scale, 1e-4 * scale

    best_objs = np.empty(pop)
    best_rows = np.empty((pop, tables.w, tables.gmax), dtype=np.int64)

    # the compiled program is looked up (and its closure constants
    # created) OUTSIDE any enable_x64 scope: the lru-cached executable is
    # shared between precision modes, so its captured index constants
    # must not inherit the first caller's x64 setting.
    if devices is None:
        kind, run = "chunked", _compiled_search(
            tables.w, tables.gmax, tables.amax, tables.kinds, objective,
            island, backend)
    else:
        kind, run = _compiled_mesh_search(
            tables.w, tables.gmax, tables.amax, tables.kinds, objective,
            island, backend, devices, migrate, fanout_r)

    tracer = get_tracer()

    def call():
        tb = _device_tables(tables)
        asg0_full = jnp.asarray(
            _scatter_population(tables, asg_row, pop, seed))
        args_tail = (seed, jnp.asarray(steps, jnp.int32),
                     jnp.asarray(exchange_every, jnp.int32),
                     jnp.asarray(float(t0)), jnp.asarray(float(t1)))
        if kind == "chunked":
            incumbent = np.inf
            for ci, lo in enumerate(range(0, pop, chunk)):
                hi = min(lo + chunk, pop)
                # chunk 0 pays any outstanding jit compile for this
                # (shape, objective, backend) — later chunks reuse the
                # executable, so their spans are pure steady state.
                with tracer.span("anneal.chunk", "search", chunk=ci,
                                 lo=lo, hi=hi,
                                 includes_compile=(ci == 0)) as sp:
                    bo, br = run(tb, jnp.arange(lo, hi, dtype=jnp.int32),
                                 asg0_full[lo:hi], *args_tail)
                    best_objs[lo:hi] = np.asarray(bo, dtype=np.float64)
                    best_rows[lo:hi] = np.asarray(br)
                if tracer.enabled:
                    chunk_objs = best_objs[lo:hi]
                    finite = chunk_objs[np.isfinite(chunk_objs)]
                    # per-move acceptance stays on-device; the fraction
                    # of chains that ended strictly better than the seed
                    # schedule is the host-visible acceptance proxy
                    # (feasible fraction when no seed objective is known).
                    if init_objective is not None and np.isfinite(
                            init_objective):
                        accepted = int((finite < init_objective).sum())
                    else:
                        accepted = int(finite.size)
                    sp.set(accept_rate=round(accepted / (hi - lo), 4))
                    if finite.size and float(finite.min()) < incumbent:
                        incumbent = float(finite.min())
                        tracer.instant(
                            "anneal.incumbent", "search",
                            objective=incumbent,
                            chain=int(lo + np.argmin(best_objs[lo:hi])))
            return
        chain_idx = jnp.arange(pop, dtype=jnp.int32)
        with tracer.span("anneal.mesh", "search", fanout=kind,
                         devices=devices):
            if kind == "pmap":
                per = pop // devices
                bo, br = run(tb, chain_idx.reshape(devices, per),
                             asg0_full.reshape(devices, per, tables.w,
                                               tables.gmax), *args_tail)
                bo = bo.reshape(pop)
                br = br.reshape(pop, tables.w, tables.gmax)
            else:
                bo, br = run(tb, chain_idx, asg0_full, *args_tail)
            best_objs[:] = np.asarray(bo, dtype=np.float64)
            best_rows[:] = np.asarray(br)

    with tracer.span("anneal_search", "search", population=pop,
                     steps=steps, island=island, seed=seed,
                     backend=backend, devices=devices,
                     objective=objective) as search_sp:
        if precision == "x64":
            with enable_x64():
                call()
        else:
            call()

        winner = int(np.argmin(best_objs))   # first min = lowest chain index
        if not np.isfinite(best_objs[winner]):
            raise RuntimeError(
                "device search found no feasible schedule (every chain "
                "error-poisoned); check the contention model coverage")
        search_sp.set(evaluated=pop * (steps + 1), chain=winner,
                      objective_value=float(best_objs[winner]))
    return SearchOutcome(
        assignment=tables.decode(best_rows[winner]),
        objective=float(best_objs[winner]),
        chain=winner,
        evaluated=pop * (steps + 1),
        population=pop,
        steps=steps,
        seed=seed,
        precision=precision,
        backend=backend,
        devices=devices,
        migrate=migrate,
        fanout=fanout_r if devices is not None else None,
    )


def _device_tables(tables: SearchTables) -> dict:
    """The frozen problem as the device-side pytree the search consumes."""
    return {
        "dur_t": jnp.asarray(tables.dur_t),
        "dem_t": jnp.asarray(tables.dem_t),
        "allowed": jnp.asarray(tables.allowed),
        "n_allowed": jnp.asarray(tables.n_allowed.astype(np.int32)),
        "legal_after": jnp.asarray(tables.legal_after),
        "move_ms": jnp.asarray(tables.move_ms),
        "tau_pair": jnp.asarray(tables.tau_pair),
        "ngroups": jnp.asarray(tables.ngroups.astype(np.int32)),
        "iters": jnp.asarray(tables.iters.astype(np.int32)),
        "dep": jnp.asarray(tables.dep.astype(np.int32)),
        "arrival": jnp.asarray(tables.arrival),
        "domshare": jnp.asarray(tables.domshare),
        "model_of_acc": jnp.asarray(tables.model_of_acc.astype(np.int32)),
        "max_transitions": jnp.asarray(tables.max_transitions, jnp.int32),
        "surf": tuple(_surface_params(s) for s in tables.surfaces),
    }


def compile_seconds(
    tables: SearchTables,
    *,
    objective: str = "latency",
    population: int = 1024,
    island: int = DEFAULT_ISLAND,
    backend: str = "auto",
    precision: str = "float32",
    devices: int | None = None,
    migrate: str = "auto",
    fanout: str = "auto",
) -> float:
    """Seconds to trace + lower + XLA-compile one search executable.

    Builds a *fresh* jitted program (bypassing every jit/lru cache) and
    times an explicit AOT ``lower(...).compile()`` for the exact argument
    shapes ``anneal_search`` would use — so repeated calls measure the
    same work and min-of-repeats is meaningful, unlike the legacy
    ``first_call_s - search_s`` single-sample attribution.
    """
    _require_jax()
    _, mig, fo = _validate_knobs(population, island, 16, 1, None, devices,
                                 migrate, fanout)

    def aot() -> float:
        tb = _device_tables(tables)
        asg0 = jnp.asarray(_scatter_population(
            tables, default_init(tables), population, 0))
        args_tail = (0, jnp.asarray(1, jnp.int32), jnp.asarray(1, jnp.int32),
                     jnp.asarray(1.0), jnp.asarray(1e-3))
        ndev = devices or 1
        if devices is not None and ndev > 1 and fo == "shard_map":
            body = _make_run(tables.w, tables.gmax, tables.amax,
                             tables.kinds, objective, island, backend,
                             migrate=mig, ndev=ndev, axis_name="d")
            mesh = _Mesh(np.array(jax.devices()[:ndev]), ("d",))
            fn = jax.jit(_shard_map(
                body, mesh=mesh,
                in_specs=(_PSpec(), _PSpec("d"), _PSpec("d"), _PSpec(),
                          _PSpec(), _PSpec(), _PSpec(), _PSpec()),
                out_specs=(_PSpec("d"), _PSpec("d")),
                check_rep=False))
        else:
            # pmap has no lower()/compile() AOT path; time the
            # single-shard executable (identical body) as its proxy.
            fn = jax.jit(_make_run(tables.w, tables.gmax, tables.amax,
                                   tables.kinds, objective, island, backend,
                                   migrate=mig))
        chain_idx = jnp.arange(population, dtype=jnp.int32)
        t0 = time.perf_counter()
        fn.lower(tb, chain_idx, asg0, *args_tail).compile()
        return time.perf_counter() - t0

    with get_tracer().span("search.compile", "search",
                           population=population, devices=devices or 1,
                           backend=backend) as sp:
        if precision == "x64":
            with enable_x64():
                dt = aot()
        else:
            dt = aot()
        sp.set(compile_s=round(dt, 6))
    # the gauge holds the most recent AOT measurement; bench_search reads
    # it (min-of-repeats over its own calls) instead of re-wall-timing.
    get_registry().gauge(
        "search_compile_s",
        "AOT lower+compile seconds of one search executable").set(dt)
    return dt

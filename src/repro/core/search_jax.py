"""Device-resident schedule search: annealing over the lowered array IR.

PR 4 made candidate *evaluation* device-resident
(:mod:`repro.core.simulate_jax`); the solver loop itself still generated
candidates on the host and round-tripped one population per batch.  This
module closes the loop: mutation, evaluation and selection all run inside
one ``lax.while_loop`` over frozen per-graph lookup tables, so the only
host<->device traffic per search is the initial tables down and the
per-chain incumbents back.

Structure:

* :class:`SearchTables` — the frozen device-side problem: per-graph
  (group, accelerator) duration/demand tables, legality masks, transition
  costs and the platform contention layout, built once from the same
  :func:`repro.core.lowering.graph_tables` the assignment lowering uses.
* :func:`anneal_search` — a population of chains walks the assignment
  space.  Each step every chain mutates one (workload, group) site to a
  random allowed accelerator (proposals that break transition legality
  revert to the current state), scores the mutant through the *lean*
  event machine (``make_event_machine(record=False)`` — identical event
  semantics to the jax evaluator, minus the observability state no
  ranking reads), and the population is selected by the Metropolis +
  incumbent kernel (:mod:`repro.kernels.search`).  Every
  ``exchange_every`` steps each island's best incumbent replaces its
  worst current member — the genetic/elitist migration that keeps deep
  islands from stagnating.

Determinism is by construction, not by luck:

* per-chain RNG streams are ``fold_in(fold_in(PRNGKey(seed),
  global_chain_index), step)`` — a chain's stream depends only on its
  global index, never on how the population was chunked across device
  calls;
* islands are fixed ``island``-sized slices of the global chain order and
  chunk boundaries must align to them (``chunk % island == 0``), so
  migration sees the same members regardless of chunking;
* uniform draws are taken in float32 in *both* precision modes, so the
  accept decisions of ``precision="float32"`` and ``"x64"`` diverge only
  where the objectives themselves do;
* the global winner is the (objective, chain index) lexicographic min —
  first-found wins ties.

The scalar simulator stays authoritative: this module reports the device
incumbent and its device objective; :mod:`repro.core.solver_anneal`
re-simulates the winner on the host scalar path before any
:class:`~repro.core.plan.Plan` is minted.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    HAVE_JAX = True
except ImportError:  # pragma: no cover - the container ships jax
    HAVE_JAX = False

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .lowering import _platform_tables, graph_tables
from .simulate_jax import _next_pow2, _surface_params, make_event_machine

OBJECTIVES = ("latency", "throughput", "sum_inverse")

#: chains per island — the migration neighborhood.  Must divide both the
#: population and the chunk so islands never straddle a device call.
DEFAULT_ISLAND = 32
#: chains per device call; population shards into island-aligned chunks.
DEFAULT_CHUNK = 8192


def _require_jax() -> None:
    if not HAVE_JAX:  # pragma: no cover
        raise RuntimeError(
            "solver 'anneal' requires jax; install it or use "
            "solver='bb' / 'greedy'")


# ---------------------------------------------------------------------------
# SearchTables: the frozen device-side problem
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchTables:
    """Per-(workload, group, accelerator) lookup tables for one problem.

    ``gmax`` is padded to the next power of two so nearby graph depths
    share compiled executables; rows at ``i >= ngroups[m]`` are dead
    (``allowed`` all-False, never reached by the event machine).
    """

    acc_names: tuple[str, ...]
    w: int
    gmax: int
    amax: int
    dur_t: np.ndarray          # (w, gmax, A) ms; 0 where not allowed
    dem_t: np.ndarray          # (w, gmax, A) demand fraction
    allowed: np.ndarray        # (w, gmax, A) bool
    n_allowed: np.ndarray      # (w, gmax) int
    legal_after: np.ndarray    # (w, gmax) bool
    move_ms: np.ndarray        # (w, gmax) output move cost
    tau_pair: np.ndarray       # (A, A) fixed in+out transition cost
    ngroups: np.ndarray        # (w,) live groups per workload
    iters: np.ndarray          # (w,)
    dep: np.ndarray            # (w,) -1 = no dependency
    arrival: np.ndarray        # (w,) ms
    domshare: np.ndarray       # (A, A) contention-domain sharing
    model_of_acc: np.ndarray   # (A,) surface index, -1 = unmodeled
    models: tuple
    surfaces: tuple
    max_transitions: int

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(s.kind for s in self.surfaces)

    def decode(self, asg: np.ndarray) -> tuple[tuple[str, ...], ...]:
        """(w, gmax) index row -> per-workload accelerator-name tuples."""
        return tuple(
            tuple(self.acc_names[int(asg[m, i])]
                  for i in range(int(self.ngroups[m])))
            for m in range(self.w))

    def encode(self, assignments: Sequence[Sequence[str]]) -> np.ndarray:
        """Per-workload accelerator names -> a (w, gmax) index row."""
        idx = {a: j for j, a in enumerate(self.acc_names)}
        out = np.zeros((self.w, self.gmax), dtype=np.int32)
        for m, asg in enumerate(assignments):
            ng = int(self.ngroups[m])
            if len(asg) != ng:
                raise ValueError(
                    f"workload {m}: assignment has {len(asg)} groups, "
                    f"graph has {ng}")
            for i, a in enumerate(asg):
                out[m, i] = idx[a]
            if ng < self.gmax:
                out[m, ng:] = out[m, ng - 1]   # dead rows: repeat last acc
        return out

    def legal(self, asg: np.ndarray) -> bool:
        """Host mirror of the device legality predicate for one row."""
        for m in range(self.w):
            ng = int(self.ngroups[m])
            trans = 0
            for i in range(ng):
                if not self.allowed[m, i, int(asg[m, i])]:
                    return False
                if i + 1 < ng and asg[m, i] != asg[m, i + 1]:
                    if not self.legal_after[m, i]:
                        return False
                    trans += 1
            if trans > self.max_transitions:
                return False
        return True


def build_tables(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    max_transitions: int,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    arrival_ms: Sequence[float] | None = None,
) -> SearchTables:
    """Freeze one scheduling problem into device-search lookup tables."""
    acc_names, domshare, model_of_acc, models, surfaces = _platform_tables(
        platform, model)
    if any(s is None for s in surfaces):
        bad = sorted({type(m).__name__
                      for m, s in zip(models, surfaces) if s is None})
        raise ValueError(
            f"solver 'anneal' needs lowerable contention surfaces, but "
            f"{', '.join(bad)} has no registered surface lowering "
            f"(repro.core.lowering.register_surface_lowering); use "
            f"solver='bb' or 'greedy' for this model")
    w = len(graphs)
    if w == 0:
        raise ValueError("cannot search an empty problem")
    amax = len(acc_names)
    gmax = _next_pow2(max(len(g) for g in graphs))
    dur_t = np.zeros((w, gmax, amax))
    dem_t = np.zeros((w, gmax, amax))
    allowed = np.zeros((w, gmax, amax), dtype=bool)
    legal_after = np.zeros((w, gmax), dtype=bool)
    move_ms = np.zeros((w, gmax))
    tau_pair = np.zeros((amax, amax))
    ngroups = np.zeros(w, dtype=np.int64)
    for m, g in enumerate(graphs):
        ng = len(g)
        ngroups[m] = ng
        time_t, dem, legal, move, tp = graph_tables(platform, g)
        tau_pair = tp
        ok = ~np.isnan(time_t)
        if not ok.any(axis=1).all():
            i = int(np.flatnonzero(~ok.any(axis=1))[0])
            raise ValueError(
                f"graph {g.name!r}[{i}] runs on no accelerator of "
                f"platform {platform.name!r}")
        allowed[m, :ng] = ok
        dur_t[m, :ng] = np.nan_to_num(time_t)
        dem_t[m, :ng] = dem
        legal_after[m, :ng] = legal
        move_ms[m, :ng] = move
    its = np.asarray(list(iterations or [1] * w), dtype=np.int64)
    deps = np.asarray([-1 if d is None else int(d)
                       for d in (depends_on or [None] * w)], dtype=np.int64)
    arr = np.asarray(list(arrival_ms or [0.0] * w))
    return SearchTables(
        acc_names=acc_names, w=w, gmax=gmax, amax=amax,
        dur_t=dur_t, dem_t=dem_t, allowed=allowed,
        n_allowed=allowed.sum(axis=-1).astype(np.int64),
        legal_after=legal_after, move_ms=move_ms, tau_pair=tau_pair,
        ngroups=ngroups, iters=its, dep=deps, arrival=arr,
        domshare=domshare, model_of_acc=model_of_acc,
        models=models, surfaces=surfaces,
        max_transitions=int(max_transitions))


def _legal_rows(tables: SearchTables, asg: np.ndarray) -> np.ndarray:
    """Vectorized legality over a (P, w, gmax) batch of index rows."""
    w, gmax = tables.w, tables.gmax
    widx = np.arange(w)[None, :, None]
    gidx = np.arange(gmax)[None, None, :]
    live = gidx < tables.ngroups[None, :, None]
    ok = (tables.allowed[widx, gidx, asg] | ~live).all(axis=(1, 2))
    pair_live = (np.arange(1, gmax)[None, None, :]
                 < tables.ngroups[None, :, None])
    diff = (asg[:, :, 1:] != asg[:, :, :-1]) & pair_live
    ok &= ~(diff & ~tables.legal_after[None, :, :-1]).any(axis=(1, 2))
    ok &= (diff.sum(axis=2) <= tables.max_transitions).all(axis=1)
    return ok


def _scatter_population(tables: SearchTables, row: np.ndarray,
                        pop: int, seed: int) -> np.ndarray:
    """Diversify the initial population: chain 0 keeps ``row`` exactly
    (the never-regress anchor), every other chain takes a seeded random
    walk of legal single-site mutations so islands start in distinct
    basins instead of all climbing out of the same one.  Depends only on
    ``seed`` — chunking, backend, and precision cannot perturb it."""
    asg = np.repeat(row[None].astype(np.int32), pop, axis=0)
    if pop == 1:
        return asg
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x5eed]))
    sites = np.array([(m, i) for m in range(tables.w)
                      for i in range(int(tables.ngroups[m]))])
    for _ in range(max(4, 2 * len(sites))):
        pick = sites[rng.integers(0, len(sites), size=pop)]
        wi, gi = pick[:, 0], pick[:, 1]
        k = rng.integers(0, tables.n_allowed[wi, gi])
        acc = (np.cumsum(tables.allowed[wi, gi], axis=1)
               > k[:, None]).argmax(axis=1)
        prop = asg.copy()
        prop[np.arange(pop), wi, gi] = acc.astype(np.int32)
        ok = _legal_rows(tables, prop)
        asg[ok] = prop[ok]
    asg[0] = row
    return asg


def default_init(tables: SearchTables) -> np.ndarray:
    """A legal all-on-one-accelerator starting row: per workload, the
    everywhere-allowed accelerator with the smallest total duration."""
    out = np.zeros((tables.w, tables.gmax), dtype=np.int32)
    for m in range(tables.w):
        ng = int(tables.ngroups[m])
        everywhere = tables.allowed[m, :ng].all(axis=0)
        if not everywhere.any():
            raise ValueError(
                f"workload {m} has no accelerator allowed on every group; "
                f"pass an explicit init_assignment")
        total = np.where(everywhere, tables.dur_t[m, :ng].sum(axis=0),
                         np.inf)
        out[m, :] = int(np.argmin(total))
    return out


# ---------------------------------------------------------------------------
# the compiled search
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _compiled_search(w: int, gmax: int, amax: int, kinds: tuple[str, ...],
                     obj_kind: str, island: int, backend: str):
    """One jitted device-resident search per (shape, kinds, objective,
    island, kernel-backend) layout; population size and dtype
    re-specialize through jit as usual."""
    from repro.kernels.search import anneal_select

    one = make_event_machine(kinds, 1, record=False)
    rows = jnp.arange(w)[:, None]
    cols = jnp.arange(gmax)[None, :]

    @jax.jit
    def run(tb, chain_idx, asg0, seed, n_steps, ex_every, t0, t1):
        dt = tb["dur_t"].dtype
        f32 = jnp.float32
        i32 = jnp.int32
        P = asg0.shape[0]
        nisl = P // island
        live = cols < tb["ngroups"][:, None]            # (w, gmax)
        iters_sum = jnp.sum(tb["iters"]).astype(dt)
        cum_live = jnp.cumsum(tb["ngroups"]).astype(i32)
        total_live = cum_live[-1]
        mt = jnp.asarray(tb["max_transitions"], i32)

        def gather(t, asg):
            return jnp.take_along_axis(t, asg[..., None], axis=-1)[..., 0]

        def legal_all(asg):
            alw = gather(tb["allowed"], asg)
            ok = jnp.all(alw | ~live)
            if gmax > 1:
                a0, a1 = asg[:, :-1], asg[:, 1:]
                moved = (a0 != a1) & live[:, 1:]
                ok &= jnp.all(~moved | tb["legal_after"][:, :-1])
                ok &= jnp.all(moved.sum(axis=1) <= mt)
            return ok

        def evaluate(asg):
            dur = gather(tb["dur_t"], asg)
            dem = gather(tb["dem_t"], asg)
            tau = jnp.zeros((w, gmax), dt)
            if gmax > 1:
                a0, a1 = asg[:, :-1], asg[:, 1:]
                moved = (a0 != a1) & live[:, 1:]
                tau = tau.at[:, :-1].set(jnp.where(
                    moved, tb["move_ms"][:, :-1] + tb["tau_pair"][a0, a1],
                    jnp.zeros((), dt)))
            finish, err = one(asg, dur, dem, tau, tb["ngroups"],
                              tb["iters"], tb["dep"], tb["arrival"],
                              tb["domshare"], tb["model_of_acc"], tb["surf"])
            if obj_kind == "latency":
                obj = jnp.max(finish)
            elif obj_kind == "throughput":
                mk = jnp.max(finish)
                obj = jnp.where(mk > 0, -1e3 * iters_sum / mk,
                                -jnp.asarray(jnp.inf, dt))
            else:  # sum_inverse
                obj = -jnp.sum(jnp.where(finish > 0, 1.0 / finish,
                                         jnp.zeros((), dt)))
            return jnp.where(err != 0, jnp.asarray(jnp.inf, dt), obj)

        def mutate(key, asg):
            ks, ka = jax.random.split(key)
            u = jax.random.randint(ks, (), 0, total_live)
            m = jnp.sum((u >= cum_live).astype(i32))
            prev = jnp.where(m > 0, cum_live[jnp.maximum(m - 1, 0)], 0)
            i = u - prev
            na = tb["n_allowed"][m, i]
            k = jax.random.randint(ka, (), 0, jnp.maximum(na, 1))
            hits = jnp.cumsum(tb["allowed"][m, i].astype(i32))
            a = jnp.argmax(hits > k).astype(asg.dtype)
            prop = jnp.where((rows == m) & (cols == i), a, asg)
            return jnp.where(legal_all(prop), prop, asg)

        base = jax.random.PRNGKey(seed)
        chain_keys = jax.vmap(
            lambda i: jax.random.fold_in(base, i))(chain_idx)

        obj0 = jax.vmap(evaluate)(asg0)
        state = dict(step=jnp.zeros((), i32), asg=asg0, obj=obj0,
                     best=asg0, best_obj=obj0)

        def cond(s):
            return s["step"] < n_steps

        def body(s):
            step = s["step"]
            keys = jax.vmap(
                lambda ck: jax.random.fold_in(ck, step))(chain_keys)
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            km, ku = ks[:, 0], ks[:, 1]       # mutation / accept draws
            prop = jax.vmap(mutate)(km, s["asg"])
            prop_obj = jax.vmap(evaluate)(prop)
            u = jax.vmap(
                lambda k: jax.random.uniform(k, (), f32))(ku).astype(dt)
            frac = step.astype(dt) / jnp.maximum(n_steps - 1, 1).astype(dt)
            temp = t0 * (t1 / t0) ** frac
            cur, curo, bst, bsto = anneal_select(
                s["asg"].reshape(P, w * gmax), prop.reshape(P, w * gmax),
                s["best"].reshape(P, w * gmax), s["obj"], prop_obj,
                s["best_obj"], u, temp, backend=backend)
            cur = cur.reshape(P, w, gmax)
            bst = bst.reshape(P, w, gmax)
            # elitist island migration: every ex_every steps the island's
            # best incumbent replaces its worst current member.
            do = (step + 1) % ex_every == 0
            obj_i = curo.reshape(nisl, island)
            bo_i = bsto.reshape(nisl, island)
            src = jnp.argmin(bo_i, axis=1)              # first-tie elite
            dst = jnp.argmax(obj_i, axis=1)             # worst current
            bst_i = bst.reshape(nisl, island, w, gmax)
            elite = jnp.take_along_axis(
                bst_i, src[:, None, None, None], axis=1)
            elite_obj = jnp.take_along_axis(bo_i, src[:, None], axis=1)
            repl = (jnp.arange(island)[None, :] == dst[:, None]) & do
            cur_i = jnp.where(repl[..., None, None],
                              elite, cur.reshape(nisl, island, w, gmax))
            obj_i = jnp.where(repl, elite_obj, obj_i)
            return dict(step=step + 1, asg=cur_i.reshape(P, w, gmax),
                        obj=obj_i.reshape(P), best=bst, best_obj=bsto)

        out = jax.lax.while_loop(cond, body, state)
        return out["best_obj"], out["best"]

    return run


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchOutcome:
    """The device search's winner — device-reported, pre-authoritative."""

    assignment: tuple[tuple[str, ...], ...]
    objective: float            # device objective of the incumbent
    chain: int                  # global index of the winning chain
    evaluated: int              # event-machine evaluations performed
    population: int
    steps: int
    seed: int
    precision: str
    backend: str


def anneal_search(
    tables: SearchTables,
    *,
    objective: str = "latency",
    seed: int = 0,
    population: int = 1024,
    steps: int = 128,
    island: int = DEFAULT_ISLAND,
    exchange_every: int = 16,
    chunk: int = DEFAULT_CHUNK,
    precision: str = "float32",
    backend: str = "auto",
    init_assignment: np.ndarray | Sequence[Sequence[str]] | None = None,
    init_objective: float | None = None,
) -> SearchOutcome:
    """Run the device-resident annealing/genetic search over ``tables``.

    ``population`` chains (rounded up to a multiple of ``island``) run
    ``steps`` temperature steps each; ``chunk`` bounds the chains per
    device call and must be island-aligned.  ``precision="float32"``
    ranks in single precision (the default — cheap, and the selection
    order is what matters); ``"x64"`` evaluates in float64 inside a
    scoped ``enable_x64``.  ``backend`` selects the selection-kernel
    dispatch (``pallas`` / ``pallas_interpret`` / ``xla`` / ``auto``).

    The same ``(seed, population, steps, island, exchange_every)`` always
    explores the same chains and returns the bit-identical incumbent
    regardless of ``chunk`` and selection-kernel backend.
    """
    _require_jax()
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {', '.join(OBJECTIVES)}")
    if precision not in ("x64", "float32"):
        raise ValueError(f"unknown precision {precision!r} "
                         f"(expected 'x64' or 'float32')")
    if island < 1 or exchange_every < 1 or steps < 0 or population < 1:
        raise ValueError("population/steps/island/exchange_every must be "
                         "positive")
    if chunk % island:
        raise ValueError(
            f"chunk ({chunk}) must be a multiple of island ({island}): "
            f"islands may not straddle device calls")
    pop = ((population + island - 1) // island) * island

    if init_assignment is None:
        asg_row = default_init(tables)
    elif isinstance(init_assignment, np.ndarray):
        asg_row = np.asarray(init_assignment, dtype=np.int32)
        if asg_row.shape != (tables.w, tables.gmax):
            raise ValueError(
                f"init_assignment shape {asg_row.shape} != "
                f"{(tables.w, tables.gmax)}")
    else:
        asg_row = tables.encode(init_assignment)
    if not tables.legal(asg_row):
        raise ValueError("init_assignment is not a legal schedule "
                         "(allowed accelerators / transition budget)")

    # temperature scale: the initial objective when the caller knows it,
    # else a contention-free serial-latency proxy — only the *scale*
    # matters, the schedule is geometric between t0 and t1.
    if init_objective is not None and np.isfinite(init_objective):
        scale = abs(float(init_objective))
    else:
        scale = float(max(
            float(tables.iters[m]) * tables.dur_t[m, :, :].max(axis=-1).sum()
            for m in range(tables.w)))
    scale = max(scale, 1e-6)
    t0, t1 = 0.1 * scale, 1e-4 * scale

    run = _compiled_search(tables.w, tables.gmax, tables.amax, tables.kinds,
                           objective, island, backend)

    best_objs = np.empty(pop)
    best_rows = np.empty((pop, tables.w, tables.gmax), dtype=np.int64)

    def call():
        tb = {
            "dur_t": jnp.asarray(tables.dur_t),
            "dem_t": jnp.asarray(tables.dem_t),
            "allowed": jnp.asarray(tables.allowed),
            "n_allowed": jnp.asarray(tables.n_allowed.astype(np.int32)),
            "legal_after": jnp.asarray(tables.legal_after),
            "move_ms": jnp.asarray(tables.move_ms),
            "tau_pair": jnp.asarray(tables.tau_pair),
            "ngroups": jnp.asarray(tables.ngroups.astype(np.int32)),
            "iters": jnp.asarray(tables.iters.astype(np.int32)),
            "dep": jnp.asarray(tables.dep.astype(np.int32)),
            "arrival": jnp.asarray(tables.arrival),
            "domshare": jnp.asarray(tables.domshare),
            "model_of_acc": jnp.asarray(
                tables.model_of_acc.astype(np.int32)),
            "max_transitions": jnp.asarray(tables.max_transitions,
                                           jnp.int32),
            "surf": tuple(_surface_params(s) for s in tables.surfaces),
        }
        asg0_full = jnp.asarray(
            _scatter_population(tables, asg_row, pop, seed))
        for lo in range(0, pop, chunk):
            hi = min(lo + chunk, pop)
            bo, br = run(tb, jnp.arange(lo, hi, dtype=jnp.int32),
                         asg0_full[lo:hi], seed, jnp.asarray(steps,
                         jnp.int32), jnp.asarray(exchange_every, jnp.int32),
                         jnp.asarray(float(t0)), jnp.asarray(float(t1)))
            best_objs[lo:hi] = np.asarray(bo, dtype=np.float64)
            best_rows[lo:hi] = np.asarray(br)

    if precision == "x64":
        with enable_x64():
            call()
    else:
        call()

    winner = int(np.argmin(best_objs))     # first min = lowest chain index
    if not np.isfinite(best_objs[winner]):
        raise RuntimeError(
            "device search found no feasible schedule (every chain "
            "error-poisoned); check the contention model coverage")
    return SearchOutcome(
        assignment=tables.decode(best_rows[winner]),
        objective=float(best_objs[winner]),
        chain=winner,
        evaluated=pop * (steps + 1),
        population=pop,
        steps=steps,
        seed=seed,
        precision=precision,
        backend=backend,
    )

"""XLA runtime tuning applied *before* the JAX backend initializes.

The device-resident search (:mod:`repro.core.search_jax`) fans its island
population out over a 1-D device mesh; how many devices exist — and how
well XLA overlaps their collectives — is decided by process-wide XLA
flags that must be in the environment before the first backend use:

* ``--xla_force_host_platform_device_count=N`` splits the host CPU
  backend into N emulated devices.  This is how CI (and any CPU-only
  host) exercises the real ``shard_map``/``ppermute`` lowering of the
  multi-device search: the N shards are genuine XLA partitions, they just
  time-share the host cores.
* the GPU latency-hiding / async-collective flags (:data:`GPU_FLAGS`)
  let the per-device annealing loop overlap its elite-migration
  collectives with compute on real multi-GPU hosts.

``import jax`` alone does *not* initialize the backend — flags applied
from ``main()`` before the first ``jax.devices()``/array op still take
effect — but a flag applied after initialization is silently inert, so
:func:`apply` warns loudly in that case instead of pretending.

Idempotent by construction: re-applying replaces a stale setting of the
same flag instead of appending a duplicate, and unrelated user-set
``XLA_FLAGS`` entries are preserved.
"""
from __future__ import annotations

import os
import sys
from typing import Iterable, MutableMapping

from ..obs import get_logger
log = get_logger(__name__)

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"

#: GPU runtime-tuning flags (SNIPPETS.md exemplar set): overlap the
#: mesh-search collectives with compute and keep triton fusions on.
GPU_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flag_name(token: str) -> str:
    return token.split("=", 1)[0]


def backend_initialized() -> bool:
    """Best-effort probe: has this process already created XLA backends?

    Reads jax's private backend table without *triggering* initialization
    (``jax.devices()`` would).  Unknown jax internals degrade to False —
    the caller then proceeds and XLA itself decides.
    """
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return bool(getattr(xla_bridge, "_backends", None))
    except Exception:  # pragma: no cover - jax internals moved
        return False


def merge_flags(existing: str, new: Iterable[str]) -> str:
    """Merge flag tokens into an ``XLA_FLAGS`` string; new settings win.

    Tokens are whitespace-separated ``--flag=value`` entries; a new token
    replaces any existing token with the same flag name, everything else
    is preserved in order.
    """
    new = list(new)
    names = {_flag_name(t) for t in new}
    kept = [t for t in existing.split() if _flag_name(t) not in names]
    return " ".join(kept + new)


def apply(devices: int | None = None, gpu: bool = False,
          extra: Iterable[str] = (),
          env: MutableMapping[str, str] = os.environ) -> str:
    """Install the requested XLA flags into ``env["XLA_FLAGS"]``.

    ``devices=N`` emulates N host-platform devices (CPU backends);
    ``gpu=True`` adds :data:`GPU_FLAGS`; ``extra`` appends verbatim
    tokens.  Returns the resulting ``XLA_FLAGS`` value.  When mutating
    this process's own ``os.environ``, warns (but still writes — a later
    subprocess inherits the env) if the backend is already initialized
    and cannot pick the flags up; copies built for subprocesses
    (:func:`subprocess_env`) stay silent.
    """
    flags: list[str] = []
    if devices is not None:
        devices = int(devices)
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        flags.append(f"{HOST_DEVICE_FLAG}={devices}")
    if gpu:
        flags.extend(GPU_FLAGS)
    flags.extend(extra)
    merged = merge_flags(env.get("XLA_FLAGS", ""), flags)
    env["XLA_FLAGS"] = merged
    if flags and env is os.environ and backend_initialized():
        log.warning(
            "XLA backend already initialized in this process; XLA_FLAGS "
            "%s will only affect subprocesses (apply before the first "
            "jax.devices()/array operation)", " ".join(flags))
    return merged


def device_count() -> int:
    """Visible jax devices (initializes the backend — call after apply)."""
    import jax
    return jax.device_count()


def subprocess_env(devices: int, gpu: bool = False,
                   base: MutableMapping[str, str] | None = None) -> dict:
    """A copy of ``base`` (default ``os.environ``) with the flags merged —
    for launching workers that must see an N-device host backend."""
    env = dict(os.environ if base is None else base)
    apply(devices=devices, gpu=gpu, env=env)
    return env

"""Layer grouping (§3.1): raw layers -> atomic schedulable layer groups.

Three grouping rules from the paper:
  1. *Preserve layer optimizations*: spans the framework would fuse
     (conv+bn+relu, attention qkv+softmax+proj, matmul+bias+act) must stay on
     one accelerator — fused layers merge into one group.
  2. *Avoid input/output reformatting*: boundaries whose tensor layout
     differs between accelerators pay a reformat penalty; layers flagged
     ``reformat_after`` are merged forward unless the boundary is also a
     natural (e.g. post-pooling, small-tensor) transition point.
  3. *Accelerator/software limitations*: boundaries after which a framework
     forbids transitions (TensorRT: no DLA->GPU right after Eltwise) collapse
     the boundary entirely.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

from .graph import DNNGraph, LayerGroup


@dataclass(frozen=True)
class RawLayer:
    """One framework-level layer before grouping."""

    name: str
    kind: str                       # conv / pool / fc / eltwise / attn / ...
    times: Mapping[str, float]
    mem_demand: Mapping[str, float] = field(default_factory=dict)
    out_bytes: float = 0.0
    #: rule 1 — this layer fuses with its successor.
    fuse_with_next: bool = False
    #: rule 3 — framework forbids an inter-accelerator transition after it.
    no_transition_after: bool = False
    #: rule 2 — transitioning here inserts a costly reformat.
    reformat_after: bool = False


#: layer kinds after which transitions are naturally cheap (small outputs,
#: pipeline-friendly — the paper observes pooling boundaries transition ~5x
#: cheaper, Table 2 groups 39-53 / 95-109).
CHEAP_BOUNDARY_KINDS = frozenset({"pool", "globalpool", "fc", "norm"})


def group_layers(name: str, layers: Sequence[RawLayer]) -> DNNGraph:
    """Apply rules 1-3 to produce the minimal atomic layer groups."""
    if not layers:
        raise ValueError("no layers")
    groups: list[list[RawLayer]] = []
    cur: list[RawLayer] = []
    for i, layer in enumerate(layers):
        cur.append(layer)
        last = i == len(layers) - 1
        if last:
            groups.append(cur)
            break
        if layer.fuse_with_next or layer.no_transition_after:
            continue                                  # rules 1 & 3: merge on
        if layer.reformat_after and layer.kind not in CHEAP_BOUNDARY_KINDS:
            continue                                  # rule 2: merge on
        groups.append(cur)
        cur = []

    out: list[LayerGroup] = []
    for gi, span in enumerate(groups):
        accs = set(span[0].times)
        for l in span[1:]:
            accs &= set(l.times)
        if not accs:
            raise ValueError(
                f"group {gi} of {name} has no common accelerator")
        times = {a: sum(l.times[a] for l in span) for a in accs}
        demand = {
            a: (sum(l.mem_demand.get(a, 0.0) * l.times[a] for l in span)
                / times[a] if times[a] else 0.0)
            for a in accs
        }
        out.append(LayerGroup(
            name=f"{span[0].name}..{span[-1].name}" if len(span) > 1
                 else span[0].name,
            times=times,
            mem_demand=demand,
            out_bytes=span[-1].out_bytes,
            can_transition_after=gi < len(groups) - 1 or True,
        ))
    return DNNGraph(name, tuple(out))

"""Analytic (roofline) per-layer characterization (§3.2 decoupled step 1).

On the paper's SoCs, per-layer standalone times and memory throughputs come
from one-time offline profiling (TensorRT IProfiler / EMC counters).  On the
TPU target — where this container has no real hardware — the equivalent
one-time characterization is *analytic*: each layer group carries FLOPs, HBM
bytes and cross-boundary collective bytes extracted from the compiled dry-run
(`compiled.cost_analysis()` + HLO collective parsing), and its standalone
time on a virtual accelerator is the roofline maximum of the three terms.
The requested demand on the shared contention domain is the group's achieved
byte rate on that domain divided by the domain capacity — exactly the paper's
"requested memory throughput (%)" but derived instead of measured.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from .accelerators import MS, Accelerator, Platform
from .graph import DNNGraph, LayerGroup


@dataclass(frozen=True)
class GroupCosts:
    """Hardware-independent cost description of one layer group."""

    name: str
    flops: float
    hbm_bytes: float
    #: bytes this group moves over the shared contention domain while
    #: executing (collectives on a pod; DRAM traffic on an SoC where the
    #: shared domain *is* the memory path).
    shared_bytes: float | None = None
    #: activation bytes crossing a transition boundary after the group.
    out_bytes: float = 0.0
    can_transition_after: bool = True


def roofline_time_ms(costs: GroupCosts, acc: Accelerator,
                     compute_eff: float = 0.8,
                     domain_bw: float | None = None) -> float:
    """Standalone time = max(compute, memory, shared-path) roofline terms."""
    t_compute = costs.flops / (acc.peak_flops * compute_eff)
    t_memory = costs.hbm_bytes / acc.mem_bw
    t_shared = 0.0
    if costs.shared_bytes and domain_bw:
        t_shared = costs.shared_bytes / domain_bw
    return max(t_compute, t_memory, t_shared) / MS


def characterize(
    name: str,
    platform: Platform,
    costs: Sequence[GroupCosts],
    compute_eff: float | Mapping[str, float] = 0.8,
    domain: str | None = None,
) -> DNNGraph:
    """Build a schedulable :class:`DNNGraph` from analytic group costs."""
    if domain is None and platform.domains:
        domain = next(iter(platform.domains))
    dom_bw = platform.domain_bw.get(domain) if domain else None
    dom_members = platform.domains.get(domain, ()) if domain else ()

    groups = []
    for c in costs:
        times: dict[str, float] = {}
        demand: dict[str, float] = {}
        for acc in platform.accelerators:
            eff = (compute_eff.get(acc.name, 0.8)
                   if isinstance(compute_eff, Mapping) else compute_eff)
            t_ms = roofline_time_ms(c, acc, eff, dom_bw)
            times[acc.name] = t_ms
            if dom_bw and acc.name in dom_members and t_ms > 0:
                shared = (c.shared_bytes if c.shared_bytes is not None
                          else c.hbm_bytes)
                demand[acc.name] = min(1.5, (shared / (t_ms * MS)) / dom_bw)
        groups.append(LayerGroup(
            name=c.name, times=times, mem_demand=demand,
            out_bytes=c.out_bytes,
            can_transition_after=c.can_transition_after,
            flops=c.flops, hbm_bytes=c.hbm_bytes,
        ))
    return DNNGraph(name, tuple(groups))

"""Vectorized batch timeline evaluation: N candidate schedules in one pass.

The exact event-driven simulator in :mod:`repro.core.simulate` is the
authoritative evaluator of the paper's Eq. 2-8 timeline, but it walks one
candidate schedule at a time — and schedule *search* (greedy hill climb,
branch-and-bound sibling scoring, the Table-8 exhaustive sweep) is bounded
by how many candidates it can score per second.  This module evaluates a
whole population of candidates simultaneously by running the same
event-driven state machine in *lockstep across candidates*: every piece of
per-workload simulator state becomes an array over ``candidates ×
workloads``, and each loop iteration advances every still-running candidate
to its own next event with a fixed number of NumPy kernels.  Interpreter
overhead is paid once per event *wave* instead of once per event per
candidate, which is where the >=10x candidate-evaluation throughput comes
from (see ``benchmarks/bench_simulate.py`` / ``BENCH_simulate.json``).

Semantics are bit-for-bit the scalar simulator's modulo floating-point
summation order (guarded to 1e-6 by ``tests/test_simulate_differential.py``):

  * one layer group per accelerator at a time, FIFO by (ready time, index);
  * inter-accelerator transitions delay the workload without occupying
    either accelerator;
  * contention intervals integrate ``1 / slowdown(own, external)`` progress
    between events, with external demand summed over shared domains;
  * multi-iteration workloads, ``depends_on`` pipelines and ``arrival_ms``
    offsets behave identically.

The scalar simulator remains *authoritative*: solvers that search with the
batch evaluator re-simulate their final incumbent through
:func:`repro.core.simulate.simulate` before returning it, so a plan's
recorded result never depends on this fast path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel, PiecewiseModel, ProportionalShareModel
from .graph import DNNGraph
from .simulate import SimResult, Workload, validate_assignment

_TOL = 1e-9   # must match simulate._TOL: the differential contract depends
              # on both simulators resolving events at the same threshold.


# ---------------------------------------------------------------------------
# vectorized slowdown surfaces
# ---------------------------------------------------------------------------

#: cls -> fn(model, own: ndarray, ext: ndarray) -> ndarray.  Third-party
#: contention models register here to stay on the fast path; anything
#: unregistered falls back to an elementwise call of ``model.slowdown``.
_VECTORIZED: dict[type, Callable[[Any, np.ndarray, np.ndarray], np.ndarray]] = {}


def register_vectorized_slowdown(
        cls: type,
        fn: Callable[[Any, np.ndarray, np.ndarray], np.ndarray],
        replace: bool = False) -> None:
    """Register a NumPy implementation of ``cls.slowdown`` for the batch path."""
    if cls in _VECTORIZED and not replace:
        raise ValueError(f"vectorized slowdown for {cls.__name__} already "
                         f"registered")
    _VECTORIZED[cls] = fn


def _proportional_share(m: ProportionalShareModel, own: np.ndarray,
                        ext: np.ndarray) -> np.ndarray:
    own = np.maximum(0.0, own)
    ext = np.maximum(0.0, ext)
    total = own + ext
    boundedness = np.minimum(1.0, own / m.capacity)
    dilation = total / m.capacity
    s = 1.0 + m.sensitivity * boundedness * (dilation - 1.0)
    return np.where((own == 0.0) | (total <= m.capacity), 1.0, s)


def _locate_batch(knots: np.ndarray, x: np.ndarray):
    """Vectorized PiecewiseModel._locate: (lo, hi, w) per element."""
    n = len(knots)
    hi = np.searchsorted(knots, x, side="right")
    lo = np.clip(hi - 1, 0, n - 1)
    hi = np.clip(hi, 0, n - 1)
    below = x <= knots[0]
    above = x >= knots[-1]
    lo = np.where(below, 0, np.where(above, n - 1, lo))
    hi = np.where(below, 0, np.where(above, n - 1, hi))
    denom = knots[hi] - knots[lo]
    with np.errstate(invalid="ignore", divide="ignore"):
        w = np.where(denom > 0, (x - knots[lo]) / np.where(denom > 0, denom, 1.0),
                     0.0)
    w = np.where(below | above, 0.0, w)
    return lo, hi, w


def _piecewise(m: PiecewiseModel, own: np.ndarray,
               ext: np.ndarray) -> np.ndarray:
    ok = np.asarray(m.own_knots, dtype=float)
    ek = np.asarray(m.ext_knots, dtype=float)
    table = np.asarray(m.table, dtype=float)
    i0, i1, wi = _locate_batch(ok, own)
    j0, j1, wj = _locate_batch(ek, ext)
    v0 = table[i0, j0] * (1 - wj) + table[i0, j1] * wj
    v1 = table[i1, j0] * (1 - wj) + table[i1, j1] * wj
    s = v0 * (1 - wi) + v1 * wi
    return np.where((own <= 0.0) | (ext <= 0.0), 1.0, s)


register_vectorized_slowdown(ProportionalShareModel, _proportional_share)
register_vectorized_slowdown(PiecewiseModel, _piecewise)


def slowdown_array(model: Any, own: np.ndarray, ext: np.ndarray) -> np.ndarray:
    """Vectorized ``model.slowdown`` over equal-shaped demand arrays.

    Uses the registered NumPy surface when the model class has one and an
    elementwise fallback otherwise — slower, but any object with a scalar
    ``slowdown`` stays usable (and *correct*) from every batch call site.
    """
    fn = _VECTORIZED.get(type(model))
    if fn is not None:
        return fn(model, own, ext)
    flat_own = np.asarray(own, dtype=float).ravel()
    flat_ext = np.asarray(ext, dtype=float).ravel()
    out = np.fromiter((model.slowdown(float(o), float(e))
                       for o, e in zip(flat_own, flat_ext)),
                      dtype=float, count=flat_own.size)
    return out.reshape(np.shape(own))


# ---------------------------------------------------------------------------
# BatchTimeline
# ---------------------------------------------------------------------------

@dataclass
class BatchTimeline:
    """Per-candidate timeline results of one :func:`simulate_batch` call.

    Arrays are indexed ``[candidate]`` / ``[candidate, workload]``; iteration
    latencies are padded with NaN beyond each workload's iteration count.
    """

    #: (N,) total schedule span per candidate (max workload finish time).
    makespan: np.ndarray
    #: (N, W) completion time of every workload.
    finish_times: np.ndarray
    #: (N, W, max_iters) per-iteration service latency, NaN-padded.
    iteration_latencies: np.ndarray
    #: (N, W) number of iterations each workload ran.
    iterations: np.ndarray
    #: (N,) wall-clock ms added purely by contention per candidate.
    contention_ms: np.ndarray
    #: (N, A) contention-free busy ms per accelerator.
    busy_ms: np.ndarray
    #: accelerator names indexing the last axis of ``busy_ms``.
    acc_names: tuple[str, ...]

    def __len__(self) -> int:
        return int(self.makespan.shape[0])

    @property
    def n_candidates(self) -> int:
        return len(self)

    @property
    def throughput_fps(self) -> np.ndarray:
        """(N,) completed DNN inferences per second per candidate."""
        n = self.iterations.sum(axis=1)
        with np.errstate(divide="ignore"):
            fps = np.where(self.makespan > 0, 1e3 * n / self.makespan,
                           np.inf)
        return fps

    def objective(self, kind: str) -> np.ndarray:
        """(N,) solver objective per candidate; lower is better for every
        kind — mirrors :meth:`repro.core.simulate.SimResult.objective`."""
        if kind == "latency":
            return self.makespan.copy()
        if kind == "throughput":
            return -self.throughput_fps
        if kind == "sum_inverse":
            with np.errstate(divide="ignore"):
                inv = np.where(self.finish_times > 0,
                               1.0 / self.finish_times, 0.0)
            return -inv.sum(axis=1)
        raise ValueError(kind)

    def argbest(self, kind: str) -> int:
        """Index of the best candidate (first among exact ties)."""
        return int(np.argmin(self.objective(kind)))

    def result(self, i: int) -> SimResult:
        """Extract candidate ``i`` as a scalar-shaped :class:`SimResult`.

        The interval-level ``timeline`` is not materialized by the batch
        path (it exists to explain one schedule, not to rank thousands);
        re-simulate the winner through the authoritative scalar simulator
        when a Gantt-grade timeline is needed.
        """
        lats = [
            [float(x) for x in row[:int(self.iterations[i, n])]]
            for n, row in enumerate(self.iteration_latencies[i])
        ]
        return SimResult(
            makespan=float(self.makespan[i]),
            finish_times=[float(x) for x in self.finish_times[i]],
            iteration_latencies=lats,
            timeline=[],
            contention_ms=float(self.contention_ms[i]),
            busy_ms={a: float(self.busy_ms[i, j])
                     for j, a in enumerate(self.acc_names)},
        )

    def results(self) -> list[SimResult]:
        return [self.result(i) for i in range(len(self))]


def batch_from_results(results: Sequence[SimResult],
                       acc_names: Sequence[str]) -> BatchTimeline:
    """Assemble a :class:`BatchTimeline` from scalar :class:`SimResult`s.

    This is the "scalar" evaluator's batch implementation: every call site
    written against the batch interface can fall back to the authoritative
    simulator without changing shape.
    """
    n = len(results)
    w = max((len(r.finish_times) for r in results), default=0)
    maxit = max((max((len(l) for l in r.iteration_latencies), default=0)
                 for r in results), default=0)
    lat = np.full((n, w, max(maxit, 1)), np.nan)
    its = np.zeros((n, w), dtype=np.int64)
    fin = np.zeros((n, w))
    for i, r in enumerate(results):
        fin[i, :len(r.finish_times)] = r.finish_times
        for j, l in enumerate(r.iteration_latencies):
            its[i, j] = len(l)
            lat[i, j, :len(l)] = l
    return BatchTimeline(
        makespan=np.array([r.makespan for r in results]),
        finish_times=fin,
        iteration_latencies=lat,
        iterations=its,
        contention_ms=np.array([r.contention_ms for r in results]),
        busy_ms=np.array([[r.busy_ms.get(a, 0.0) for a in acc_names]
                          for r in results]),
        acc_names=tuple(acc_names),
    )


# ---------------------------------------------------------------------------
# packing: Workload lists -> dense candidate arrays
# ---------------------------------------------------------------------------

class _Packed:
    """Dense array form of a candidate population (all float64/int64)."""

    __slots__ = ("n", "w", "gmax", "amax", "acc", "dur", "dem", "tau",
                 "ngroups", "iters", "dep", "arrival", "acc_names",
                 "domshare", "model_of_acc", "models")

    def __init__(self, platform: Platform, n: int, w: int, gmax: int,
                 model: ContentionModel | Mapping[str, ContentionModel]):
        acc_names = list(platform.names)
        acc_idx = {a: j for j, a in enumerate(acc_names)}
        self.n, self.w, self.gmax = n, w, gmax
        self.amax = len(acc_names)
        self.acc_names = tuple(acc_names)
        self.acc = np.zeros((n, w, gmax), dtype=np.int64)
        self.dur = np.zeros((n, w, gmax))
        self.dem = np.zeros((n, w, gmax))
        self.tau = np.zeros((n, w, gmax))
        self.ngroups = np.zeros((n, w), dtype=np.int64)
        self.iters = np.ones((n, w), dtype=np.int64)
        self.dep = np.full((n, w), -1, dtype=np.int64)
        self.arrival = np.zeros((n, w))

        # domain-share matrix: domshare[a, b] = number of contention domains
        # containing both accelerators (diagonal zero) — external demand seen
        # by a layer on `a` is sum_b demand_b * domshare[a, b], replicating
        # the scalar simulator's per-domain accumulation.
        ds = np.zeros((self.amax, self.amax))
        for members in platform.domains.values():
            idxs = [acc_idx[m] for m in members]
            for i in idxs:
                for j in idxs:
                    if i != j:
                        ds[i, j] += 1.0
        self.domshare = ds

        # per-accelerator contention model (the scalar simulator uses the
        # model of the accelerator's *first* domain).
        if hasattr(model, "slowdown"):
            models: dict[str, Any] = {d: model for d in platform.domains}
            if not models:
                models = {"_": model}
        else:
            models = dict(model)  # type: ignore[arg-type]
        first_domain: dict[str, str] = {}
        for dom, members in platform.domains.items():
            for m in members:
                first_domain.setdefault(m, dom)
        self.models = []
        self.model_of_acc = np.full(self.amax, -1, dtype=np.int64)
        seen: dict[int, int] = {}
        for j, a in enumerate(acc_names):
            dom = first_domain.get(a)
            if dom is None:
                continue  # never contends: slowdown is never evaluated
            mod = models.get(dom)
            if mod is None:
                # scalar simulate would KeyError on first contention; defer
                # identically by leaving the slot unmodeled.
                continue
            key = id(mod)
            if key not in seen:
                seen[key] = len(self.models)
                self.models.append(mod)
            self.model_of_acc[j] = seen[key]


def _pack_workloads(platform: Platform,
                    workloads_batch: Sequence[Sequence[Workload]],
                    model: ContentionModel | Mapping[str, ContentionModel],
                    validate: bool) -> _Packed:
    """Generic packing: per-candidate Workload lists (graphs may differ)."""
    acc_idx = {a: j for j, a in enumerate(platform.names)}
    n = len(workloads_batch)
    w = len(workloads_batch[0])
    for c, wls in enumerate(workloads_batch):
        if len(wls) != w:
            raise ValueError(
                f"candidate {c} has {len(wls)} workloads, expected {w} "
                f"(all candidates of a batch share the workload count)")
    gmax = max(len(wl.graph) for wls in workloads_batch for wl in wls)
    p = _Packed(platform, n, w, gmax, model)
    for c, wls in enumerate(workloads_batch):
        for m, wl in enumerate(wls):
            if validate:
                validate_assignment(platform, wl)
            g = wl.graph
            ng = len(g)
            p.ngroups[c, m] = ng
            p.iters[c, m] = wl.iterations
            p.dep[c, m] = -1 if wl.depends_on is None else wl.depends_on
            p.arrival[c, m] = wl.arrival_ms
            asg = wl.assignment
            for i in range(ng):
                a = asg[i]
                p.acc[c, m, i] = acc_idx[a]
                p.dur[c, m, i] = g[i].time_on(a)
                p.dem[c, m, i] = g[i].demand_on(a)
                if i + 1 < ng:
                    p.tau[c, m, i] = platform.transition_cost_ms(
                        g[i].out_bytes, a, asg[i + 1])
    return p


def _graph_arrays(platform: Platform, g: DNNGraph,
                  arr: np.ndarray, validate: bool):
    """Vectorized per-graph fill: assignment string array (K, len(g)) ->
    (acc idx, duration, demand, post-group transition delay) arrays."""
    names = list(platform.names)
    a_cnt = len(names)
    ng = len(g)
    if arr.shape[1:] != (ng,):
        raise ValueError(
            f"graph {g.name!r}: assignment shape {arr.shape} != (*, {ng})")
    time_t = np.full((ng, a_cnt), np.nan)
    dem_t = np.zeros((ng, a_cnt))
    legal = np.zeros(ng, dtype=bool)
    out_b = np.zeros(ng)
    for i, grp in enumerate(g):
        legal[i] = grp.can_transition_after
        out_b[i] = grp.out_bytes
        for a, tv in grp.times.items():
            if a in names:
                time_t[i, names.index(a)] = float(tv)
        for a, dv in grp.mem_demand.items():
            if a in names:
                dem_t[i, names.index(a)] = float(dv)
    tau_pair = np.zeros((a_cnt, a_cnt))
    for si, src in enumerate(names):
        for di, dst in enumerate(names):
            if si != di:
                tau_pair[si, di] = (platform.acc(src).transition_out_ms
                                    + platform.acc(dst).transition_in_ms)
    move = (out_b / platform.transition_bw / 1e-3
            if platform.transition_bw else np.zeros(ng))

    sorted_names = sorted(names)
    to_idx = np.argsort(np.array(names))            # sorted pos -> acc index
    pos = np.clip(np.searchsorted(sorted_names, arr), 0, a_cnt - 1)
    idx = to_idx[pos]
    if validate and not (np.asarray(names)[idx] == arr).all():
        bad = arr[np.asarray(names)[idx] != arr].ravel()[0]
        raise ValueError(f"{g.name}: unknown accelerator {bad!r}")
    gi = np.arange(ng)
    dur = time_t[gi[None, :], idx]
    if validate and np.isnan(dur).any():
        ci, gix = np.nonzero(np.isnan(dur))
        raise ValueError(
            f"{g.name}[{gix[0]}] cannot run on {arr[ci[0], gix[0]]!r}")
    dem = dem_t[gi[None, :], idx]
    tau = np.zeros_like(dur)
    if ng > 1:
        moved = idx[:, :-1] != idx[:, 1:]
        if validate and (moved & ~legal[None, :-1]).any():
            ci, gix = np.nonzero(moved & ~legal[None, :-1])
            raise ValueError(
                f"{g.name}: illegal transition after group {gix[0]} "
                f"({g[gix[0]].name})")
        tau[:, :-1] = np.where(
            moved, move[None, :-1] + tau_pair[idx[:, :-1], idx[:, 1:]], 0.0)
    return idx, np.nan_to_num(dur), dem, tau


def _set_static_columns(p: _Packed, iterations: Sequence[int],
                        depends_on: Sequence[int | None]) -> None:
    p.iters[:] = np.asarray(list(iterations), dtype=np.int64)[None, :]
    p.dep[:] = np.asarray([-1 if d is None else d for d in depends_on],
                          dtype=np.int64)[None, :]


def _pack_assignments(platform: Platform, graphs: Sequence[DNNGraph],
                      assignments_batch: Sequence[Sequence[Sequence[str]]],
                      model: ContentionModel | Mapping[str, ContentionModel],
                      iterations: Sequence[int],
                      depends_on: Sequence[int | None],
                      validate: bool) -> _Packed:
    """Solver hot-path packing: fixed graphs, N assignment vectors.

    Per-graph (group, accelerator) lookup tables are built once and every
    candidate is filled by vectorized gathers — no per-candidate Python
    loop, which is what keeps huge sweeps pack-bound on NumPy rather than
    the interpreter.
    """
    n = len(assignments_batch)
    w = len(graphs)
    gmax = max(len(g) for g in graphs)
    p = _Packed(platform, n, w, gmax, model)
    _set_static_columns(p, iterations, depends_on)
    for m, g in enumerate(graphs):
        ng = len(g)
        p.ngroups[:, m] = ng
        arr = np.asarray([asgs[m] for asgs in assignments_batch])
        idx, dur, dem, tau = _graph_arrays(platform, g, arr, validate)
        p.acc[:, m, :ng] = idx
        p.dur[:, m, :ng] = dur
        p.dem[:, m, :ng] = dem
        p.tau[:, m, :ng] = tau
    return p


def _pack_product(platform: Platform, graphs: Sequence[DNNGraph],
                  cand_lists: Sequence[Sequence[Sequence[str]]],
                  model: ContentionModel | Mapping[str, ContentionModel],
                  iterations: Sequence[int],
                  depends_on: Sequence[int | None],
                  validate: bool) -> _Packed:
    """Pack the full cross product of per-graph candidate lists without
    materializing the combinations: each graph's unique assignments are
    packed once, then broadcast into the product in ``itertools.product``
    order by pure index arithmetic."""
    w = len(graphs)
    ks = [len(c) for c in cand_lists]
    n = 1
    for k in ks:
        n *= k
    gmax = max(len(g) for g in graphs)
    p = _Packed(platform, n, w, gmax, model)
    _set_static_columns(p, iterations, depends_on)
    after = n
    for m, g in enumerate(graphs):
        ng = len(g)
        p.ngroups[:, m] = ng
        arr = np.asarray(list(cand_lists[m]))
        idx, dur, dem, tau = _graph_arrays(platform, g, arr, validate)
        # itertools.product order: graph m's index repeats `after` times
        # within one period and the whole period tiles `before` times.
        after //= ks[m]
        sel = np.tile(np.repeat(np.arange(ks[m]), after), n // (ks[m] * after))
        p.acc[:, m, :ng] = idx[sel]
        p.dur[:, m, :ng] = dur[sel]
        p.dem[:, m, :ng] = dem[sel]
        p.tau[:, m, :ng] = tau[sel]
    return p


# ---------------------------------------------------------------------------
# the lockstep event loop
# ---------------------------------------------------------------------------

def _empty_batch(platform: Platform) -> BatchTimeline:
    return BatchTimeline(
        makespan=np.zeros(0), finish_times=np.zeros((0, 0)),
        iteration_latencies=np.zeros((0, 0, 1)),
        iterations=np.zeros((0, 0), dtype=np.int64),
        contention_ms=np.zeros(0),
        busy_ms=np.zeros((0, len(platform.names))),
        acc_names=tuple(platform.names))


def simulate_batch(
    platform: Platform,
    workloads_batch: Sequence[Sequence[Workload]],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
) -> BatchTimeline:
    """Simulate N candidate schedules in one vectorized pass.

    ``workloads_batch[c]`` is candidate ``c``'s workload list; candidates
    must agree on the number of workloads but may differ in assignments,
    graphs, iterations, dependencies and arrival offsets.  Returns a
    :class:`BatchTimeline` whose per-candidate values match the scalar
    simulator within floating-point summation order (see
    ``tests/test_simulate_differential.py``).
    """
    if len(workloads_batch) == 0:
        return _empty_batch(platform)
    return _run(_pack_workloads(platform, workloads_batch, model, validate))


def _col_reduce(ufunc, arr: np.ndarray) -> np.ndarray:
    """Reduce (N, W) along axis 1 via W-1 vectorized column ops.

    NumPy's ``arr.min(axis=1)``/``.any(axis=1)`` degenerate to a Python-side
    outer loop when the reduced axis is tiny (W is 2-4 here) — column-wise
    reduction keeps every op SIMD-width over N instead.
    """
    if arr.shape[1] == 1:
        return arr[:, 0].copy()    # never alias mutable state
    out = ufunc(arr[:, 0], arr[:, 1])
    for j in range(2, arr.shape[1]):
        out = ufunc(out, arr[:, j])
    return out


def _run(p: _Packed) -> BatchTimeline:
    n, w, a_cnt = p.n, p.w, p.amax
    n0 = n
    rows = np.arange(n)
    #: live position -> original candidate id (identity until compaction).
    orig = np.arange(n)

    # mutable per-(candidate, workload) state — the scalar _WorkloadState
    # fields as arrays.  cur_acc/own are maintained incrementally (they only
    # change at group/iteration boundaries) to keep the per-wave kernel
    # count down.
    group = np.zeros((n, w), dtype=np.int64)
    cur_acc = p.acc[:, :, 0].copy()
    own = p.dem[:, :, 0].copy()
    remaining = p.dur[:, :, 0].copy()
    ready = p.arrival.copy()
    it = np.zeros((n, w), dtype=np.int64)
    it_start = p.arrival.copy()
    started = np.zeros((n, w), dtype=bool)
    done = np.zeros((n, w), dtype=bool)
    is_run = np.zeros((n, w), dtype=bool)
    run_wl = np.full((n, a_cnt), -1, dtype=np.int64)
    t = np.zeros(n)

    # outputs stay full-size, indexed by original candidate id.
    max_it = int(p.iters.max())
    iters_full = p.iters.copy()
    finish = np.zeros((n0, w))
    lat = np.full((n0, w, max_it), np.nan)
    contention = np.zeros(n0)
    busy = np.zeros((n0, a_cnt))

    # same guard shape as the scalar simulator, summed across the batch
    # (each lockstep wave advances at least one event or idle jump in every
    # still-alive candidate).
    per_cand = 200000 + 200 * (p.ngroups * p.iters).sum(axis=1)
    max_waves = int(per_cand.sum())
    guard = 0

    inf = np.inf
    alive = ~done.all(axis=1)
    n_alive = n
    while n_alive:
        guard += 1
        if guard > max_waves:
            raise RuntimeError("batch simulator did not converge "
                               "(event storm)")

        if n >= 1024 and n_alive <= n // 2:
            # compact: candidates finish at wildly different wave counts in
            # heterogeneous sweeps; dropping finished rows keeps every wave
            # proportional to live work instead of the original batch size.
            keep = np.nonzero(alive)[0]
            orig = orig[keep]
            t = t[keep]
            group, cur_acc, own = group[keep], cur_acc[keep], own[keep]
            remaining, ready = remaining[keep], ready[keep]
            it, it_start = it[keep], it_start[keep]
            started, done, is_run = started[keep], done[keep], is_run[keep]
            run_wl = run_wl[keep]
            alive = alive[keep]
            p.acc, p.dur = p.acc[keep], p.dur[keep]
            p.dem, p.tau = p.dem[keep], p.tau[keep]
            p.ngroups, p.iters = p.ngroups[keep], p.iters[keep]
            p.dep, p.arrival = p.dep[keep], p.arrival[keep]
            n = len(keep)
            rows = np.arange(n)

        # 1) FIFO claim: eligible waiting workloads sorted by (ready, idx)
        # take their accelerator if free.
        dep_row = np.clip(p.dep, 0, w - 1)
        dep_ok = ((p.dep < 0)
                  | done[rows[:, None], dep_row]
                  | (it[rows[:, None], dep_row] > it))
        eligible = (alive[:, None] & ~done & ~is_run & dep_ok
                    & (ready <= t[:, None] + _TOL))
        if eligible.any():
            key = np.where(eligible, ready, inf)
            if w == 2:
                # stable (ready, idx) order without an axis-1 argsort
                second_first = key[:, 1] < key[:, 0]
                order = np.empty((n, 2), dtype=np.int64)
                order[:, 0] = second_first
                order[:, 1] = ~second_first
            else:
                order = np.argsort(key, axis=1, kind="stable")
            for r in range(w):
                w_r = order[:, r]
                el = eligible[rows, w_r]
                if not el.any():
                    continue
                a_r = cur_acc[rows, w_r]
                claim = el & (run_wl[rows, a_r] < 0)
                if claim.any():
                    cc = rows[claim]
                    run_wl[cc, a_r[claim]] = w_r[claim]
                    is_run[cc, w_r[claim]] = True
                    fresh = (claim & (group[rows, w_r] == 0)
                             & ~started[rows, w_r])
                    if fresh.any():
                        fc = rows[fresh]
                        it_start[fc, w_r[fresh]] = t[fresh]
                        started[fc, w_r[fresh]] = True

        any_run = _col_reduce(np.logical_or, is_run)
        idle = alive & ~any_run
        if idle.any():
            # idle gap: jump those candidates to their next arrival /
            # transition / dependency boundary (they re-claim next wave,
            # exactly like the scalar simulator's `continue`) while every
            # running candidate still integrates this wave.
            pend = np.where(~done & (ready > t[:, None] + _TOL), ready, inf)
            tmin = _col_reduce(np.minimum, pend)
            if not np.isfinite(tmin[idle]).all():
                raise RuntimeError(
                    "deadlock: nothing running, nothing pending")
            t = np.where(idle, tmin, t)
            if not any_run.any():
                continue

        # 2) per-interval slowdowns — computed on the 1-D running-entry
        # vectors (rc, rw), not full (N, W) planes.  One accelerator runs
        # at most one layer, so per-(candidate, acc) demand needs no
        # accumulation: plain fancy assignment is collision-free.
        rc, rw = np.nonzero(is_run)
        run_acc = cur_acc[rc, rw]
        own_run = own[rc, rw]
        acc_dem = np.zeros((n, a_cnt))
        acc_dem[rc, run_acc] = own_run
        # external demand visible from acc a = sum_b domshare[a, b]·demand_b
        ext_run = (acc_dem @ p.domshare.T)[rc, run_acc]
        s_run = np.ones(len(rc))
        contended = (own_run > 0.0) & (ext_run > 0.0)
        if contended.any():
            macc = np.where(contended, p.model_of_acc[run_acc], -1)
            for mid, mod in enumerate(p.models):
                m2 = macc == mid
                if m2.any():
                    s_run[m2] = np.maximum(
                        1.0, slowdown_array(mod, own_run[m2], ext_run[m2]))
            if (contended & (macc < 0)).any():
                bad = int(run_acc[np.nonzero(contended & (macc < 0))[0][0]])
                raise KeyError(
                    f"no contention model covers accelerator "
                    f"{p.acc_names[bad]!r}")

        # 3) next event horizon: earliest running completion, capped by any
        # ready/arrival boundary strictly inside the interval.
        rem_run = remaining[rc, rw]
        run_rem = np.full((n, w), inf)
        run_rem[rc, rw] = rem_run * s_run
        dt = _col_reduce(np.minimum, run_rem)
        horizon = t + dt
        cap = _col_reduce(np.minimum, np.where(
            ~done & ~is_run & (ready > t[:, None] + _TOL)
            & (ready < horizon[:, None] - _TOL),
            ready, inf))
        horizon = np.minimum(horizon, cap)

        # 4) integrate the contention interval.
        span_run = (horizon - t)[rc]
        prog = span_run / s_run
        rem_run = rem_run - prog
        remaining[rc, rw] = rem_run
        np.add.at(contention, orig[rc], span_run * (1.0 - 1.0 / s_run))
        busy[orig[rc], run_acc] += prog   # collision-free: one layer per acc
        t = np.where(alive & any_run, horizon, t)

        # 5) process completions.
        fin_run = rem_run <= _TOL
        if fin_run.any():
            cc, cw = rc[fin_run], rw[fin_run]
            run_wl[cc, run_acc[fin_run]] = -1
            is_run[cc, cw] = False

            g_cur = group[cc, cw]
            has_next = g_cur + 1 < p.ngroups[cc, cw]
            if has_next.any():
                hc, hw = cc[has_next], cw[has_next]
                tau = p.tau[hc, hw, g_cur[has_next]]
                g_new = g_cur[has_next] + 1
                group[hc, hw] = g_new
                cur_acc[hc, hw] = p.acc[hc, hw, g_new]
                own[hc, hw] = p.dem[hc, hw, g_new]
                remaining[hc, hw] = p.dur[hc, hw, g_new]
                ready[hc, hw] = t[hc] + tau

            if not has_next.all():
                lc, lw = cc[~has_next], cw[~has_next]
                it_new = it[lc, lw] + 1
                lat[orig[lc], lw, it_new - 1] = t[lc] - it_start[lc, lw]
                it[lc, lw] = it_new
                started[lc, lw] = False
                fin = it_new >= p.iters[lc, lw]
                if fin.any():
                    fc, fw = lc[fin], lw[fin]
                    done[fc, fw] = True
                    finish[orig[fc], fw] = t[fc]
                if not fin.all():
                    ac, aw = lc[~fin], lw[~fin]
                    group[ac, aw] = 0
                    cur_acc[ac, aw] = p.acc[ac, aw, 0]
                    own[ac, aw] = p.dem[ac, aw, 0]
                    remaining[ac, aw] = p.dur[ac, aw, 0]
                    ready[ac, aw] = t[ac]
            alive = ~_col_reduce(np.logical_and, done)
            n_alive = int(alive.sum())

    return BatchTimeline(
        makespan=finish.max(axis=1),
        finish_times=finish,
        iteration_latencies=lat,
        iterations=iters_full,
        contention_ms=contention,
        busy_ms=busy,
        acc_names=p.acc_names,
    )


def simulate_assignments(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    assignments_batch: Sequence[Sequence[Sequence[str]]],
    model: ContentionModel | Mapping[str, ContentionModel],
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    validate: bool = True,
) -> BatchTimeline:
    """Batch-evaluate assignment vectors for fixed graphs, iterations and
    dependencies — the solver hot-path shape.  Skips Workload object
    construction entirely: packing is a handful of vectorized gathers."""
    if len(assignments_batch) == 0:
        return _empty_batch(platform)
    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    return _run(_pack_assignments(platform, graphs, assignments_batch,
                                  model, its, deps, validate))


def _concat_packed(packs: Sequence[_Packed]) -> _Packed:
    """Concatenate per-problem packs along the candidate axis (shared
    platform/model; same workload count; group axis padded to the max)."""
    first = packs[0]
    w = first.w
    gmax = max(pk.gmax for pk in packs)
    n = sum(pk.n for pk in packs)
    out = _Packed.__new__(_Packed)
    out.n, out.w, out.gmax = n, w, gmax
    out.amax = first.amax
    out.acc_names = first.acc_names
    out.domshare = first.domshare
    out.models = first.models
    out.model_of_acc = first.model_of_acc

    def cat(name: str, pad_axis2: bool):
        parts = []
        for pk in packs:
            a = getattr(pk, name)
            if pad_axis2 and pk.gmax < gmax:
                pad = np.zeros((pk.n, w, gmax - pk.gmax), dtype=a.dtype)
                a = np.concatenate([a, pad], axis=2)
            parts.append(a)
        setattr(out, name, np.concatenate(parts, axis=0))

    for name in ("acc", "dur", "dem", "tau"):
        cat(name, True)
    for name in ("ngroups", "iters", "dep", "arrival"):
        cat(name, False)
    return out


def simulate_product(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    cand_lists: Sequence[Sequence[Sequence[str]]],
    model: ContentionModel | Mapping[str, ContentionModel],
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    validate: bool = True,
) -> BatchTimeline:
    """Evaluate the full cross product of per-graph assignment lists.

    ``cand_lists[m]`` holds graph ``m``'s candidate assignments (e.g. from
    :func:`repro.core.solver_bb.enumerate_assignments`); candidate ``i`` of
    the result corresponds to ``list(itertools.product(*cand_lists))[i]``
    without that list ever being built.
    """
    if any(len(c) == 0 for c in cand_lists):
        return _empty_batch(platform)
    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    return _run(_pack_product(platform, graphs, cand_lists, model,
                              its, deps, validate))


def simulate_sweep(
    platform: Platform,
    problems: Sequence[tuple],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
) -> tuple[BatchTimeline, list[slice]]:
    """Evaluate many scheduling problems' candidate populations in ONE pass.

    ``problems[k] = (graphs, cand_lists, iterations, depends_on)`` — e.g.
    one entry per Table-8 DNN pair with its per-graph exhaustive assignment
    lists (the cross product is expanded by index arithmetic, in
    ``itertools.product`` order).  All problems must share the platform,
    model and workload count; their candidates are concatenated into a
    single lockstep wave loop, which is where sweep-scale batches amortize
    the per-wave kernel overhead far beyond what per-problem calls reach.

    Returns the combined :class:`BatchTimeline` plus one ``slice`` per
    problem addressing its candidates inside the combined arrays.
    """
    packs, slices, lo = [], [], 0
    for graphs, cand_lists, iterations, depends_on in problems:
        its = list(iterations or [1] * len(graphs))
        deps = list(depends_on or [None] * len(graphs))
        pk = _pack_product(platform, graphs, cand_lists, model,
                           its, deps, validate)
        packs.append(pk)
        slices.append(slice(lo, lo + pk.n))
        lo += pk.n
    if not packs:
        return _empty_batch(platform), []
    if len({pk.w for pk in packs}) != 1:
        raise ValueError("all problems in a sweep must share the workload "
                         "count")
    return _run(_concat_packed(packs)), slices

"""Vectorized batch timeline evaluation: N candidate schedules in one pass.

The exact event-driven simulator in :mod:`repro.core.simulate` is the
authoritative evaluator of the paper's Eq. 2-8 timeline, but it walks one
candidate schedule at a time — and schedule *search* (greedy hill climb,
branch-and-bound sibling scoring, the Table-8 exhaustive sweep) is bounded
by how many candidates it can score per second.  This module evaluates a
whole population of candidates simultaneously by running the same
event-driven state machine in *lockstep across candidates*: every piece of
per-workload simulator state becomes an array over ``candidates ×
workloads``, and each loop iteration advances every still-running candidate
to its own next event with a fixed number of NumPy kernels.  Interpreter
overhead is paid once per event *wave* instead of once per event per
candidate, which is where the >=10x candidate-evaluation throughput comes
from (see ``benchmarks/bench_simulate.py`` / ``BENCH_simulate.json``).

Problems reach the event loop as a lowered :class:`~repro.core.lowering.
ProblemSpec` — the frozen array-IR produced by :mod:`repro.core.lowering`
(``lower_workloads`` / ``lower_assignments`` / ``lower_product`` /
``lower_sweep``) and shared with the XLA evaluator in
:mod:`repro.core.simulate_jax`; :func:`simulate_spec` here is the NumPy
interpretation of that IR, and the convenience wrappers below lower and run
in one call.

Semantics are bit-for-bit the scalar simulator's modulo floating-point
summation order (guarded to 1e-6 by ``tests/test_simulate_differential.py``):

  * one layer group per accelerator at a time, FIFO by (ready time, index);
  * inter-accelerator transitions delay the workload without occupying
    either accelerator;
  * contention intervals integrate ``1 / slowdown(own, external)`` progress
    between events, with external demand summed over shared domains;
  * multi-iteration workloads, ``depends_on`` pipelines and ``arrival_ms``
    offsets behave identically.

The scalar simulator remains *authoritative*: solvers that search with the
batch evaluator re-simulate their final incumbent through
:func:`repro.core.simulate.simulate` before returning it, so a plan's
recorded result never depends on this fast path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
# re-exported for backward compatibility: the surface/vectorized-slowdown
# registries live in core.lowering now (one home, every backend consumes).
from .lowering import (ProblemSpec, TOL as _TOL, lower_assignments,
                       lower_product, lower_sweep, lower_workloads,
                       model_slowdown, register_vectorized_slowdown,
                       slowdown_array)
from .simulate import SimResult, Workload

__all__ = [
    "BatchTimeline", "batch_from_results", "simulate_spec", "simulate_batch",
    "simulate_assignments", "simulate_product", "simulate_sweep",
    "register_vectorized_slowdown", "slowdown_array",
]


# ---------------------------------------------------------------------------
# BatchTimeline
# ---------------------------------------------------------------------------

@dataclass
class BatchTimeline:
    """Per-candidate timeline results of one :func:`simulate_batch` call.

    Arrays are indexed ``[candidate]`` / ``[candidate, workload]``; iteration
    latencies are padded with NaN beyond each workload's iteration count.
    """

    #: (N,) total schedule span per candidate (max workload finish time).
    makespan: np.ndarray
    #: (N, W) completion time of every workload.
    finish_times: np.ndarray
    #: (N, W, max_iters) per-iteration service latency, NaN-padded.
    iteration_latencies: np.ndarray
    #: (N, W) number of iterations each workload ran.
    iterations: np.ndarray
    #: (N,) wall-clock ms added purely by contention per candidate.
    contention_ms: np.ndarray
    #: (N, A) contention-free busy ms per accelerator.
    busy_ms: np.ndarray
    #: accelerator names indexing the last axis of ``busy_ms``.
    acc_names: tuple[str, ...]

    def __len__(self) -> int:
        return int(self.makespan.shape[0])

    @property
    def n_candidates(self) -> int:
        return len(self)

    @property
    def throughput_fps(self) -> np.ndarray:
        """(N,) completed DNN inferences per second per candidate."""
        n = self.iterations.sum(axis=1)
        with np.errstate(divide="ignore"):
            fps = np.where(self.makespan > 0, 1e3 * n / self.makespan,
                           np.inf)
        return fps

    def objective(self, kind: str) -> np.ndarray:
        """(N,) solver objective per candidate; lower is better for every
        kind — mirrors :meth:`repro.core.simulate.SimResult.objective`."""
        if kind == "latency":
            return self.makespan.copy()
        if kind == "throughput":
            return -self.throughput_fps
        if kind == "sum_inverse":
            with np.errstate(divide="ignore"):
                inv = np.where(self.finish_times > 0,
                               1.0 / self.finish_times, 0.0)
            return -inv.sum(axis=1)
        raise ValueError(kind)

    def argbest(self, kind: str) -> int:
        """Index of the best candidate (first among exact ties)."""
        return int(np.argmin(self.objective(kind)))

    def result(self, i: int) -> SimResult:
        """Extract candidate ``i`` as a scalar-shaped :class:`SimResult`.

        The interval-level ``timeline`` is not materialized by the batch
        path (it exists to explain one schedule, not to rank thousands);
        re-simulate the winner through the authoritative scalar simulator
        when a Gantt-grade timeline is needed.
        """
        lats = [
            [float(x) for x in row[:int(self.iterations[i, n])]]
            for n, row in enumerate(self.iteration_latencies[i])
        ]
        return SimResult(
            makespan=float(self.makespan[i]),
            finish_times=[float(x) for x in self.finish_times[i]],
            iteration_latencies=lats,
            timeline=[],
            contention_ms=float(self.contention_ms[i]),
            busy_ms={a: float(self.busy_ms[i, j])
                     for j, a in enumerate(self.acc_names)},
        )

    def results(self) -> list[SimResult]:
        return [self.result(i) for i in range(len(self))]


def batch_from_results(results: Sequence[SimResult],
                       acc_names: Sequence[str]) -> BatchTimeline:
    """Assemble a :class:`BatchTimeline` from scalar :class:`SimResult`s.

    This is the "scalar" evaluator's batch implementation: every call site
    written against the batch interface can fall back to the authoritative
    simulator without changing shape.
    """
    n = len(results)
    w = max((len(r.finish_times) for r in results), default=0)
    maxit = max((max((len(l) for l in r.iteration_latencies), default=0)
                 for r in results), default=0)
    lat = np.full((n, w, max(maxit, 1)), np.nan)
    its = np.zeros((n, w), dtype=np.int64)
    fin = np.zeros((n, w))
    for i, r in enumerate(results):
        fin[i, :len(r.finish_times)] = r.finish_times
        for j, l in enumerate(r.iteration_latencies):
            its[i, j] = len(l)
            lat[i, j, :len(l)] = l
    return BatchTimeline(
        makespan=np.array([r.makespan for r in results]),
        finish_times=fin,
        iteration_latencies=lat,
        iterations=its,
        contention_ms=np.array([r.contention_ms for r in results]),
        busy_ms=np.array([[r.busy_ms.get(a, 0.0) for a in acc_names]
                          for r in results]),
        acc_names=tuple(acc_names),
    )


# ---------------------------------------------------------------------------
# the lockstep event loop (NumPy interpretation of the lowered IR)
# ---------------------------------------------------------------------------

def _empty_batch(platform: Platform) -> BatchTimeline:
    return BatchTimeline(
        makespan=np.zeros(0), finish_times=np.zeros((0, 0)),
        iteration_latencies=np.zeros((0, 0, 1)),
        iterations=np.zeros((0, 0), dtype=np.int64),
        contention_ms=np.zeros(0),
        busy_ms=np.zeros((0, len(platform.names))),
        acc_names=tuple(platform.names))


def simulate_batch(
    platform: Platform,
    workloads_batch: Sequence[Sequence[Workload]],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
) -> BatchTimeline:
    """Simulate N candidate schedules in one vectorized pass.

    ``workloads_batch[c]`` is candidate ``c``'s workload list; candidates
    must agree on the number of workloads but may differ in assignments,
    graphs, iterations, dependencies and arrival offsets.  Returns a
    :class:`BatchTimeline` whose per-candidate values match the scalar
    simulator within floating-point summation order (see
    ``tests/test_simulate_differential.py``).
    """
    if len(workloads_batch) == 0:
        return _empty_batch(platform)
    return simulate_spec(lower_workloads(platform, workloads_batch, model,
                                         validate))


def _col_reduce(ufunc, arr: np.ndarray) -> np.ndarray:
    """Reduce (N, W) along axis 1 via W-1 vectorized column ops.

    NumPy's ``arr.min(axis=1)``/``.any(axis=1)`` degenerate to a Python-side
    outer loop when the reduced axis is tiny (W is 2-4 here) — column-wise
    reduction keeps every op SIMD-width over N instead.
    """
    if arr.shape[1] == 1:
        return arr[:, 0].copy()    # never alias mutable state
    out = ufunc(arr[:, 0], arr[:, 1])
    for j in range(2, arr.shape[1]):
        out = ufunc(out, arr[:, j])
    return out


def simulate_spec(spec: ProblemSpec) -> BatchTimeline:
    """Run the lockstep NumPy event loop over a lowered problem spec.

    The spec is immutable and reusable; candidate compaction during the run
    operates on local gathers, never on the spec's arrays.
    """
    p = spec
    n, w, a_cnt = p.n, p.w, p.amax
    n0 = n
    rows = np.arange(n)
    #: live position -> original candidate id (identity until compaction).
    orig = np.arange(n)

    # spec columns as locals: compaction re-gathers these (the spec's own
    # arrays are read-only and shared).
    g_acc, g_dur, g_dem, g_tau = p.acc, p.dur, p.dem, p.tau
    g_ngroups, g_iters = p.ngroups, p.iters
    g_dep, g_arrival = p.dep, p.arrival

    # mutable per-(candidate, workload) state — the scalar _WorkloadState
    # fields as arrays.  cur_acc/own are maintained incrementally (they only
    # change at group/iteration boundaries) to keep the per-wave kernel
    # count down.
    group = np.zeros((n, w), dtype=np.int64)
    cur_acc = g_acc[:, :, 0].copy()
    own = g_dem[:, :, 0].copy()
    remaining = g_dur[:, :, 0].copy()
    ready = g_arrival.copy()
    it = np.zeros((n, w), dtype=np.int64)
    it_start = g_arrival.copy()
    started = np.zeros((n, w), dtype=bool)
    done = np.zeros((n, w), dtype=bool)
    is_run = np.zeros((n, w), dtype=bool)
    run_wl = np.full((n, a_cnt), -1, dtype=np.int64)
    t = np.zeros(n)

    # outputs stay full-size, indexed by original candidate id.
    max_it = int(g_iters.max())
    iters_full = g_iters.copy()
    finish = np.zeros((n0, w))
    lat = np.full((n0, w, max_it), np.nan)
    contention = np.zeros(n0)
    busy = np.zeros((n0, a_cnt))

    # same guard shape as the scalar simulator, summed across the batch
    # (each lockstep wave advances at least one event or idle jump in every
    # still-alive candidate).
    per_cand = 200000 + 200 * (g_ngroups * g_iters).sum(axis=1)
    max_waves = int(per_cand.sum())
    guard = 0

    inf = np.inf
    alive = ~done.all(axis=1)
    n_alive = n
    while n_alive:
        guard += 1
        if guard > max_waves:
            raise RuntimeError("batch simulator did not converge "
                               "(event storm)")

        if n >= 1024 and n_alive <= n // 2:
            # compact: candidates finish at wildly different wave counts in
            # heterogeneous sweeps; dropping finished rows keeps every wave
            # proportional to live work instead of the original batch size.
            keep = np.nonzero(alive)[0]
            orig = orig[keep]
            t = t[keep]
            group, cur_acc, own = group[keep], cur_acc[keep], own[keep]
            remaining, ready = remaining[keep], ready[keep]
            it, it_start = it[keep], it_start[keep]
            started, done, is_run = started[keep], done[keep], is_run[keep]
            run_wl = run_wl[keep]
            alive = alive[keep]
            g_acc, g_dur = g_acc[keep], g_dur[keep]
            g_dem, g_tau = g_dem[keep], g_tau[keep]
            g_ngroups, g_iters = g_ngroups[keep], g_iters[keep]
            g_dep, g_arrival = g_dep[keep], g_arrival[keep]
            n = len(keep)
            rows = np.arange(n)

        # 1) FIFO claim: eligible waiting workloads sorted by (ready, idx)
        # take their accelerator if free.
        dep_row = np.clip(g_dep, 0, w - 1)
        dep_ok = ((g_dep < 0)
                  | done[rows[:, None], dep_row]
                  | (it[rows[:, None], dep_row] > it))
        eligible = (alive[:, None] & ~done & ~is_run & dep_ok
                    & (ready <= t[:, None] + _TOL))
        if eligible.any():
            key = np.where(eligible, ready, inf)
            if w == 2:
                # stable (ready, idx) order without an axis-1 argsort
                second_first = key[:, 1] < key[:, 0]
                order = np.empty((n, 2), dtype=np.int64)
                order[:, 0] = second_first
                order[:, 1] = ~second_first
            else:
                order = np.argsort(key, axis=1, kind="stable")
            for r in range(w):
                w_r = order[:, r]
                el = eligible[rows, w_r]
                if not el.any():
                    continue
                a_r = cur_acc[rows, w_r]
                claim = el & (run_wl[rows, a_r] < 0)
                if claim.any():
                    cc = rows[claim]
                    run_wl[cc, a_r[claim]] = w_r[claim]
                    is_run[cc, w_r[claim]] = True
                    fresh = (claim & (group[rows, w_r] == 0)
                             & ~started[rows, w_r])
                    if fresh.any():
                        fc = rows[fresh]
                        it_start[fc, w_r[fresh]] = t[fresh]
                        started[fc, w_r[fresh]] = True

        any_run = _col_reduce(np.logical_or, is_run)
        idle = alive & ~any_run
        if idle.any():
            # idle gap: jump those candidates to their next arrival /
            # transition / dependency boundary (they re-claim next wave,
            # exactly like the scalar simulator's `continue`) while every
            # running candidate still integrates this wave.
            pend = np.where(~done & (ready > t[:, None] + _TOL), ready, inf)
            tmin = _col_reduce(np.minimum, pend)
            if not np.isfinite(tmin[idle]).all():
                raise RuntimeError(
                    "deadlock: nothing running, nothing pending")
            t = np.where(idle, tmin, t)
            if not any_run.any():
                continue

        # 2) per-interval slowdowns — computed on the 1-D running-entry
        # vectors (rc, rw), not full (N, W) planes.  One accelerator runs
        # at most one layer, so per-(candidate, acc) demand needs no
        # accumulation: plain fancy assignment is collision-free.
        rc, rw = np.nonzero(is_run)
        run_acc = cur_acc[rc, rw]
        own_run = own[rc, rw]
        acc_dem = np.zeros((n, a_cnt))
        acc_dem[rc, run_acc] = own_run
        # external demand visible from acc a = sum_b domshare[a, b]·demand_b
        ext_run = (acc_dem @ p.domshare.T)[rc, run_acc]
        s_run = np.ones(len(rc))
        contended = (own_run > 0.0) & (ext_run > 0.0)
        if contended.any():
            macc = np.where(contended, p.model_of_acc[run_acc], -1)
            for mid, mod in enumerate(p.models):
                m2 = macc == mid
                if m2.any():
                    # surfaces come pre-lowered on the spec: no per-wave
                    # re-lowering on the hot path.
                    s_run[m2] = np.maximum(
                        1.0, model_slowdown(mod, p.surfaces[mid],
                                            own_run[m2], ext_run[m2]))
            if (contended & (macc < 0)).any():
                bad = int(run_acc[np.nonzero(contended & (macc < 0))[0][0]])
                raise KeyError(
                    f"no contention model covers accelerator "
                    f"{p.acc_names[bad]!r}")

        # 3) next event horizon: earliest running completion, capped by any
        # ready/arrival boundary strictly inside the interval.
        rem_run = remaining[rc, rw]
        run_rem = np.full((n, w), inf)
        run_rem[rc, rw] = rem_run * s_run
        dt = _col_reduce(np.minimum, run_rem)
        horizon = t + dt
        cap = _col_reduce(np.minimum, np.where(
            ~done & ~is_run & (ready > t[:, None] + _TOL)
            & (ready < horizon[:, None] - _TOL),
            ready, inf))
        horizon = np.minimum(horizon, cap)

        # 4) integrate the contention interval.
        span_run = (horizon - t)[rc]
        prog = span_run / s_run
        rem_run = rem_run - prog
        remaining[rc, rw] = rem_run
        np.add.at(contention, orig[rc], span_run * (1.0 - 1.0 / s_run))
        busy[orig[rc], run_acc] += prog   # collision-free: one layer per acc
        t = np.where(alive & any_run, horizon, t)

        # 5) process completions.
        fin_run = rem_run <= _TOL
        if fin_run.any():
            cc, cw = rc[fin_run], rw[fin_run]
            run_wl[cc, run_acc[fin_run]] = -1
            is_run[cc, cw] = False

            g_cur = group[cc, cw]
            has_next = g_cur + 1 < g_ngroups[cc, cw]
            if has_next.any():
                hc, hw = cc[has_next], cw[has_next]
                tau = g_tau[hc, hw, g_cur[has_next]]
                g_new = g_cur[has_next] + 1
                group[hc, hw] = g_new
                cur_acc[hc, hw] = g_acc[hc, hw, g_new]
                own[hc, hw] = g_dem[hc, hw, g_new]
                remaining[hc, hw] = g_dur[hc, hw, g_new]
                ready[hc, hw] = t[hc] + tau

            if not has_next.all():
                lc, lw = cc[~has_next], cw[~has_next]
                it_new = it[lc, lw] + 1
                lat[orig[lc], lw, it_new - 1] = t[lc] - it_start[lc, lw]
                it[lc, lw] = it_new
                started[lc, lw] = False
                fin = it_new >= g_iters[lc, lw]
                if fin.any():
                    fc, fw = lc[fin], lw[fin]
                    done[fc, fw] = True
                    finish[orig[fc], fw] = t[fc]
                if not fin.all():
                    ac, aw = lc[~fin], lw[~fin]
                    group[ac, aw] = 0
                    cur_acc[ac, aw] = g_acc[ac, aw, 0]
                    own[ac, aw] = g_dem[ac, aw, 0]
                    remaining[ac, aw] = g_dur[ac, aw, 0]
                    ready[ac, aw] = t[ac]
            alive = ~_col_reduce(np.logical_and, done)
            n_alive = int(alive.sum())

    return BatchTimeline(
        makespan=finish.max(axis=1),
        finish_times=finish,
        iteration_latencies=lat,
        iterations=iters_full,
        contention_ms=contention,
        busy_ms=busy,
        acc_names=p.acc_names,
    )


def simulate_assignments(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    assignments_batch: Sequence[Sequence[Sequence[str]]],
    model: ContentionModel | Mapping[str, ContentionModel],
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    validate: bool = True,
) -> BatchTimeline:
    """Batch-evaluate assignment vectors for fixed graphs, iterations and
    dependencies — the solver hot-path shape.  Skips Workload object
    construction entirely: packing is a handful of vectorized gathers."""
    if len(assignments_batch) == 0:
        return _empty_batch(platform)
    return simulate_spec(lower_assignments(
        platform, graphs, assignments_batch, model, iterations=iterations,
        depends_on=depends_on, validate=validate))


def simulate_product(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    cand_lists: Sequence[Sequence[Sequence[str]]],
    model: ContentionModel | Mapping[str, ContentionModel],
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    validate: bool = True,
) -> BatchTimeline:
    """Evaluate the full cross product of per-graph assignment lists.

    ``cand_lists[m]`` holds graph ``m``'s candidate assignments (e.g. from
    :func:`repro.core.solver_bb.enumerate_assignments`); candidate ``i`` of
    the result corresponds to ``list(itertools.product(*cand_lists))[i]``
    without that list ever being built.
    """
    if any(len(c) == 0 for c in cand_lists):
        return _empty_batch(platform)
    return simulate_spec(lower_product(
        platform, graphs, cand_lists, model, iterations=iterations,
        depends_on=depends_on, validate=validate))


def simulate_sweep(
    platform: Platform,
    problems: Sequence[tuple],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
) -> tuple[BatchTimeline, list[slice]]:
    """Evaluate many scheduling problems' candidate populations in ONE pass.

    ``problems[k] = (graphs, cand_lists, iterations, depends_on)`` — e.g.
    one entry per Table-8 DNN pair with its per-graph exhaustive assignment
    lists (the cross product is expanded by index arithmetic, in
    ``itertools.product`` order).  All problems must share the platform,
    model and workload count; their candidates are concatenated into a
    single lockstep wave loop, which is where sweep-scale batches amortize
    the per-wave kernel overhead far beyond what per-problem calls reach.

    Returns the combined :class:`BatchTimeline` plus one ``slice`` per
    problem addressing its candidates inside the combined arrays.
    """
    spec, slices = lower_sweep(platform, problems, model, validate)
    if spec is None:
        return _empty_batch(platform), []
    return simulate_spec(spec), slices

"""Lowered array-IR for scheduling problems: ``ProblemSpec`` + surfaces.

Every fast evaluator of the paper's Eq. 2-8 timeline — the NumPy lockstep
loop in :mod:`repro.core.simulate_batch` and the XLA evaluator in
:mod:`repro.core.simulate_jax` — consumes the same *lowered* form of a
scheduling problem instead of walking ``Platform``/``DNNGraph``/``Workload``
objects.  This module is that lowering pass:

* :class:`ProblemSpec` — a frozen, hashable bundle of pure arrays: per
  (candidate, workload, group) accelerator indices, contention-free
  durations, shared-memory demands and post-group transition delays, plus
  the per-workload iteration / dependency / arrival columns and the
  platform's contention topology (domain-share matrix, per-accelerator
  model ids).  Arrays are read-only; equal-valued specs hash and compare
  equal, so a spec can key caches (e.g. compiled XLA executables).
* :func:`lower_workloads` / :func:`lower_assignments` /
  :func:`lower_product` / :func:`lower_sweep` — the three packing shapes
  evaluators need (arbitrary per-candidate workload lists; fixed graphs x N
  assignment vectors; cross products expanded by index arithmetic) plus the
  multi-problem sweep concatenation, all producing ``ProblemSpec``.
* :class:`SlowdownSurface` — the PCCS slowdown model lowered to pure
  parameters (piecewise-linear surface knots/table or the proportional-
  share closed form, with a scale factor for §4.4's
  ``ScaledContentionModel``).  Surfaces are what lets the jax evaluator
  price contention without calling back into Python; the NumPy path
  evaluates the same parameters through :func:`surface_slowdown`.

Registries (one home, every backend consumes them):

* :func:`register_surface_lowering` — ``model class -> SlowdownSurface``.
  Built-ins (:class:`~repro.core.contention.ProportionalShareModel`,
  :class:`~repro.core.contention.PiecewiseModel`) register here below;
  :class:`~repro.core.dynamic.ScaledContentionModel` registers its
  factor-folding lowering in its home module.
* :func:`register_vectorized_slowdown` — ``model class -> NumPy slowdown``
  for third-party models that have no surface form but still want the
  batch fast path.  :func:`slowdown_array` dispatches: explicit vectorized
  fn > lowered surface > elementwise ``model.slowdown`` fallback.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel, PiecewiseModel, ProportionalShareModel
from .graph import DNNGraph
from .simulate import Workload, validate_assignment

#: event-resolution threshold shared by every evaluator backend (scalar,
#: NumPy batch, jax); the differential contract depends on all of them
#: resolving events at the same tolerance.
TOL = 1e-9


# ---------------------------------------------------------------------------
# slowdown surfaces: contention models lowered to pure parameters
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SlowdownSurface:
    """A contention model lowered to array-IR parameters.

    ``kind`` selects the closed form:

    * ``"proportional"`` — :class:`ProportionalShareModel`'s analytic
      formula, parameterized by ``capacity`` and ``sensitivity``.
    * ``"piecewise"`` — PCCS proper: bilinear interpolation over
      ``own_knots`` x ``ext_knots`` with values ``table`` (clamped
      extension outside the grid).

    ``factor`` scales the *excess* slowdown (``1 + factor * (s - 1)``) —
    the lowered form of §4.4's ``ScaledContentionModel``; nesting folds
    multiplicatively, so any scaled tower lowers to one surface.
    """

    kind: str
    capacity: float = 1.0
    sensitivity: float = 1.0
    own_knots: tuple[float, ...] = ()
    ext_knots: tuple[float, ...] = ()
    table: tuple[tuple[float, ...], ...] = ()
    factor: float = 1.0


#: cls -> fn(model) -> SlowdownSurface | None (None = not lowerable).
_SURFACES: dict[type, Callable[[Any], SlowdownSurface | None]] = {}


def register_surface_lowering(
        cls: type, fn: Callable[[Any], SlowdownSurface | None],
        replace: bool = False) -> None:
    """Register a lowering of ``cls`` instances to :class:`SlowdownSurface`."""
    if cls in _SURFACES and not replace:
        raise ValueError(f"surface lowering for {cls.__name__} already "
                         f"registered")
    _SURFACES[cls] = fn


def lower_surface(model: Any) -> SlowdownSurface | None:
    """Lower a contention model to its surface, or None if it has no
    registered array-IR form (such models stay usable through the NumPy
    elementwise fallback but are rejected by the jax evaluator)."""
    fn = _SURFACES.get(type(model))
    return fn(model) if fn is not None else None


register_surface_lowering(
    ProportionalShareModel,
    lambda m: SlowdownSurface("proportional", capacity=float(m.capacity),
                              sensitivity=float(m.sensitivity)))
register_surface_lowering(
    PiecewiseModel,
    lambda m: SlowdownSurface(
        "piecewise",
        own_knots=tuple(float(x) for x in m.own_knots),
        ext_knots=tuple(float(x) for x in m.ext_knots),
        table=tuple(tuple(float(v) for v in row) for row in m.table)))


def _locate_batch(knots: np.ndarray, x: np.ndarray):
    """Vectorized PiecewiseModel._locate: (lo, hi, w) per element."""
    n = len(knots)
    hi = np.searchsorted(knots, x, side="right")
    lo = np.clip(hi - 1, 0, n - 1)
    hi = np.clip(hi, 0, n - 1)
    below = x <= knots[0]
    above = x >= knots[-1]
    lo = np.where(below, 0, np.where(above, n - 1, lo))
    hi = np.where(below, 0, np.where(above, n - 1, hi))
    denom = knots[hi] - knots[lo]
    with np.errstate(invalid="ignore", divide="ignore"):
        w = np.where(denom > 0, (x - knots[lo]) / np.where(denom > 0, denom, 1.0),
                     0.0)
    w = np.where(below | above, 0.0, w)
    return lo, hi, w


def surface_slowdown(surface: SlowdownSurface, own: np.ndarray,
                     ext: np.ndarray) -> np.ndarray:
    """NumPy evaluation of a lowered surface over equal-shaped demand arrays.

    Matches the scalar models bit-for-bit (same operations in the same
    order); :mod:`repro.core.simulate_jax` evaluates the same parameters
    through :mod:`repro.kernels.slowdown`.
    """
    if surface.kind == "proportional":
        own_ = np.maximum(0.0, own)
        ext_ = np.maximum(0.0, ext)
        total = own_ + ext_
        boundedness = np.minimum(1.0, own_ / surface.capacity)
        dilation = total / surface.capacity
        s = 1.0 + surface.sensitivity * boundedness * (dilation - 1.0)
        s = np.where((own_ == 0.0) | (total <= surface.capacity), 1.0, s)
    elif surface.kind == "piecewise":
        ok = np.asarray(surface.own_knots, dtype=float)
        ek = np.asarray(surface.ext_knots, dtype=float)
        table = np.asarray(surface.table, dtype=float)
        i0, i1, wi = _locate_batch(ok, own)
        j0, j1, wj = _locate_batch(ek, ext)
        v0 = table[i0, j0] * (1 - wj) + table[i0, j1] * wj
        v1 = table[i1, j0] * (1 - wj) + table[i1, j1] * wj
        s = v0 * (1 - wi) + v1 * wi
        s = np.where((own <= 0.0) | (ext <= 0.0), 1.0, s)
    else:
        raise ValueError(f"unknown surface kind {surface.kind!r}")
    if surface.factor != 1.0:
        s = 1.0 + surface.factor * (s - 1.0)
    return s


# ---------------------------------------------------------------------------
# vectorized slowdown dispatch (NumPy batch path)
# ---------------------------------------------------------------------------

#: cls -> fn(model, own: ndarray, ext: ndarray) -> ndarray.  Third-party
#: contention models without a surface form register here to stay on the
#: fast path; anything unregistered falls back to an elementwise call of
#: ``model.slowdown``.
_VECTORIZED: dict[type, Callable[[Any, np.ndarray, np.ndarray], np.ndarray]] = {}


def register_vectorized_slowdown(
        cls: type,
        fn: Callable[[Any, np.ndarray, np.ndarray], np.ndarray],
        replace: bool = False) -> None:
    """Register a NumPy implementation of ``cls.slowdown`` for the batch path."""
    if cls in _VECTORIZED and not replace:
        raise ValueError(f"vectorized slowdown for {cls.__name__} already "
                         f"registered")
    _VECTORIZED[cls] = fn


def model_slowdown(model: Any, surface: SlowdownSurface | None,
                   own: np.ndarray, ext: np.ndarray) -> np.ndarray:
    """:func:`slowdown_array` with a pre-lowered surface.

    Dispatch order: the lowered surface when one exists (it *is* the
    model's array-IR semantics, and hot loops holding a
    :class:`ProblemSpec` pass ``spec.surfaces[mid]`` so no re-lowering
    happens per contention interval), then an explicitly registered
    vectorized implementation, then an elementwise fallback — slower, but
    any object with a scalar ``slowdown`` stays usable (and *correct*)
    from every batch call site.
    """
    if surface is not None:
        return surface_slowdown(surface, np.asarray(own, dtype=float),
                                np.asarray(ext, dtype=float))
    fn = _VECTORIZED.get(type(model))
    if fn is not None:
        return fn(model, own, ext)
    flat_own = np.asarray(own, dtype=float).ravel()
    flat_ext = np.asarray(ext, dtype=float).ravel()
    out = np.fromiter((model.slowdown(float(o), float(e))
                       for o, e in zip(flat_own, flat_ext)),
                      dtype=float, count=flat_own.size)
    return out.reshape(np.shape(own))


def slowdown_array(model: Any, own: np.ndarray, ext: np.ndarray) -> np.ndarray:
    """Vectorized ``model.slowdown`` over equal-shaped demand arrays
    (lowers the model's surface on the fly; see :func:`model_slowdown`)."""
    return model_slowdown(model, lower_surface(model), own, ext)


# ---------------------------------------------------------------------------
# ProblemSpec: the frozen array-IR of a candidate population
# ---------------------------------------------------------------------------

_ARRAY_FIELDS = ("acc", "dur", "dem", "tau", "ngroups", "iters", "dep",
                 "arrival", "domshare", "model_of_acc")


@dataclass(frozen=True, eq=False)
class ProblemSpec:
    """Dense array form of ``n`` candidate schedules over ``w`` workloads.

    Group-axis arrays are zero-padded to ``gmax`` (the longest graph);
    ``ngroups`` bounds the live prefix per (candidate, workload).  All
    arrays are read-only; :meth:`content_hash` (and ``__hash__``/``__eq__``)
    are value-based, so equal problems lowered independently compare equal
    and can share cache entries.
    """

    #: candidates, workloads per candidate, max groups, accelerators.
    n: int
    w: int
    gmax: int
    amax: int
    #: accelerator names indexing the accelerator axis everywhere below.
    acc_names: tuple[str, ...]
    #: (n, w, gmax) accelerator index of each layer group.
    acc: np.ndarray
    #: (n, w, gmax) contention-free duration / shared-memory demand /
    #: post-group transition delay.
    dur: np.ndarray
    dem: np.ndarray
    tau: np.ndarray
    #: (n, w) live group count / iteration count / producer index (-1 =
    #: independent) / release offset.
    ngroups: np.ndarray
    iters: np.ndarray
    dep: np.ndarray
    arrival: np.ndarray
    #: (amax, amax) number of contention domains shared by each accelerator
    #: pair (diagonal zero): external demand seen from ``a`` is
    #: ``sum_b demand_b * domshare[a, b]``.
    domshare: np.ndarray
    #: (amax,) index into ``models``/``surfaces`` (-1 = never modeled).
    model_of_acc: np.ndarray
    #: deduplicated contention-model objects (NumPy path) and their lowered
    #: surfaces (jax path; ``None`` where a model has no array-IR form).
    models: tuple[Any, ...]
    surfaces: tuple[SlowdownSurface | None, ...]

    def __post_init__(self):
        for name in _ARRAY_FIELDS:
            given = getattr(self, name)
            arr = np.ascontiguousarray(given)
            if arr.flags.writeable:
                if arr is given:
                    # never freeze (or alias) a caller-owned buffer in
                    # place; internal builders hand over pre-frozen arrays
                    # so the common path stays zero-copy.
                    arr = arr.copy()
                arr.setflags(write=False)
            object.__setattr__(self, name, arr)
        object.__setattr__(self, "_hash", None)

    def __len__(self) -> int:
        return self.n

    @property
    def n_candidates(self) -> int:
        return self.n

    def _model_fingerprints(self) -> tuple[str, ...]:
        # value-based identity: the lowered surface when one exists (the
        # parameters ARE the model as far as any evaluator is concerned),
        # else the registry codec, else the model repr.
        out = []
        for model, surface in zip(self.models, self.surfaces):
            if surface is not None:
                out.append(repr(surface))
                continue
            from . import registry  # deferred: registry imports this module
            out.append(json.dumps(registry.encode_model(model),
                                  sort_keys=True))
        return tuple(out)

    def content_hash(self) -> str:
        """Hex digest of the full problem content (arrays + topology +
        lowered model parameters) — stable across processes for specs built
        from surface-lowerable models."""
        h = hashlib.sha256()
        h.update(repr((self.n, self.w, self.gmax, self.amax, self.acc_names,
                       self._model_fingerprints())).encode())
        for name in _ARRAY_FIELDS:
            arr = getattr(self, name)
            h.update(name.encode())
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    def __hash__(self) -> int:
        cached = getattr(self, "_hash")
        if cached is None:
            cached = int.from_bytes(
                bytes.fromhex(self.content_hash()[:16]), "big")
            object.__setattr__(self, "_hash", cached)
        return cached

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, ProblemSpec):
            return NotImplemented
        if (self.n, self.w, self.gmax, self.amax, self.acc_names) != \
                (other.n, other.w, other.gmax, other.amax, other.acc_names):
            return False
        if any(not np.array_equal(getattr(self, f), getattr(other, f))
               for f in _ARRAY_FIELDS):
            return False
        return self._model_fingerprints() == other._model_fingerprints()

    def __repr__(self) -> str:
        return (f"ProblemSpec(n={self.n}, w={self.w}, gmax={self.gmax}, "
                f"accs={self.acc_names}, models={len(self.models)})")


# ---------------------------------------------------------------------------
# platform topology lowering (shared by every packing shape)
# ---------------------------------------------------------------------------

def _platform_tables(platform: Platform,
                     model: ContentionModel | Mapping[str, ContentionModel]):
    """(domshare, model_of_acc, models, surfaces) for one platform+model."""
    acc_names = tuple(platform.names)
    acc_idx = {a: j for j, a in enumerate(acc_names)}
    amax = len(acc_names)

    ds = np.zeros((amax, amax))
    for members in platform.domains.values():
        idxs = [acc_idx[m] for m in members]
        for i in idxs:
            for j in idxs:
                if i != j:
                    ds[i, j] += 1.0

    # per-accelerator contention model (the scalar simulator uses the model
    # of the accelerator's *first* domain).
    if hasattr(model, "slowdown"):
        models_map: dict[str, Any] = {d: model for d in platform.domains}
        if not models_map:
            models_map = {"_": model}
    else:
        models_map = dict(model)  # type: ignore[arg-type]
    first_domain: dict[str, str] = {}
    for dom, members in platform.domains.items():
        for m in members:
            first_domain.setdefault(m, dom)
    models: list[Any] = []
    model_of_acc = np.full(amax, -1, dtype=np.int64)
    seen: dict[int, int] = {}
    for j, a in enumerate(acc_names):
        dom = first_domain.get(a)
        if dom is None:
            continue  # never contends: slowdown is never evaluated
        mod = models_map.get(dom)
        if mod is None:
            # scalar simulate would KeyError on first contention; defer
            # identically by leaving the slot unmodeled.
            continue
        key = id(mod)
        if key not in seen:
            seen[key] = len(models)
            models.append(mod)
        model_of_acc[j] = seen[key]
    surfaces = tuple(lower_surface(m) for m in models)
    return acc_names, ds, model_of_acc, tuple(models), surfaces


class _SpecBuilder:
    """Mutable staging area for one :class:`ProblemSpec`."""

    def __init__(self, platform: Platform, n: int, w: int, gmax: int,
                 model: ContentionModel | Mapping[str, ContentionModel]):
        (self.acc_names, self.domshare, self.model_of_acc, self.models,
         self.surfaces) = _platform_tables(platform, model)
        self.n, self.w, self.gmax = n, w, gmax
        self.amax = len(self.acc_names)
        self.acc = np.zeros((n, w, gmax), dtype=np.int64)
        self.dur = np.zeros((n, w, gmax))
        self.dem = np.zeros((n, w, gmax))
        self.tau = np.zeros((n, w, gmax))
        self.ngroups = np.zeros((n, w), dtype=np.int64)
        self.iters = np.ones((n, w), dtype=np.int64)
        self.dep = np.full((n, w), -1, dtype=np.int64)
        self.arrival = np.zeros((n, w))

    def set_static_columns(self, iterations: Sequence[int],
                           depends_on: Sequence[int | None]) -> None:
        self.iters[:] = np.asarray(list(iterations), dtype=np.int64)[None, :]
        self.dep[:] = np.asarray([-1 if d is None else d for d in depends_on],
                                 dtype=np.int64)[None, :]

    def freeze(self) -> ProblemSpec:
        # the builder owns these arrays: pre-freeze for a zero-copy handoff
        # (ProblemSpec copies any still-writable array it is given).
        for name in ("acc", "dur", "dem", "tau", "ngroups", "iters",
                     "dep", "arrival", "domshare", "model_of_acc"):
            np.ascontiguousarray(getattr(self, name)).setflags(write=False)
        return ProblemSpec(
            n=self.n, w=self.w, gmax=self.gmax, amax=self.amax,
            acc_names=self.acc_names, acc=self.acc, dur=self.dur,
            dem=self.dem, tau=self.tau, ngroups=self.ngroups,
            iters=self.iters, dep=self.dep, arrival=self.arrival,
            domshare=self.domshare, model_of_acc=self.model_of_acc,
            models=self.models, surfaces=self.surfaces)


# ---------------------------------------------------------------------------
# the three packing shapes + sweep concatenation
# ---------------------------------------------------------------------------

def lower_workloads(platform: Platform,
                    workloads_batch: Sequence[Sequence[Workload]],
                    model: ContentionModel | Mapping[str, ContentionModel],
                    validate: bool = True) -> ProblemSpec:
    """Generic lowering: per-candidate Workload lists (graphs may differ)."""
    acc_idx = {a: j for j, a in enumerate(platform.names)}
    n = len(workloads_batch)
    if n == 0:
        raise ValueError("cannot lower an empty candidate population")
    w = len(workloads_batch[0])
    for c, wls in enumerate(workloads_batch):
        if len(wls) != w:
            raise ValueError(
                f"candidate {c} has {len(wls)} workloads, expected {w} "
                f"(all candidates of a batch share the workload count)")
    gmax = max(len(wl.graph) for wls in workloads_batch for wl in wls)
    b = _SpecBuilder(platform, n, w, gmax, model)
    for c, wls in enumerate(workloads_batch):
        for m, wl in enumerate(wls):
            if validate:
                validate_assignment(platform, wl)
            g = wl.graph
            ng = len(g)
            b.ngroups[c, m] = ng
            b.iters[c, m] = wl.iterations
            b.dep[c, m] = -1 if wl.depends_on is None else wl.depends_on
            b.arrival[c, m] = wl.arrival_ms
            asg = wl.assignment
            for i in range(ng):
                a = asg[i]
                b.acc[c, m, i] = acc_idx[a]
                b.dur[c, m, i] = g[i].time_on(a)
                b.dem[c, m, i] = g[i].demand_on(a)
                if i + 1 < ng:
                    b.tau[c, m, i] = platform.transition_cost_ms(
                        g[i].out_bytes, a, asg[i + 1])
    return b.freeze()


def graph_tables(platform: Platform, g: DNNGraph):
    """Per-graph (group, accelerator) lookup tables.

    Returns ``(time_t, dem_t, legal, move, tau_pair)``:

    * ``time_t`` (ng, A) — group duration per accelerator, NaN = illegal;
    * ``dem_t``  (ng, A) — memory demand per accelerator;
    * ``legal``  (ng,)   — ``can_transition_after`` per group;
    * ``move``   (ng,)   — output-tensor move time through the shared
      interconnect when the *next* group runs elsewhere;
    * ``tau_pair`` (A, A) — per-pair fixed transition in+out cost.

    Shared by the assignment lowering gathers below and by the
    device-resident search tables (:mod:`repro.core.search_jax`), which
    mutate assignment indices directly against these tables.
    """
    names = list(platform.names)
    a_cnt = len(names)
    ng = len(g)
    time_t = np.full((ng, a_cnt), np.nan)
    dem_t = np.zeros((ng, a_cnt))
    legal = np.zeros(ng, dtype=bool)
    out_b = np.zeros(ng)
    for i, grp in enumerate(g):
        legal[i] = grp.can_transition_after
        out_b[i] = grp.out_bytes
        for a, tv in grp.times.items():
            if a in names:
                time_t[i, names.index(a)] = float(tv)
        for a, dv in grp.mem_demand.items():
            if a in names:
                dem_t[i, names.index(a)] = float(dv)
    tau_pair = np.zeros((a_cnt, a_cnt))
    for si, src in enumerate(names):
        for di, dst in enumerate(names):
            if si != di:
                tau_pair[si, di] = (platform.acc(src).transition_out_ms
                                    + platform.acc(dst).transition_in_ms)
    move = (out_b / platform.transition_bw / 1e-3
            if platform.transition_bw else np.zeros(ng))
    return time_t, dem_t, legal, move, tau_pair


def _graph_arrays(platform: Platform, g: DNNGraph,
                  arr: np.ndarray, validate: bool):
    """Vectorized per-graph fill: assignment string array (K, len(g)) ->
    (acc idx, duration, demand, post-group transition delay) arrays."""
    names = list(platform.names)
    a_cnt = len(names)
    ng = len(g)
    if arr.shape[1:] != (ng,):
        raise ValueError(
            f"graph {g.name!r}: assignment shape {arr.shape} != (*, {ng})")
    time_t, dem_t, legal, move, tau_pair = graph_tables(platform, g)

    sorted_names = sorted(names)
    to_idx = np.argsort(np.array(names))            # sorted pos -> acc index
    pos = np.clip(np.searchsorted(sorted_names, arr), 0, a_cnt - 1)
    idx = to_idx[pos]
    if validate and not (np.asarray(names)[idx] == arr).all():
        bad = arr[np.asarray(names)[idx] != arr].ravel()[0]
        raise ValueError(f"{g.name}: unknown accelerator {bad!r}")
    gi = np.arange(ng)
    dur = time_t[gi[None, :], idx]
    if validate and np.isnan(dur).any():
        ci, gix = np.nonzero(np.isnan(dur))
        raise ValueError(
            f"{g.name}[{gix[0]}] cannot run on {arr[ci[0], gix[0]]!r}")
    dem = dem_t[gi[None, :], idx]
    tau = np.zeros_like(dur)
    if ng > 1:
        moved = idx[:, :-1] != idx[:, 1:]
        if validate and (moved & ~legal[None, :-1]).any():
            ci, gix = np.nonzero(moved & ~legal[None, :-1])
            raise ValueError(
                f"{g.name}: illegal transition after group {gix[0]} "
                f"({g[gix[0]].name})")
        tau[:, :-1] = np.where(
            moved, move[None, :-1] + tau_pair[idx[:, :-1], idx[:, 1:]], 0.0)
    return idx, np.nan_to_num(dur), dem, tau


def lower_assignments(platform: Platform, graphs: Sequence[DNNGraph],
                      assignments_batch: Sequence[Sequence[Sequence[str]]],
                      model: ContentionModel | Mapping[str, ContentionModel],
                      iterations: Sequence[int] | None = None,
                      depends_on: Sequence[int | None] | None = None,
                      validate: bool = True) -> ProblemSpec:
    """Solver hot-path lowering: fixed graphs, N assignment vectors.

    Per-graph (group, accelerator) lookup tables are built once and every
    candidate is filled by vectorized gathers — no per-candidate Python
    loop, which is what keeps huge sweeps pack-bound on NumPy rather than
    the interpreter.
    """
    n = len(assignments_batch)
    if n == 0:
        raise ValueError("cannot lower an empty candidate population")
    w = len(graphs)
    gmax = max(len(g) for g in graphs)
    b = _SpecBuilder(platform, n, w, gmax, model)
    b.set_static_columns(list(iterations or [1] * w),
                         list(depends_on or [None] * w))
    for m, g in enumerate(graphs):
        ng = len(g)
        b.ngroups[:, m] = ng
        arr = np.asarray([asgs[m] for asgs in assignments_batch])
        idx, dur, dem, tau = _graph_arrays(platform, g, arr, validate)
        b.acc[:, m, :ng] = idx
        b.dur[:, m, :ng] = dur
        b.dem[:, m, :ng] = dem
        b.tau[:, m, :ng] = tau
    return b.freeze()


def lower_product(platform: Platform, graphs: Sequence[DNNGraph],
                  cand_lists: Sequence[Sequence[Sequence[str]]],
                  model: ContentionModel | Mapping[str, ContentionModel],
                  iterations: Sequence[int] | None = None,
                  depends_on: Sequence[int | None] | None = None,
                  validate: bool = True) -> ProblemSpec:
    """Lower the full cross product of per-graph candidate lists without
    materializing the combinations: each graph's unique assignments are
    packed once, then broadcast into the product in ``itertools.product``
    order by pure index arithmetic."""
    w = len(graphs)
    ks = [len(c) for c in cand_lists]
    n = 1
    for k in ks:
        n *= k
    if n == 0:
        raise ValueError("cannot lower an empty candidate population")
    gmax = max(len(g) for g in graphs)
    b = _SpecBuilder(platform, n, w, gmax, model)
    b.set_static_columns(list(iterations or [1] * w),
                         list(depends_on or [None] * w))
    after = n
    for m, g in enumerate(graphs):
        ng = len(g)
        b.ngroups[:, m] = ng
        arr = np.asarray(list(cand_lists[m]))
        idx, dur, dem, tau = _graph_arrays(platform, g, arr, validate)
        # itertools.product order: graph m's index repeats `after` times
        # within one period and the whole period tiles `before` times.
        after //= ks[m]
        sel = np.tile(np.repeat(np.arange(ks[m]), after), n // (ks[m] * after))
        b.acc[:, m, :ng] = idx[sel]
        b.dur[:, m, :ng] = dur[sel]
        b.dem[:, m, :ng] = dem[sel]
        b.tau[:, m, :ng] = tau[sel]
    return b.freeze()


def concat_specs(specs: Sequence[ProblemSpec]) -> ProblemSpec:
    """Concatenate specs along the candidate axis (shared platform/model;
    same workload count; group axis padded to the max)."""
    first = specs[0]
    w = first.w
    if len({s.w for s in specs}) != 1:
        raise ValueError("all specs in a sweep must share the workload count")
    if any(s.acc_names != first.acc_names for s in specs):
        raise ValueError("all specs in a sweep must share the platform")
    # the concatenated spec adopts the first spec's contention topology and
    # models — reject silently-different ones instead of mis-scoring.
    ref_fp = first._model_fingerprints()
    for s in specs[1:]:
        if (not np.array_equal(s.domshare, first.domshare)
                or not np.array_equal(s.model_of_acc, first.model_of_acc)):
            raise ValueError("all specs in a sweep must share the "
                             "contention-domain topology")
        if s._model_fingerprints() != ref_fp:
            raise ValueError("all specs in a sweep must share the "
                             "contention model(s)")
    gmax = max(s.gmax for s in specs)
    n = sum(s.n for s in specs)

    def cat(name: str, pad_axis2: bool) -> np.ndarray:
        parts = []
        for s in specs:
            a = getattr(s, name)
            if pad_axis2 and s.gmax < gmax:
                pad = np.zeros((s.n, w, gmax - s.gmax), dtype=a.dtype)
                a = np.concatenate([a, pad], axis=2)
            parts.append(a)
        out = np.concatenate(parts, axis=0)
        out.setflags(write=False)    # freshly owned: zero-copy handoff
        return out

    return ProblemSpec(
        n=n, w=w, gmax=gmax, amax=first.amax, acc_names=first.acc_names,
        acc=cat("acc", True), dur=cat("dur", True), dem=cat("dem", True),
        tau=cat("tau", True), ngroups=cat("ngroups", False),
        iters=cat("iters", False), dep=cat("dep", False),
        arrival=cat("arrival", False), domshare=first.domshare,
        model_of_acc=first.model_of_acc, models=first.models,
        surfaces=first.surfaces)


def lower_sweep(
    platform: Platform,
    problems: Sequence[tuple],
    model: ContentionModel | Mapping[str, ContentionModel],
    validate: bool = True,
) -> tuple[ProblemSpec | None, list[slice]]:
    """Lower many problems' cross-product populations into ONE spec.

    ``problems[k] = (graphs, cand_lists, iterations, depends_on)``; returns
    the concatenated spec (None for an empty problem list) plus one
    ``slice`` per problem addressing its candidates inside it.
    """
    specs, slices, lo = [], [], 0
    for graphs, cand_lists, iterations, depends_on in problems:
        s = lower_product(platform, graphs, cand_lists, model,
                          iterations=iterations, depends_on=depends_on,
                          validate=validate)
        specs.append(s)
        slices.append(slice(lo, lo + s.n))
        lo += s.n
    if not specs:
        return None, []
    return concat_specs(specs), slices

"""Greedy contention-aware solver (pure Python, no z3, never exhaustive).

The registry's last-resort entry for ``solver="auto"``: when z3 is missing
and the branch-and-bound search space is too large, this solver still
returns a valid, contention-scored schedule in polynomial time:

  1. evaluate every baseline scheduler under the *exact* contention
     simulator and take the best one as the incumbent (the same §5.3
     starting point the CEGAR loop uses);
  2. improve it with single-group reassignment moves scored by the
     simulator until no move helps (or the sweep budget is hit).

Search backends (the registry ``evaluator`` knob):

* ``"batch"`` (default via ``"auto"``) / ``"jax"`` — population hill
  climb: every legal single-group move of every beam member is scored in
  one ``simulate_assignments`` call of the selected evaluator entry per
  step (steepest ascent; ``beam_width > 1`` keeps the best k incumbents
  alive).  The NumPy entry packs each frontier directly; the jax entry
  lowers it to a :class:`~repro.core.lowering.ProblemSpec` and pads to a
  power of two, so the varying frontier sizes share compiled XLA
  executables.  The final incumbent is re-simulated through the
  authoritative scalar simulator before being returned.
* ``"scalar"`` — the original first-improvement sweep, one scalar
  simulation per move.

The result is never worse than the best baseline — the never-worse
guarantee HaX-CoNN claims for its fallback path — but carries no
optimality certificate (``Solution.optimal`` is always False).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import Workload, simulate

_EPS = 1e-9


def _legal(graph: DNNGraph, assignment: Sequence[str],
           max_transitions: int | None) -> bool:
    trans = 0
    for i in range(len(assignment) - 1):
        if assignment[i] != assignment[i + 1]:
            if not graph[i].can_transition_after:
                return False
            trans += 1
    return max_transitions is None or trans <= max_transitions


def _baseline_pool(platform, graphs, its, deps, max_transitions):
    """(name, workloads) for every registered baseline that yields a legal
    schedule on this platform."""
    from . import registry

    pool = []
    for name in registry.baseline_names():
        try:
            wls = registry.get_baseline(name)(
                platform, graphs, iterations=its, depends_on=deps)
        except (ValueError, KeyError):
            continue
        if any(not _legal(w.graph, w.assignment, max_transitions)
               for w in wls):
            continue
        pool.append((name, wls))
    if not pool:
        raise RuntimeError("no baseline produced a valid schedule")
    return pool


def _neighbors(platform: Platform, graphs: Sequence[DNNGraph],
               asg: tuple[tuple[str, ...], ...],
               max_transitions: int | None):
    """All legal single-group reassignments of ``asg``."""
    for n, g in enumerate(graphs):
        for i in range(len(g)):
            for acc in platform.names:
                if acc == asg[n][i] or acc not in g[i].times:
                    continue
                cand = list(asg[n])
                cand[i] = acc
                if not _legal(g, cand, max_transitions):
                    continue
                yield asg[:n] + (tuple(cand),) + asg[n + 1:]


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    max_sweeps: int = 3,
    evaluator: str = "auto",
    beam_width: int = 1,
):
    from . import registry
    from .solver_bb import Solution

    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    entry = registry.resolve_evaluator(evaluator)
    if entry.name != "scalar":
        return _solve_population(entry, platform, graphs, model, objective,
                                 max_transitions, its, deps, max_sweeps,
                                 beam_width)

    def build(assignments):
        return [Workload(g, tuple(a), iterations=it, depends_on=dep)
                for g, a, it, dep in zip(graphs, assignments, its, deps)]

    # 1) incumbent: best *registered* baseline under the exact simulator.
    best = None
    evaluated = 0
    for _name, wls in _baseline_pool(platform, graphs, its, deps,
                                     max_transitions):
        res = simulate(platform, wls, model, record_timeline=False)
        evaluated += 1
        obj = res.objective(objective)
        if best is None or obj < best[0]:
            best = (obj, wls, res)
    obj, wls, res = best

    # 2) hill climb: single-group reassignments scored by the simulator.
    assignments = [list(w.assignment) for w in wls]
    for _ in range(max_sweeps):
        improved = False
        for n, g in enumerate(graphs):
            for i in range(len(g)):
                for acc in platform.names:
                    if acc == assignments[n][i] or acc not in g[i].times:
                        continue
                    old = assignments[n][i]
                    assignments[n][i] = acc
                    if not _legal(g, assignments[n], max_transitions):
                        assignments[n][i] = old
                        continue
                    cand = build(assignments)
                    cand_res = simulate(platform, cand, model,
                                        record_timeline=False)
                    evaluated += 1
                    cand_obj = cand_res.objective(objective)
                    if cand_obj < obj - _EPS:
                        obj, wls, res = cand_obj, cand, cand_res
                        improved = True
                    else:
                        assignments[n][i] = old
        if not improved:
            break

    return Solution(wls, res, obj, objective, evaluated, optimal=False)


def _solve_population(entry, platform: Platform, graphs: Sequence[DNNGraph],
                      model, objective: str, max_transitions: int | None,
                      its: Sequence[int], deps: Sequence[int | None],
                      max_sweeps: int, beam_width: int):
    from .solver_bb import Solution

    # 1) incumbent: all baselines scored in one batch call.
    pool = _baseline_pool(platform, graphs, its, deps, max_transitions)
    base_asgs = [tuple(w.assignment for w in wls) for _, wls in pool]
    bt = entry.simulate_assignments(platform, graphs, base_asgs, model,
                                    iterations=its, depends_on=deps,
                                    validate=False)
    objs = bt.objective(objective)
    evaluated = len(pool)
    start = int(np.argmin(objs))

    beam: list[tuple[float, tuple[tuple[str, ...], ...]]] = [
        (float(objs[start]), base_asgs[start])]
    seen = {base_asgs[start]}

    # 2) population hill climb: score every legal single-group move of every
    # beam member in one batch per step; steepest ascent with optional beam.
    max_steps = max(1, max_sweeps) * sum(len(g) for g in graphs)
    for _ in range(max_steps):
        frontier: list[tuple[tuple[str, ...], ...]] = []
        for _obj, asg in beam:
            for nb in _neighbors(platform, graphs, asg, max_transitions):
                if nb not in seen:
                    seen.add(nb)
                    frontier.append(nb)
        if not frontier:
            break
        bt = entry.simulate_assignments(platform, graphs, frontier, model,
                                        iterations=its, depends_on=deps,
                                        validate=False)
        objs = bt.objective(objective)
        evaluated += len(frontier)
        merged = beam + [(float(o), a) for o, a in zip(objs, frontier)]
        merged.sort(key=lambda t: t[0])
        improved = merged[0][0] < beam[0][0] - _EPS
        beam = merged[:max(1, beam_width)]
        if not improved:
            break

    best_asg = beam[0][1]
    wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
           for g, a, it, dep in zip(graphs, best_asg, its, deps)]
    # scalar re-simulation: the recorded result is authoritative.
    res = entry.simulate(platform, wls, model, record_timeline=False)
    return Solution(wls, res, res.objective(objective), objective,
                    evaluated, optimal=False)

"""Greedy contention-aware solver (pure Python, no z3, never exhaustive).

The registry's last-resort entry for ``solver="auto"``: when z3 is missing
and the branch-and-bound search space is too large, this solver still
returns a valid, contention-scored schedule in polynomial time:

  1. evaluate every baseline scheduler under the *exact* contention
     simulator and take the best one as the incumbent (the same §5.3
     starting point the CEGAR loop uses);
  2. hill-climb with single-group reassignment moves, accepting only moves
     the simulator scores as strict improvements, until a sweep over every
     (workload, group, accelerator) move finds nothing (or ``max_sweeps``
     is hit).

The result is never worse than the best baseline — the never-worse
guarantee HaX-CoNN claims for its fallback path — but carries no
optimality certificate (``Solution.optimal`` is always False).
"""
from __future__ import annotations

from typing import Mapping, Sequence

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import Workload, simulate

_EPS = 1e-9


def _legal(graph: DNNGraph, assignment: Sequence[str],
           max_transitions: int | None) -> bool:
    trans = 0
    for i in range(len(assignment) - 1):
        if assignment[i] != assignment[i + 1]:
            if not graph[i].can_transition_after:
                return False
            trans += 1
    return max_transitions is None or trans <= max_transitions


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    max_sweeps: int = 3,
):
    from .solver_bb import Solution

    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))

    def build(assignments):
        return [Workload(g, tuple(a), iterations=it, depends_on=dep)
                for g, a, it, dep in zip(graphs, assignments, its, deps)]

    # 1) incumbent: best *registered* baseline under the exact simulator
    # (registry imported lazily — it registers this module at import time).
    from . import registry

    best = None
    evaluated = 0
    for name in registry.baseline_names():
        try:
            wls = registry.get_baseline(name)(
                platform, graphs, iterations=its, depends_on=deps)
        except (ValueError, KeyError):
            continue
        if any(not _legal(w.graph, w.assignment, max_transitions)
               for w in wls):
            continue
        res = simulate(platform, wls, model, record_timeline=False)
        evaluated += 1
        obj = res.objective(objective)
        if best is None or obj < best[0]:
            best = (obj, wls, res)
    if best is None:
        raise RuntimeError("no baseline produced a valid schedule")
    obj, wls, res = best

    # 2) hill climb: single-group reassignments scored by the simulator.
    assignments = [list(w.assignment) for w in wls]
    for _ in range(max_sweeps):
        improved = False
        for n, g in enumerate(graphs):
            for i in range(len(g)):
                for acc in platform.names:
                    if acc == assignments[n][i] or acc not in g[i].times:
                        continue
                    old = assignments[n][i]
                    assignments[n][i] = acc
                    if not _legal(g, assignments[n], max_transitions):
                        assignments[n][i] = old
                        continue
                    cand = build(assignments)
                    cand_res = simulate(platform, cand, model,
                                        record_timeline=False)
                    evaluated += 1
                    cand_obj = cand_res.objective(objective)
                    if cand_obj < obj - _EPS:
                        obj, wls, res = cand_obj, cand, cand_res
                        improved = True
                    else:
                        assignments[n][i] = old
        if not improved:
            break

    return Solution(wls, res, obj, objective, evaluated, optimal=False)

"""Device-resident annealing solver (the ``"anneal"`` registry entry).

A thin host shell around :mod:`repro.core.search_jax`: seed the search from
the best registered baseline schedule (the same pool the greedy solver
starts from), run the jit-compiled island annealer over the lowered tables,
then re-simulate the device incumbent through the authoritative scalar
simulator — the returned :class:`~repro.core.solver_bb.Solution` never
depends on device numerics, exactly like the batch/jax evaluator paths of
the bb and greedy solvers.

The entry is *opt-in*: it registers at priority 30, behind z3 -> bb ->
greedy, so ``solver="auto"`` never reaches it; callers ask for it by name
(``solver="anneal"``) when the joint space is too large to enumerate and
greedy's single-site hill climb stalls.  Search provenance (seed, steps,
population, the device-side objective) is recorded in ``Solution.params``
and flows into :class:`~repro.core.plan.Plan` artifacts.

Knobs left unset fall to fixed defaults — unless ``budget_ms`` is given,
in which case :func:`auto_tune` derives them from the problem's log2
joint-space size, the requested device count, and the *measured*
evaluator throughput (a two-call probe at the final population, or a
``cands_per_s`` hint recorded in a ProfileBundle's provenance) so the
search fills its wall-clock budget instead of guessing.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import Workload
from .solver_bb import Solution
from .solver_greedy import _baseline_pool

#: fixed defaults when no wall-clock budget drives the auto-tuner.
DEFAULT_POPULATION = 2048
DEFAULT_STEPS = 192

#: auto-tune clamps: the population stays large enough for island
#: migration to matter and small enough that compile time stays amortized.
MIN_POPULATION, MAX_POPULATION = 256, 8192
MIN_STEPS, MAX_STEPS = 16, 4096
#: steps used by the throughput probe (compile-warm + one timed call).
PROBE_STEPS = 8


def _round_up(value: float, quantum: int) -> int:
    return max(quantum, int(math.ceil(value / quantum)) * quantum)


def space_bits(tables) -> float:
    """log2 of the joint assignment-space size (ignoring transition
    legality): the sum over live (workload, group) sites of the per-site
    accelerator branching."""
    bits = 0.0
    for m in range(tables.w):
        ng = int(tables.ngroups[m])
        bits += float(np.sum(np.log2(
            np.maximum(tables.n_allowed[m, :ng], 1))))
    return bits


def probe_cands_per_s(tables, *, objective: str = "latency",
                      population: int, island: int,
                      devices: int | None = None, migrate: str = "auto",
                      fanout: str = "auto", backend: str = "auto",
                      precision: str = "float32", seed: int = 0) -> float:
    """Measured steady-state candidates/s of the compiled search.

    Two short runs at the *final* population: the first warms the jit
    cache (the very executable the real search will reuse — probe cost is
    recycled, not wasted), the second is timed.
    """
    from . import search_jax
    kw = dict(objective=objective, seed=seed, population=population,
              island=island, steps=PROBE_STEPS, devices=devices,
              migrate=migrate, fanout=fanout, backend=backend,
              precision=precision)
    search_jax.anneal_search(tables, **kw)        # compile warm-up
    t0 = time.perf_counter()
    out = search_jax.anneal_search(tables, **kw)
    dt = max(time.perf_counter() - t0, 1e-9)
    return out.evaluated / dt


@dataclass(frozen=True)
class TunedKnobs:
    """What :func:`auto_tune` decided, plus how it got there."""

    population: int
    steps: int
    island: int
    cands_per_s: float | None
    probed: bool


def auto_tune(tables, *, budget_ms: float,
              population: int | None = None, steps: int | None = None,
              island: int | None = None, devices: int | None = None,
              cands_per_s: float | None = None, objective: str = "latency",
              migrate: str = "auto", fanout: str = "auto",
              backend: str = "auto", precision: str = "float32",
              seed: int = 0) -> TunedKnobs:
    """Derive (population, steps) filling ``budget_ms`` of search time.

    Population scales with the problem's log2 joint-space size — wider
    spaces get more parallel chains — rounded up to the island x devices
    quantum the mesh requires.  Steps then spend the remaining budget at
    the measured throughput: ``cands_per_s`` when the caller has one (a
    ProfileBundle provenance hint), else a live two-call probe whose
    compiled executable the real search reuses.  Explicitly-set knobs are
    honored and only the unset ones are derived.
    """
    from . import search_jax
    if budget_ms <= 0:
        raise ValueError(f"budget_ms ({budget_ms}) must be > 0")
    isl = search_jax.DEFAULT_ISLAND if island is None else island
    quantum = isl * (devices or 1)
    if population is None:
        # ~64 chains per joint-space bit: small two-DNN pairs get a few
        # hundred chains, Table-6 triples a few thousand.
        population = int(np.clip(_round_up(64.0 * space_bits(tables),
                                           quantum),
                                 _round_up(MIN_POPULATION, quantum),
                                 _round_up(MAX_POPULATION, quantum)))
    probed = False
    if steps is None:
        if cands_per_s is None:
            cands_per_s = probe_cands_per_s(
                tables, objective=objective, population=population,
                island=isl, devices=devices, migrate=migrate,
                fanout=fanout, backend=backend, precision=precision,
                seed=seed)
            probed = True
        # evaluated = population * (steps + 1)  =>  solve for steps.
        steps = int(np.clip(
            budget_ms / 1e3 * cands_per_s / population - 1,
            MIN_STEPS, MAX_STEPS))
    return TunedKnobs(population=population, steps=steps, island=isl,
                      cands_per_s=cands_per_s, probed=probed)


def measure_search_throughput(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    *,
    objective: str = "latency",
    max_transitions: int | None = 3,
    population: int = 1024,
    island: int | None = None,
    devices: int | None = None,
) -> float:
    """Candidates/s of the device search on this host for one problem —
    the number a ProfileBundle records (provenance ``search_cands_per_s``)
    so later budgeted solves can skip the live probe."""
    from . import search_jax
    tables = search_jax.build_tables(
        platform, graphs, model,
        max(len(g) for g in graphs) if max_transitions is None
        else max_transitions)
    isl = search_jax.DEFAULT_ISLAND if island is None else island
    return probe_cands_per_s(tables, objective=objective,
                             population=_round_up(population,
                                                  isl * (devices or 1)),
                             island=isl, devices=devices)


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    *,
    seed: int = 0,
    population: int | None = None,
    steps: int | None = None,
    island: int | None = None,
    exchange_every: int = 16,
    precision: str = "float32",
    backend: str = "auto",
    chunk: int | None = None,
    devices: int | None = None,
    migrate: str = "auto",
    fanout: str = "auto",
    budget_ms: float | None = None,
    cands_per_s: float | None = None,
    evaluator: str = "auto",
) -> Solution:
    from . import registry, search_jax

    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    mt = (max(len(g) for g in graphs) if max_transitions is None
          else max_transitions)
    tables = search_jax.build_tables(platform, graphs, model, mt,
                                     iterations=its, depends_on=deps)
    entry = registry.resolve_evaluator(evaluator)

    tuned = None
    if budget_ms is not None:
        tuned = auto_tune(
            tables, budget_ms=budget_ms, population=population, steps=steps,
            island=island, devices=devices, cands_per_s=cands_per_s,
            objective=objective, migrate=migrate, fanout=fanout,
            backend=backend, precision=precision, seed=seed)
        population, steps, island = (tuned.population, tuned.steps,
                                     tuned.island)
    else:
        island = search_jax.DEFAULT_ISLAND if island is None else island
        if population is None:
            population = _round_up(DEFAULT_POPULATION,
                                   island * (devices or 1))
        if steps is None:
            steps = DEFAULT_STEPS

    # Baseline-seeded start: best registered baseline under the scalar
    # simulator (greedy's incumbent pool).  Failing that, the search falls
    # back to its own duration-greedy single-accelerator init.
    init = init_obj = None
    scalar_evals = 0
    try:
        pool = _baseline_pool(platform, graphs, its, deps, mt)
    except RuntimeError:
        pool = []
    for _name, wls in pool:
        res = entry.simulate(platform, wls, model, record_timeline=False)
        scalar_evals += 1
        obj = res.objective(objective)
        if init_obj is None or obj < init_obj:
            init, init_obj = [w.assignment for w in wls], obj

    out = search_jax.anneal_search(
        tables, objective=objective, seed=seed, population=population,
        steps=steps, island=island, exchange_every=exchange_every,
        precision=precision, backend=backend, chunk=chunk, devices=devices,
        migrate=migrate, fanout=fanout, init_assignment=init,
        init_objective=init_obj)

    # The scalar simulator is authoritative: the recorded result (and the
    # objective the Solution carries) never comes from the device.
    wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
           for g, a, it, dep in zip(graphs, out.assignment, its, deps)]
    res = entry.simulate(platform, wls, model, record_timeline=False)
    scalar_evals += 1
    obj = res.objective(objective)
    if init_obj is not None and init_obj < obj:
        # float32 ranking can (rarely) prefer a mutant the exact simulator
        # scores a hair worse than the baseline seed; never regress.
        wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
               for g, a, it, dep in zip(graphs, init, its, deps)]
        res = entry.simulate(platform, wls, model, record_timeline=False)
        scalar_evals += 1
        obj = res.objective(objective)

    params = {
        "seed": int(out.seed),
        "steps": int(out.steps),
        "population": int(out.population),
        "island": int(island),
        "exchange_every": int(exchange_every),
        "precision": out.precision,
        "backend": out.backend,
        "chain": int(out.chain),
        "device_objective": float(out.objective),
    }
    if devices is not None:
        params.update(devices=int(devices), migrate=out.migrate,
                      fanout=out.fanout)
    if budget_ms is not None:
        params["budget_ms"] = float(budget_ms)
        if tuned is not None and tuned.cands_per_s is not None:
            params["cands_per_s"] = float(tuned.cands_per_s)
            params["throughput_probed"] = bool(tuned.probed)
    return Solution(
        wls, res, obj, objective, out.evaluated + scalar_evals,
        optimal=False, params=params)

"""Device-resident annealing solver (the ``"anneal"`` registry entry).

A thin host shell around :mod:`repro.core.search_jax`: seed the search from
the best registered baseline schedule (the same pool the greedy solver
starts from), run the jit-compiled island annealer over the lowered tables,
then re-simulate the device incumbent through the authoritative scalar
simulator — the returned :class:`~repro.core.solver_bb.Solution` never
depends on device numerics, exactly like the batch/jax evaluator paths of
the bb and greedy solvers.

The entry is *opt-in*: it registers at priority 30, behind z3 -> bb ->
greedy, so ``solver="auto"`` never reaches it; callers ask for it by name
(``solver="anneal"``) when the joint space is too large to enumerate and
greedy's single-site hill climb stalls.  Search provenance (seed, steps,
population, the device-side objective) is recorded in ``Solution.params``
and flows into :class:`~repro.core.plan.Plan` artifacts.
"""
from __future__ import annotations

from typing import Mapping, Sequence

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import Workload
from .solver_bb import Solution
from .solver_greedy import _baseline_pool


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int | None = 3,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    *,
    seed: int = 0,
    population: int = 2048,
    steps: int = 192,
    exchange_every: int = 16,
    precision: str = "float32",
    backend: str = "auto",
    chunk: int | None = None,
    evaluator: str = "auto",
) -> Solution:
    from . import registry, search_jax

    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    mt = (max(len(g) for g in graphs) if max_transitions is None
          else max_transitions)
    tables = search_jax.build_tables(platform, graphs, model, mt,
                                     iterations=its, depends_on=deps)
    entry = registry.resolve_evaluator(evaluator)

    # Baseline-seeded start: best registered baseline under the scalar
    # simulator (greedy's incumbent pool).  Failing that, the search falls
    # back to its own duration-greedy single-accelerator init.
    init = init_obj = None
    scalar_evals = 0
    try:
        pool = _baseline_pool(platform, graphs, its, deps, mt)
    except RuntimeError:
        pool = []
    for _name, wls in pool:
        res = entry.simulate(platform, wls, model, record_timeline=False)
        scalar_evals += 1
        obj = res.objective(objective)
        if init_obj is None or obj < init_obj:
            init, init_obj = [w.assignment for w in wls], obj

    kw = {} if chunk is None else {"chunk": chunk}
    out = search_jax.anneal_search(
        tables, objective=objective, seed=seed, population=population,
        steps=steps, exchange_every=exchange_every, precision=precision,
        backend=backend, init_assignment=init, init_objective=init_obj, **kw)

    # The scalar simulator is authoritative: the recorded result (and the
    # objective the Solution carries) never comes from the device.
    wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
           for g, a, it, dep in zip(graphs, out.assignment, its, deps)]
    res = entry.simulate(platform, wls, model, record_timeline=False)
    scalar_evals += 1
    obj = res.objective(objective)
    if init_obj is not None and init_obj < obj:
        # float32 ranking can (rarely) prefer a mutant the exact simulator
        # scores a hair worse than the baseline seed; never regress.
        wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
               for g, a, it, dep in zip(graphs, init, its, deps)]
        res = entry.simulate(platform, wls, model, record_timeline=False)
        scalar_evals += 1
        obj = res.objective(objective)

    return Solution(
        wls, res, obj, objective, out.evaluated + scalar_evals,
        optimal=False,
        params={
            "seed": int(out.seed),
            "steps": int(out.steps),
            "population": int(out.population),
            "exchange_every": int(exchange_every),
            "precision": out.precision,
            "backend": out.backend,
            "chain": int(out.chain),
            "device_objective": float(out.objective),
        })

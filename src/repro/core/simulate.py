"""Exact event-driven timeline simulation of a concurrent schedule.

This is the authoritative evaluator of Eqs. 2, 4, 5, 7, 8 of the paper.  The
formulation's circularity (end times depend on contention, contention depends
on overlap intervals, intervals depend on end times) is resolved exactly by
event-driven integration: between consecutive events the set of active layers
is constant, so each active layer progresses at the constant rate
``1 / slowdown(own demand, external demand)`` — the paper's *contention
intervals* (Fig. 4) are precisely the spans between our events.

Semantics:
  * each accelerator executes at most one layer group at a time (Eq. 9 with
    ε = 0; the solver may assume ε slack, the simulator is authoritative),
    FIFO among ready workloads;
  * an inter-accelerator transition after group i delays the *workload* by
    τ(out) + τ(in) + bytes/bw (Eq. 2/3) without occupying either accelerator
    (the data moves over the shared path);
  * a workload may run several back-to-back iterations (Table 8 balancing,
    Scenario 1), and may depend on another workload per-iteration
    (Scenario 3 streaming pipelines).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph

_TOL = 1e-9


@dataclass(frozen=True)
class Workload:
    graph: DNNGraph
    #: accelerator name per layer group.
    assignment: tuple[str, ...]
    iterations: int = 1
    #: if set, iteration k of this workload only becomes ready once iteration
    #: k of workload ``depends_on`` has completed (streaming pipeline).
    depends_on: int | None = None
    #: release time offset (ms).
    arrival_ms: float = 0.0

    def __post_init__(self):
        if len(self.assignment) != len(self.graph):
            raise ValueError(
                f"{self.graph.name}: assignment length {len(self.assignment)}"
                f" != {len(self.graph)} groups"
            )


@dataclass(frozen=True)
class Interval:
    """One executed span of a layer group at a constant slowdown."""
    start: float
    end: float
    workload: int
    iteration: int
    group: int
    acc: str
    slowdown: float


@dataclass
class SimResult:
    makespan: float
    finish_times: list[float]
    iteration_latencies: list[list[float]]
    timeline: list[Interval]
    #: wall-clock ms added purely by contention (Σ interval (1 - 1/s) · len).
    contention_ms: float
    #: contention-free total busy ms (for utilization reporting).
    busy_ms: dict[str, float] = field(default_factory=dict)

    @property
    def latency_ms(self) -> float:
        return self.makespan

    @property
    def throughput_fps(self) -> float:
        """Completed DNN inferences per second."""
        n = sum(len(lats) for lats in self.iteration_latencies)
        return 1e3 * n / self.makespan if self.makespan > 0 else float("inf")

    def objective(self, kind: str) -> float:
        """Solver objective: lower is better for every kind."""
        if kind == "latency":       # Eq. 11: min max T_n
            return self.makespan
        if kind == "throughput":    # max completed inferences / second
            return -self.throughput_fps
        if kind == "sum_inverse":   # Eq. 10 literal: max Σ 1/T_n
            return -sum(1.0 / t for t in self.finish_times if t > 0)
        raise ValueError(kind)


def validate_assignment(platform: Platform, wl: Workload) -> None:
    for i, acc in enumerate(wl.assignment):
        if acc not in platform.names:
            raise ValueError(f"{wl.graph.name}[{i}] -> unknown accelerator {acc!r}")
    for i in range(len(wl.assignment) - 1):
        if wl.assignment[i] != wl.assignment[i + 1]:
            if not wl.graph[i].can_transition_after:
                raise ValueError(
                    f"{wl.graph.name}: illegal transition after group {i} "
                    f"({wl.graph[i].name})"
                )


class _WorkloadState:
    __slots__ = ("wl", "idx", "it", "group", "remaining", "ready_at",
                 "it_start", "started", "done", "lat")

    def __init__(self, wl: Workload, idx: int):
        self.wl = wl
        self.idx = idx
        self.it = 0
        self.group = 0
        self.remaining = wl.graph[0].time_on(wl.assignment[0])
        self.ready_at = wl.arrival_ms   # may be raised by dependencies
        self.it_start = wl.arrival_ms
        self.started = False
        self.done = False
        self.lat: list[float] = []

    @property
    def acc(self) -> str:
        return self.wl.assignment[self.group]

    @property
    def demand(self) -> float:
        return self.wl.graph[self.group].demand_on(self.acc)


def simulate(
    platform: Platform,
    workloads: Sequence[Workload],
    model: ContentionModel | Mapping[str, ContentionModel],
    record_timeline: bool = True,
) -> SimResult:
    for wl in workloads:
        validate_assignment(platform, wl)
    models: dict[str, ContentionModel]
    if hasattr(model, "slowdown"):
        models = {dom: model for dom in platform.domains} or {"_": model}  # type: ignore[dict-item]
    else:
        models = dict(model)  # type: ignore[arg-type]

    # accelerator -> contention domains it belongs to
    acc_domains: dict[str, list[str]] = {a: [] for a in platform.names}
    for dom, members in platform.domains.items():
        for m in members:
            acc_domains[m].append(dom)

    states = [_WorkloadState(wl, i) for i, wl in enumerate(workloads)]
    running: dict[str, _WorkloadState] = {}          # acc -> state
    finish: list[float] = [0.0] * len(workloads)
    timeline: list[Interval] = []
    contention_ms = 0.0
    busy: dict[str, float] = {a: 0.0 for a in platform.names}
    t = 0.0

    def slowdown_of(st: _WorkloadState) -> float:
        own = st.demand
        external = 0.0
        for dom in acc_domains[st.acc]:
            for other in running.values():
                if other is st:
                    continue
                if st.acc != other.acc and other.acc in platform.domains[dom]:
                    external += other.demand
        if external <= 0.0 or own <= 0.0:
            return 1.0
        dom = acc_domains[st.acc][0] if acc_domains[st.acc] else "_"
        return max(1.0, models[dom].slowdown(own, external))

    def dependency_ready(st: _WorkloadState) -> bool:
        dep = st.wl.depends_on
        if dep is None:
            return True
        return states[dep].done or states[dep].it > st.it

    guard = 0
    max_events = 200000 + 200 * sum(
        len(w.graph) * w.iterations for w in workloads
    )
    while not all(st.done for st in states):
        guard += 1
        if guard > max_events:
            raise RuntimeError("simulator did not converge (event storm)")

        # 1) start any ready workload whose accelerator is free (FIFO by
        #    ready time then index).
        waiting = [
            st for st in states
            if not st.done and st not in running.values()
            and st.ready_at <= t + _TOL and dependency_ready(st)
        ]
        waiting.sort(key=lambda s: (s.ready_at, s.idx))
        for st in waiting:
            if st.acc not in running:
                running[st.acc] = st
                if st.group == 0 and not st.started:
                    st.it_start = t        # iteration service actually begins
                    st.started = True

        if not running:
            # idle gap: jump to the next arrival / transition end / dependency
            pend = [st.ready_at for st in states
                    if not st.done and st.ready_at > t + _TOL]
            if not pend:
                # blocked purely on a dependency whose producer is running —
                # cannot happen with running empty; guard against deadlock.
                raise RuntimeError("deadlock: nothing running, nothing pending")
            t = min(pend)
            continue

        # 2) compute per-running-layer slowdowns for this contention interval.
        rates = {st.idx: slowdown_of(st) for st in running.values()}

        # 3) next event: earliest completion among running layers, or the
        #    next ready/arrival boundary that could change the active set.
        dt = min(st.remaining * rates[st.idx] for st in running.values())
        horizon = t + dt
        for st in states:
            if (not st.done and st not in running.values()
                    and t + _TOL < st.ready_at < horizon - _TOL):
                horizon = st.ready_at
        span = horizon - t

        # 4) integrate.
        for st in list(running.values()):
            s = rates[st.idx]
            st.remaining -= span / s
            if record_timeline:
                timeline.append(Interval(t, horizon, st.idx, st.it, st.group,
                                         st.acc, s))
            contention_ms += span * (1.0 - 1.0 / s)
            busy[st.acc] += span / s
        t = horizon

        # 5) process completions.
        for acc, st in list(running.items()):
            if st.remaining > _TOL:
                continue
            del running[acc]
            wl = st.wl
            if st.group + 1 < len(wl.graph):
                nxt = st.group + 1
                tau = platform.transition_cost_ms(
                    wl.graph[st.group].out_bytes, wl.assignment[st.group],
                    wl.assignment[nxt])
                st.group = nxt
                st.remaining = wl.graph[nxt].time_on(wl.assignment[nxt])
                st.ready_at = t + tau
            else:
                st.lat.append(t - st.it_start)
                st.it += 1
                st.started = False
                if st.it >= wl.iterations:
                    st.done = True
                    finish[st.idx] = t
                else:
                    st.group = 0
                    st.remaining = wl.graph[0].time_on(wl.assignment[0])
                    st.ready_at = t

    return SimResult(
        makespan=t,
        finish_times=finish,
        iteration_latencies=[st.lat for st in states],
        timeline=timeline,
        contention_ms=contention_ms,
        busy_ms=busy,
    )

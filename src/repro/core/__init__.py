"""HaX-CoNN core: contention-aware concurrent DNN scheduling.

Reproduces "Shared Memory-contention-aware Concurrent DNN Execution for
Diversely Heterogeneous System-on-Chips" (Dagli & Belviranli, 2023) and
generalizes it to TPU-pod virtual accelerators.
"""
from .accelerators import PLATFORMS, Accelerator, Platform
from .contention import (PiecewiseModel, ProportionalShareModel,
                         estimate_blackbox_demand, pccs_from_pairs)
from .graph import DNNGraph, LayerGroup
from .simulate import Interval, SimResult, Workload, simulate
from .solver_bb import Solution

__all__ = [
    "PLATFORMS", "Accelerator", "Platform",
    "PiecewiseModel", "ProportionalShareModel",
    "estimate_blackbox_demand", "pccs_from_pairs",
    "DNNGraph", "LayerGroup",
    "Interval", "SimResult", "Workload", "simulate",
    "Solution",
]

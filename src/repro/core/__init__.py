"""HaX-CoNN core: contention-aware concurrent DNN scheduling.

Reproduces "Shared Memory-contention-aware Concurrent DNN Execution for
Diversely Heterogeneous System-on-Chips" (Dagli & Belviranli, 2023) and
generalizes it to TPU-pod virtual accelerators.

Primary entry points: :class:`Scheduler` (solve/compare against a resolved
platform), :class:`ScheduleRequest` (one validated problem description),
:class:`Plan` (serializable schedule artifact) and :class:`PlanCache`
(content-addressed store).  Solvers, contention models and baselines are
pluggable through :mod:`repro.core.registry`.
"""
from . import registry
from .accelerators import PLATFORMS, Accelerator, Platform
from .contention import (PiecewiseModel, ProportionalShareModel,
                         estimate_blackbox_demand, pccs_from_pairs)
from .graph import DNNGraph, LayerGroup
from .lowering import (ProblemSpec, SlowdownSurface, concat_specs,
                       lower_assignments, lower_product, lower_surface,
                       lower_sweep, lower_workloads,
                       register_surface_lowering,
                       register_vectorized_slowdown, slowdown_array)
from .plan import Plan, PlanCache, ScheduleRequest, ShardedPlanCache
from .scheduler import (DEFAULT_POD_MODEL, DEFAULT_SOC_MODEL, Scheduler,
                        default_model, resolve_graphs, resolve_platform)
from .simulate import Interval, SimResult, Workload, simulate
from .simulate_batch import (BatchTimeline, simulate_assignments,
                             simulate_batch, simulate_spec, simulate_sweep)
from .solver_bb import Solution

__all__ = [
    "PLATFORMS", "Accelerator", "Platform",
    "PiecewiseModel", "ProportionalShareModel",
    "estimate_blackbox_demand", "pccs_from_pairs",
    "DNNGraph", "LayerGroup",
    "Interval", "SimResult", "Workload", "simulate",
    "BatchTimeline", "simulate_assignments", "simulate_batch",
    "simulate_spec", "simulate_sweep",
    "ProblemSpec", "SlowdownSurface", "concat_specs", "lower_assignments",
    "lower_product", "lower_surface", "lower_sweep", "lower_workloads",
    "register_surface_lowering", "register_vectorized_slowdown",
    "slowdown_array",
    "Solution",
    "Plan", "PlanCache", "ScheduleRequest", "Scheduler",
    "ShardedPlanCache",
    "DEFAULT_POD_MODEL", "DEFAULT_SOC_MODEL",
    "default_model", "resolve_graphs", "resolve_platform",
    "registry",
]

"""DNN graph IR for the HaX-CoNN scheduler.

A DNN is an ordered chain of *layer groups* (the paper's atomic schedulable
units, §3.1).  Each group carries the decoupled characterization data of
§3.2-3.3:

  * ``times[a]``        — standalone execution time on accelerator ``a`` (ms)
  * ``mem_demand[a]``   — requested shared-resource bandwidth while running on
                          ``a``, as a *fraction of the contention-domain
                          capacity* (the paper's "Memory Thr. (%)" column)
  * ``out_bytes``       — activation bytes crossing a transition boundary
                          after this group (drives τ(L, a, OUT|IN))
  * ``can_transition_after`` — §3.1 legality (fusion / reformatting /
                          framework constraints collapse illegal boundaries)

Groups may be produced three ways: hand-calibrated paper profiles
(:mod:`repro.core.profiles`), analytic roofline characterization
(:mod:`repro.core.characterize`), or export from a JAX model
(:mod:`repro.models.graph_export`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Sequence


@dataclass(frozen=True)
class LayerGroup:
    """One atomic schedulable unit (a fused span of layers)."""

    name: str
    #: standalone execution time per accelerator name, in milliseconds.
    times: Mapping[str, float]
    #: requested bandwidth on the shared contention domain while executing on
    #: accelerator ``a``, as a fraction in [0, ~1.5] of domain capacity.
    mem_demand: Mapping[str, float] = field(default_factory=dict)
    #: bytes of activation output that must be flushed to shared memory if a
    #: transition happens after this group.
    out_bytes: float = 0.0
    #: whether an inter-accelerator transition is legal after this group.
    can_transition_after: bool = True
    #: bookkeeping: analytic FLOPs / HBM bytes for roofline-derived groups.
    flops: float = 0.0
    hbm_bytes: float = 0.0

    def time_on(self, acc: str) -> float:
        return float(self.times[acc])

    def demand_on(self, acc: str) -> float:
        return float(self.mem_demand.get(acc, 0.0))

    def with_times(self, times: Mapping[str, float]) -> "LayerGroup":
        return dataclasses.replace(self, times=dict(times))


@dataclass(frozen=True)
class DNNGraph:
    """An ordered chain of layer groups belonging to one network."""

    name: str
    groups: tuple[LayerGroup, ...]

    def __post_init__(self) -> None:
        if not self.groups:
            raise ValueError(f"DNN {self.name!r} has no layer groups")

    def __len__(self) -> int:
        return len(self.groups)

    def __iter__(self):
        return iter(self.groups)

    def __getitem__(self, i: int) -> LayerGroup:
        return self.groups[i]

    @property
    def accelerators(self) -> tuple[str, ...]:
        accs: set[str] = set(self.groups[0].times)
        for g in self.groups[1:]:
            accs &= set(g.times)
        return tuple(sorted(accs))

    def standalone_time(self, acc: str) -> float:
        """Total contention-free time if every group runs on ``acc``."""
        return sum(g.time_on(acc) for g in self.groups)

    def transition_points(self) -> tuple[int, ...]:
        """Indices i such that a transition after group i is legal."""
        return tuple(
            i for i, g in enumerate(self.groups[:-1]) if g.can_transition_after
        )

    def merged(self, boundaries: Sequence[int]) -> "DNNGraph":
        """Coarsen: keep only transition boundaries listed in ``boundaries``.

        Groups between consecutive kept boundaries are merged (times and
        demands combine: times add, demand is the time-weighted mean).
        Used to shrink solver instances for very deep networks.
        """
        keep = sorted(set(boundaries) | {len(self.groups) - 1})
        merged: list[LayerGroup] = []
        start = 0
        for b in keep:
            span = self.groups[start : b + 1]
            merged.append(_merge_span(span))
            start = b + 1
        return DNNGraph(self.name, tuple(merged))


def _merge_span(span: Sequence[LayerGroup]) -> LayerGroup:
    if len(span) == 1:
        return span[0]
    accs = set(span[0].times)
    for g in span[1:]:
        accs &= set(g.times)
    times = {a: sum(g.time_on(a) for g in span) for a in accs}
    demand = {}
    for a in accs:
        tot = times[a]
        demand[a] = (
            sum(g.demand_on(a) * g.time_on(a) for g in span) / tot if tot else 0.0
        )
    return LayerGroup(
        name=f"{span[0].name}..{span[-1].name}",
        times=times,
        mem_demand=demand,
        out_bytes=span[-1].out_bytes,
        can_transition_after=span[-1].can_transition_after,
        flops=sum(g.flops for g in span),
        hbm_bytes=sum(g.hbm_bytes for g in span),
    )

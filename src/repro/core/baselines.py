"""Baseline schedulers the paper compares against (§5, Table 1).

All baselines return per-group assignments which are then evaluated by the
*exact* contention-aware simulator — reproducing the paper's observation that
contention-unaware schedulers mispredict timings (by up to 75%, §5.2) and
therefore produce inefficient schedules.

  * ``fastest_only``      — Case 1: everything serialized on the fastest
                            accelerator (GPU-only).
  * ``naive_concurrent``  — Case 2: whole-DNN mapping, one DNN per
                            accelerator (no layer-level transitions).
  * ``mensa_like``        — greedy per-layer, per-DNN affinity mapping with
                            myopic transition costs, contention-unaware
                            (Mensa [6] supports single-DNN only: each DNN is
                            mapped independently of the others).
  * ``herald_like``       — multi-DNN load-balancing list scheduler, no
                            transition costs, contention-unaware (Herald [35]).
  * ``h2h_like``          — Herald + transition-cost awareness (H2H [69]),
                            still contention-unaware.
"""
from __future__ import annotations

import itertools
from typing import Sequence

from .accelerators import Platform
from .graph import DNNGraph
from .simulate import Workload


def _fastest(platform: Platform, graphs: Sequence[DNNGraph]) -> str:
    """Accelerator with the lowest total standalone time over all graphs."""
    accs = set(platform.names)
    for g in graphs:
        accs &= set(g.accelerators)
    if not accs:
        raise ValueError("no accelerator supports every graph")
    return min(accs, key=lambda a: sum(g.standalone_time(a) for g in graphs))


def _mk(graphs, assignments, iterations, depends_on):
    its = iterations or [1] * len(graphs)
    deps = depends_on or [None] * len(graphs)
    return [
        Workload(g, tuple(a), iterations=its[i], depends_on=deps[i])
        for i, (g, a) in enumerate(zip(graphs, assignments))
    ]


def fastest_only(platform: Platform, graphs: Sequence[DNNGraph],
                 iterations=None, depends_on=None) -> list[Workload]:
    best = _fastest(platform, graphs)
    return _mk(graphs, [[best] * len(g) for g in graphs], iterations, depends_on)


def naive_concurrent(platform: Platform, graphs: Sequence[DNNGraph],
                     iterations=None, depends_on=None) -> list[Workload]:
    """Whole-DNN mapping (no layer-level transitions): pick the whole-network
    to accelerator assignment minimizing the *contention-free* makespan bound
    (max of per-accelerator load and per-DNN runtime) — the strongest
    schedule expressible without layer splitting, still contention-blind."""
    its = iterations or [1] * len(graphs)
    best: tuple[float, list[str]] | None = None
    for combo in itertools.product(platform.names, repeat=len(graphs)):
        if any(a not in g.accelerators for a, g in zip(combo, graphs)):
            continue
        load: dict[str, float] = {a: 0.0 for a in platform.names}
        paths = []
        for a, g, it in zip(combo, graphs, its):
            t = g.standalone_time(a) * it
            load[a] += t
            paths.append(t)
        bound = max(max(load.values()), max(paths))
        if best is None or bound < best[0]:
            best = (bound, list(combo))
    if best is None:
        raise ValueError("no feasible whole-DNN mapping")
    assignments = [[a] * len(g) for a, g in zip(best[1], graphs)]
    return _mk(graphs, assignments, iterations, depends_on)


def mensa_like(platform: Platform, graphs: Sequence[DNNGraph],
               iterations=None, depends_on=None) -> list[Workload]:
    """Greedy per-layer affinity with myopic transition accounting.

    For each DNN independently: walk groups in order and pick the accelerator
    minimizing (group time + transition cost from the previous choice).
    Ignores other DNNs and contention entirely.
    """
    assignments = []
    for g in graphs:
        choice: list[str] = []
        for i, grp in enumerate(g):
            def cost(a: str) -> float:
                c = grp.time_on(a)
                if choice and a != choice[-1]:
                    if not g[i - 1].can_transition_after:
                        return float("inf")
                    c += platform.transition_cost_ms(
                        g[i - 1].out_bytes, choice[-1], a)
                return c
            choice.append(min(grp.times, key=cost))
        assignments.append(choice)
    return _mk(graphs, assignments, iterations, depends_on)


def _list_schedule(platform: Platform, graphs: Sequence[DNNGraph],
                   transition_aware: bool) -> list[list[str]]:
    """Contention-unaware multi-DNN list scheduler (Herald/H2H stand-ins).

    Event-driven greedy: repeatedly dispatch the next group of the DNN whose
    frontier is earliest, to the accelerator minimizing its *predicted*
    completion (no contention in the prediction).
    """
    avail = {a: 0.0 for a in platform.names}
    frontier = [0.0] * len(graphs)       # time the DNN's next group is ready
    idx = [0] * len(graphs)
    last_acc: list[str | None] = [None] * len(graphs)
    assignments: list[list[str]] = [[] for _ in graphs]
    remaining = sum(len(g) for g in graphs)
    while remaining:
        n = min((i for i in range(len(graphs)) if idx[i] < len(graphs[i])),
                key=lambda i: frontier[i])
        g, i = graphs[n], idx[n]
        grp = g[i]

        def completion(a: str) -> float:
            start = max(avail[a], frontier[n])
            tau = 0.0
            if transition_aware and last_acc[n] is not None and a != last_acc[n]:
                if not g[i - 1].can_transition_after:
                    return float("inf")
                tau = platform.transition_cost_ms(g[i - 1].out_bytes,
                                                  last_acc[n], a)
            elif (last_acc[n] is not None and a != last_acc[n]
                  and not g[i - 1].can_transition_after):
                return float("inf")
            return start + tau + grp.time_on(a)

        acc = min(grp.times, key=completion)
        done = completion(acc)
        avail[acc] = done
        frontier[n] = done
        last_acc[n] = acc
        assignments[n].append(acc)
        idx[n] += 1
        remaining -= 1
    return assignments


def herald_like(platform: Platform, graphs: Sequence[DNNGraph],
                iterations=None, depends_on=None) -> list[Workload]:
    return _mk(graphs, _list_schedule(platform, graphs, transition_aware=False),
               iterations, depends_on)


def h2h_like(platform: Platform, graphs: Sequence[DNNGraph],
             iterations=None, depends_on=None) -> list[Workload]:
    return _mk(graphs, _list_schedule(platform, graphs, transition_aware=True),
               iterations, depends_on)


BASELINES = {
    "fastest_only": fastest_only,
    "naive_concurrent": naive_concurrent,
    "mensa": mensa_like,
    "herald": herald_like,
    "h2h": h2h_like,
}

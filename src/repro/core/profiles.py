"""Paper-calibrated DNN layer-group profiles (§3.2, Tables 2 & 5).

The paper publishes (a) full per-group profiles for GoogleNet on Xavier
(Table 2: GPU/DLA times, G→D transition times, requested memory throughput),
(b) whole-network standalone runtimes for ten DNNs on both NVIDIA platforms
(Table 5), and (c) qualitative per-network characteristics (D/G ratio ranges
per net, which nets are compute- vs memory-intensive, where DLA is
proportionally fast).  We reconstruct layer-group profiles as follows:

  * GoogleNet uses Table 2 verbatim, rescaled so column totals match the
    Table 5 standalone totals of the target platform.
  * Every other network gets a documented group template: per-group GPU time
    weights (sum 1), per-group DLA/GPU ratios (inside the published per-net
    ranges: VGG-19 1.2–3.4x, ResNet152 1.3–1.9x, GoogleNet 1.40–2.02x),
    per-group requested memory throughput (shaped like Table 2: higher for
    early large-activation groups; low overall for compute-dense CaffeNet per
    §5.4 obs. 3), and boundary activation sizes (decreasing with depth, cheap
    after pooling, Table 2 col 5).  Totals are rescaled to Table 5.
  * Snapdragon 865 profiles are anchored to the Table 6 GPU-only latencies of
    experiments 9–10 with a uniform DSP/GPU ratio of 1.5 (the paper: "GPU &
    DSP are more balanced ... in this platform").

Absolute times therefore match the paper where published; where only totals
or ranges are published the shapes are synthetic-but-constrained, and
EXPERIMENTS.md compares *improvement percentages* (the paper's headline
claims) rather than absolute milliseconds.
"""
from __future__ import annotations

from dataclasses import dataclass

from .accelerators import MS, Platform
from .graph import DNNGraph, LayerGroup

KB = 1e3
MB = 1e6

# ---------------------------------------------------------------------------
# Table 5 standalone runtimes (ms): (orin_gpu, orin_dla, xavier_gpu, xavier_dla)
# ---------------------------------------------------------------------------
TABLE5 = {
    "caffenet":   (0.74, 1.79, 2.26, 5.51),
    "densenet":   (2.19, 3.10, 7.84, None),
    "googlenet":  (0.99, 1.52, 1.98, 3.68),
    "inc-res-v2": (3.06, 5.15, 15.12, 17.95),
    "inception":  (2.49, 5.66, 8.31, 15.94),
    "resnet18":   (0.41, 0.74, 1.37, 2.81),
    "resnet50":   (0.91, 1.67, 2.88, 6.01),
    "resnet101":  (1.56, 2.47, 5.34, 10.60),
    "resnet152":  (2.19, 3.26, 7.70, 12.71),
    "vgg19":      (1.07, 2.93, 5.95, 19.05),
    # not in Table 5 — calibrated from experiment rows / sized analogues:
    "alexnet":    (0.74, 1.79, 2.26, 5.51),   # CaffeNet twin (AlexNet deploy)
    "fcn-resnet18": (1.60, 2.70, 5.70, 11.40),  # Exp 5 residual budget
    "mobilenet":  (0.60, 1.00, 1.90, 3.60),
    "vgg16":      (0.95, 2.60, 5.30, 17.00),
}

# ---------------------------------------------------------------------------
# Table 2: GoogleNet on Xavier — (gpu_ms, dla_ms, trans_G2D_ms, mem_thr_frac)
# ---------------------------------------------------------------------------
TABLE2_GOOGLENET = (
    ("g0-9",     0.45, 0.75, 0.056, 0.4197),
    ("g10-24",   0.19, 0.34, 0.075, 0.6221),
    ("g25-38",   0.31, 0.45, 0.062, 0.7849),
    ("g39-53",   0.18, 0.37, 0.011, 0.5341),
    ("g54-66",   0.16, 0.31, 0.055, 0.5570),
    ("g67-80",   0.17, 0.33, 0.024, 0.5924),
    ("g81-94",   0.21, 0.31, 0.058, 0.6260),
    ("g95-109",  0.25, 0.35, 0.030, 0.7612),
    ("g110-123", 0.16, 0.27, 0.024, 0.6695),
    ("g124-140", 0.24, 0.36, 0.007, 0.4796),
)

# ---------------------------------------------------------------------------
# Group templates for the other networks:
#   (weight of GPU time, DLA/GPU ratio, GPU mem demand, boundary out bytes)
# Ratios stay inside published ranges; demands follow the Table-2 shape.
# ---------------------------------------------------------------------------
_T = {
    "vgg19": [   # paper: ratios 1.2-3.4; DLA proportionally fast EARLY
        (0.22, 1.25, 0.82, 3.2 * MB), (0.18, 1.60, 0.74, 1.6 * MB),
        (0.20, 2.60, 0.60, 0.8 * MB), (0.16, 3.40, 0.48, 0.4 * MB),
        (0.14, 3.20, 0.42, 0.2 * MB), (0.10, 2.40, 0.30, 40 * KB),
    ],
    "vgg16": [
        (0.24, 1.30, 0.80, 3.2 * MB), (0.20, 1.70, 0.72, 1.6 * MB),
        (0.22, 2.70, 0.58, 0.8 * MB), (0.18, 3.30, 0.46, 0.4 * MB),
        (0.16, 2.50, 0.32, 40 * KB),
    ],
    "resnet101": [  # ratios 1.3-1.9 (ResNet-152 range shared)
        (0.08, 1.90, 0.78, 1.6 * MB), (0.10, 1.80, 0.66, 0.8 * MB),
        (0.13, 1.70, 0.60, 0.8 * MB), (0.13, 1.60, 0.56, 0.4 * MB),
        (0.13, 1.55, 0.52, 0.4 * MB), (0.13, 1.45, 0.50, 0.4 * MB),
        (0.20, 1.30, 0.44, 0.2 * MB), (0.10, 1.40, 0.34, 16 * KB),
    ],
    "resnet152": [
        (0.07, 1.90, 0.78, 1.6 * MB), (0.09, 1.80, 0.66, 0.8 * MB),
        (0.12, 1.72, 0.62, 0.8 * MB), (0.14, 1.62, 0.58, 0.4 * MB),
        (0.14, 1.55, 0.54, 0.4 * MB), (0.14, 1.48, 0.50, 0.4 * MB),
        (0.20, 1.32, 0.44, 0.2 * MB), (0.10, 1.40, 0.34, 16 * KB),
    ],
    "resnet50": [
        (0.12, 1.85, 0.76, 1.6 * MB), (0.18, 1.70, 0.64, 0.8 * MB),
        (0.22, 1.60, 0.56, 0.4 * MB), (0.28, 1.45, 0.48, 0.2 * MB),
        (0.20, 1.35, 0.36, 16 * KB),
    ],
    "resnet18": [
        (0.18, 1.95, 0.74, 0.8 * MB), (0.24, 1.80, 0.62, 0.4 * MB),
        (0.28, 1.65, 0.52, 0.2 * MB), (0.30, 1.50, 0.40, 16 * KB),
    ],
    "inception": [  # Inception-V4; avg ratio ~1.9
        (0.10, 2.10, 0.72, 1.2 * MB), (0.11, 2.00, 0.66, 0.8 * MB),
        (0.12, 1.95, 0.62, 0.8 * MB), (0.12, 1.90, 0.58, 0.6 * MB),
        (0.12, 1.88, 0.56, 0.6 * MB), (0.11, 1.85, 0.54, 0.4 * MB),
        (0.11, 1.82, 0.50, 0.4 * MB), (0.11, 1.78, 0.46, 0.2 * MB),
        (0.10, 1.70, 0.38, 24 * KB),
    ],
    "inc-res-v2": [  # 985 layers -> most groups; avg ratio ~1.19
        (0.08, 1.35, 0.70, 1.2 * MB), (0.08, 1.30, 0.66, 0.8 * MB),
        (0.09, 1.28, 0.62, 0.8 * MB), (0.09, 1.25, 0.60, 0.6 * MB),
        (0.09, 1.22, 0.58, 0.6 * MB), (0.09, 1.20, 0.56, 0.6 * MB),
        (0.08, 1.18, 0.54, 0.4 * MB), (0.08, 1.16, 0.52, 0.4 * MB),
        (0.08, 1.14, 0.50, 0.4 * MB), (0.08, 1.12, 0.46, 0.2 * MB),
        (0.08, 1.10, 0.42, 0.2 * MB), (0.08, 1.08, 0.36, 24 * KB),
    ],
    "caffenet": [  # compute-dense, little contention pressure (§5.4 obs. 3)
        (0.26, 2.60, 0.38, 1.0 * MB), (0.22, 2.50, 0.32, 0.6 * MB),
        (0.20, 2.45, 0.28, 0.3 * MB), (0.18, 2.35, 0.22, 0.2 * MB),
        (0.14, 2.25, 0.16, 16 * KB),
    ],
    "alexnet": [
        (0.26, 2.60, 0.38, 1.0 * MB), (0.22, 2.50, 0.32, 0.6 * MB),
        (0.20, 2.45, 0.28, 0.3 * MB), (0.18, 2.35, 0.22, 0.2 * MB),
        (0.14, 2.25, 0.16, 16 * KB),
    ],
    "densenet": [  # DLA proportionally fast LATE (§5.4 obs. 2)
        (0.14, 1.75, 0.76, 1.2 * MB), (0.14, 1.65, 0.70, 0.8 * MB),
        (0.13, 1.55, 0.66, 0.8 * MB), (0.13, 1.45, 0.62, 0.6 * MB),
        (0.12, 1.35, 0.58, 0.4 * MB), (0.12, 1.25, 0.52, 0.4 * MB),
        (0.12, 1.12, 0.46, 0.2 * MB), (0.10, 1.05, 0.38, 24 * KB),
    ],
    "fcn-resnet18": [
        (0.16, 2.10, 0.78, 1.6 * MB), (0.20, 2.00, 0.70, 0.8 * MB),
        (0.22, 1.90, 0.62, 0.8 * MB), (0.22, 1.95, 0.66, 1.6 * MB),
        (0.20, 2.05, 0.72, 3.2 * MB),   # upsampling head: big activations
    ],
    "mobilenet": [
        (0.22, 1.70, 0.60, 0.6 * MB), (0.26, 1.60, 0.54, 0.3 * MB),
        (0.28, 1.55, 0.48, 0.2 * MB), (0.24, 1.45, 0.36, 16 * KB),
    ],
}

DNN_SET = ("caffenet", "densenet", "googlenet", "inc-res-v2", "inception",
           "resnet18", "resnet50", "resnet101", "resnet152", "vgg19")


@dataclass(frozen=True)
class _PlatKey:
    gpu_col: int
    dla_col: int
    dsa_name: str


_PLATFORM_COLS = {
    "agx-orin": _PlatKey(0, 1, "DLA"),
    "xavier-agx": _PlatKey(2, 3, "DLA"),
}

# Snapdragon 865: GPU anchored to Table-6 GPU-only rows (exp 9-10), DSP=1.5x.
_SD865_GPU_SCALE = 13.4   # x Xavier-GPU ms; fits 98.3ms (exp9), 219.6 (exp10)
_SD865_DSP_RATIO = 1.5


def _transition_bytes(platform: Platform, trans_ms: float,
                      src: str = "GPU", dst: str = "DLA") -> float:
    fixed = (platform.acc(src).transition_out_ms
             + platform.acc(dst).transition_in_ms)
    return max(0.0, (trans_ms - fixed) * MS) * platform.transition_bw


def get_graph(dnn: str, platform: Platform) -> DNNGraph:
    """Layer-group graph of ``dnn`` calibrated for ``platform``."""
    dnn = dnn.lower()
    if dnn not in TABLE5:
        raise KeyError(f"unknown DNN {dnn!r}; have {sorted(TABLE5)}")

    if platform.name == "snapdragon-865":
        g_tot = TABLE5[dnn][2] * _SD865_GPU_SCALE
        d_tot = g_tot * _SD865_DSP_RATIO
        dsa = "DSP"
    elif platform.name in _PLATFORM_COLS:
        key = _PLATFORM_COLS[platform.name]
        g_tot = TABLE5[dnn][key.gpu_col]
        d_tot = TABLE5[dnn][key.dla_col]
        dsa = key.dsa_name
    else:
        raise ValueError(f"no paper profiles for platform {platform.name!r}")

    if dnn == "googlenet":
        gpu_raw = sum(r[1] for r in TABLE2_GOOGLENET)
        dla_raw = sum(r[2] for r in TABLE2_GOOGLENET)
        groups = []
        for name, g, d, tr, thr in TABLE2_GOOGLENET:
            t_gpu = g * g_tot / gpu_raw
            times = {"GPU": t_gpu}
            demand = {"GPU": thr}
            if d_tot is not None:
                t_dla = d * d_tot / dla_raw
                times[dsa] = t_dla
                # §3.3 black-box estimate: scale GPU demand by the EMC
                # utilization ratio (calibrated as sqrt of the time ratio —
                # DLA moves the same bytes over a longer window but with
                # burstier, less latency-tolerant access).
                demand[dsa] = thr * (t_gpu / t_dla) ** 0.5
            groups.append(LayerGroup(
                name=name, times=times, mem_demand=demand,
                out_bytes=_transition_bytes(
                    platform, tr, "GPU",
                    dsa if dsa in times else platform.names[-1]),
            ))
        return DNNGraph(dnn, tuple(groups))

    tpl = _T[dnn]
    wsum = sum(w for w, *_ in tpl)
    groups = []
    for gi, (w, ratio, thr, out_b) in enumerate(tpl):
        t_gpu = g_tot * w / wsum
        times = {"GPU": t_gpu}
        demand = {"GPU": thr}
        if d_tot is not None:
            # per-group ratios are shape; normalize so DLA total matches.
            ratio_norm = d_tot / g_tot
            ratio_scale = ratio_norm / (
                sum(wi * ri for wi, ri, *_ in tpl) / wsum)
            t_dla = t_gpu * ratio * ratio_scale
            times[dsa] = t_dla
            demand[dsa] = thr * (t_gpu / t_dla) ** 0.5
        groups.append(LayerGroup(
            name=f"{dnn}-g{gi}", times=times, mem_demand=demand,
            out_bytes=out_b))
    return DNNGraph(dnn, tuple(groups))


def chain(*graphs: DNNGraph) -> DNNGraph:
    """Serially-dependent DNNs as one schedulable chain (Scenario 4 pairs)."""
    groups = []
    for g in graphs:
        groups.extend(g.groups)
    return DNNGraph("+".join(g.name for g in graphs), tuple(groups))

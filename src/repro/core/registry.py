"""Entry-point-style registries for solvers, contention models and baselines.

The Scheduler/Plan API (:mod:`repro.core.scheduler`, :mod:`repro.core.plan`)
never hard-codes a solver module: every schedule is produced by a *named*
solver entry looked up here, every serialized plan records which entry
produced it, and contention models round-trip through named codecs so a
:class:`~repro.core.plan.Plan` artifact is self-describing.  Third-party
backends register themselves at import time exactly like the built-ins
below:

    from repro.core import registry

    @registry.register_solver("ilp", priority=5,
                              available=lambda: HAVE_PULP)
    def solve_ilp(platform, graphs, model, *, objective, max_transitions,
                  iterations, depends_on, deadline_s):
        ...
        return Solution(...)

``solver="auto"`` resolves to the best *available* entry by ascending
priority and degrades down the list when an entry raises ``ValueError``
(e.g. the exhaustive search space is too large): z3 -> bb -> greedy with the
built-ins.
"""
from __future__ import annotations

import re
from collections import abc as _abc
from dataclasses import dataclass
from typing import Any, Callable, Mapping

from . import baselines as _baselines
from . import simulate_batch as _sb
from . import solver_bb, solver_greedy, solver_z3
from .contention import PiecewiseModel, ProportionalShareModel
from .simulate import SimResult, Workload, simulate
from .solver_bb import Solution
from ..obs import get_logger

AUTO = "auto"
#: evaluator auto-selection sentinel (same spelling as the solver knob).
EVAL_AUTO = "auto"


class SolverUnavailable(RuntimeError):
    """A solver entry exists but its backend is not importable here."""


class UnknownEntryError(KeyError):
    """Lookup of an unregistered entry name (solver/evaluator/
    contention-model/baseline).

    A ``KeyError`` whose ``str()`` is the human-readable message (plain
    ``KeyError`` reprs its argument), so CLI surfaces can show it directly;
    the message always lists the registered names.
    """

    def __str__(self) -> str:  # pragma: no cover - trivial
        return self.args[0] if self.args else ""


# ---------------------------------------------------------------------------
# solvers
# ---------------------------------------------------------------------------

#: uniform solver signature: ``fn(platform, graphs, model, *, objective,
#: max_transitions, iterations, depends_on, deadline_s) -> Solution``.
SolverFn = Callable[..., Solution]


@dataclass(frozen=True)
class SolverEntry:
    name: str
    fn: SolverFn
    #: probed at dispatch time — an entry may be registered unconditionally
    #: while its backend (z3, ...) is an optional dependency.
    available: Callable[[], bool]
    #: ascending preference order for ``solver="auto"``.
    priority: int
    description: str = ""
    #: extra keyword knobs this entry accepts beyond the uniform solver
    #: signature — the vocabulary :func:`validate_solver_knobs` checks
    #: ``Scheduler.solve(**knobs)`` pass-throughs against.
    knobs: tuple[str, ...] = ()


_SOLVERS: dict[str, SolverEntry] = {}


def register_solver(name: str, *, priority: int = 100,
                    available: Callable[[], bool] = lambda: True,
                    description: str = "",
                    knobs: tuple[str, ...] = (),
                    replace: bool = False) -> Callable[[SolverFn], SolverFn]:
    """Decorator registering a solver entry under ``name``."""

    def deco(fn: SolverFn) -> SolverFn:
        if name in _SOLVERS and not replace:
            raise ValueError(f"solver {name!r} already registered")
        _SOLVERS[name] = SolverEntry(name, fn, available, priority,
                                     description or (fn.__doc__ or ""),
                                     tuple(knobs))
        return fn

    return deco


def solver_names() -> tuple[str, ...]:
    """Registered solver names in auto-dispatch (priority) order."""
    return tuple(e.name for e in
                 sorted(_SOLVERS.values(), key=lambda e: e.priority))


def get_solver(name: str) -> SolverEntry:
    """Look up one entry; raises with the known names on a typo."""
    try:
        return _SOLVERS[name]
    except KeyError:
        raise UnknownEntryError(
            f"unknown solver {name!r}; registered solvers: "
            f"{', '.join(solver_names())} (or {AUTO!r})") from None


def validate_solver_knobs(solver: str, knobs: Mapping[str, Any]) -> None:
    """Reject unknown solver knobs up front, listing the valid names.

    Knobs are per-entry vocabulary, so they require a *named* solver:
    with ``solver="auto"`` the dispatch target (hence the legal knob set)
    is unknowable before solve time and the combination is refused.
    """
    if not knobs:
        return
    if solver == AUTO:
        raise UnknownEntryError(
            f"solver knobs {sorted(knobs)} require an explicit solver "
            f"(knob vocabularies are per-entry); pick one of: "
            f"{', '.join(n for n in solver_names() if _SOLVERS[n].knobs)}")
    entry = get_solver(solver)
    unknown = sorted(set(knobs) - set(entry.knobs))
    if unknown:
        valid = ", ".join(entry.knobs) if entry.knobs else "none"
        raise UnknownEntryError(
            f"unknown knob(s) {unknown} for solver {solver!r}; "
            f"valid knobs: {valid}")


def auto_order() -> tuple[SolverEntry, ...]:
    """Available entries in the order ``solver="auto"`` tries them."""
    return tuple(e for e in sorted(_SOLVERS.values(),
                                   key=lambda e: e.priority)
                 if e.available())


def dispatch_order(name: str) -> tuple[SolverEntry, ...]:
    """Entries to try for a requested solver name (length 1 unless auto)."""
    if name == AUTO:
        order = auto_order()
        if not order:
            raise SolverUnavailable("no solver backend is available")
        return order
    entry = get_solver(name)
    if not entry.available():
        raise SolverUnavailable(
            f"solver {name!r} is registered but its backend is not "
            f"available (available: "
            f"{', '.join(e.name for e in auto_order()) or 'none'})")
    return (entry,)


@register_solver("z3", priority=0,
                 available=lambda: solver_z3.HAVE_Z3,
                 description="CEGAR-optimal via Z3 + exact simulator (§3.4)")
def _solve_z3(platform, graphs, model, *, objective, max_transitions,
              iterations, depends_on, deadline_s,
              evaluator=EVAL_AUTO) -> Solution:
    # CEGAR refines one counterexample at a time; its simulator use is
    # inherently scalar, so the evaluator knob is accepted but unused.
    return solver_z3.solve(platform, graphs, model, objective=objective,
                           max_transitions=max_transitions,
                           iterations=iterations, depends_on=depends_on,
                           deadline_s=deadline_s)


@register_solver("bb", priority=10,
                 description="exact branch-and-bound (pure Python)")
def _solve_bb(platform, graphs, model, *, objective, max_transitions,
              iterations, depends_on, deadline_s,
              evaluator=EVAL_AUTO) -> Solution:
    # bb has no deadline (it is exact or refuses); None transitions = full
    # space, bounded by the longest chain.
    mt = (max(len(g) for g in graphs) if max_transitions is None
          else max_transitions)
    return solver_bb.solve(platform, graphs, model, objective, mt,
                           iterations, depends_on, evaluator=evaluator)


@register_solver("greedy", priority=20,
                 description="best baseline + simulator-scored hill climb")
def _solve_greedy(platform, graphs, model, *, objective, max_transitions,
                  iterations, depends_on, deadline_s,
                  evaluator=EVAL_AUTO) -> Solution:
    return solver_greedy.solve(platform, graphs, model, objective=objective,
                               max_transitions=max_transitions,
                               iterations=iterations, depends_on=depends_on,
                               evaluator=evaluator)


#: the anneal entry's pass-through knob vocabulary — kept next to the
#: registration so `Scheduler.solve(**knobs)` validation and the actual
#: `solver_anneal.solve` signature stay in one reviewable place.
ANNEAL_KNOBS = ("seed", "population", "steps", "island", "exchange_every",
                "precision", "backend", "chunk", "devices", "migrate",
                "fanout", "budget_ms", "cands_per_s")


# priority 30: greedy (20) always succeeds, so "auto" never degrades this
# far — the device search is strictly opt-in via solver="anneal".
@register_solver("anneal", priority=30,
                 available=lambda: _jax_available(),
                 knobs=ANNEAL_KNOBS,
                 description="device-resident island annealing over the "
                             "lowered IR (core.search_jax; jax, opt-in)")
def _solve_anneal(platform, graphs, model, *, objective, max_transitions,
                  iterations, depends_on, deadline_s,
                  evaluator=EVAL_AUTO, **knobs) -> Solution:
    # deadline-free like bb: the step budget, not wall-clock, bounds the
    # search.  Extra knobs (seed, population, steps, ...) pass through for
    # direct registry callers; Scheduler sends only the uniform signature.
    from . import solver_anneal
    return solver_anneal.solve(platform, graphs, model, objective=objective,
                               max_transitions=max_transitions,
                               iterations=iterations, depends_on=depends_on,
                               evaluator=evaluator, **knobs)


# ---------------------------------------------------------------------------
# evaluators: how candidate schedules are scored (batch vs scalar)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EvaluatorEntry:
    """One named way to score candidate schedules under the Eq. 2-8 timeline.

    ``simulate`` scores a single candidate and is always the authoritative
    scalar simulator; ``simulate_batch``/``simulate_assignments`` score a
    population in one call.  The "scalar" entry implements the batch
    interface as a plain loop over the scalar simulator, so every call site
    written against the batch shape can fall back with ``evaluator="scalar"``
    and nothing else changes.
    """

    name: str
    simulate: Callable[..., SimResult]
    simulate_batch: Callable[..., "_sb.BatchTimeline"]
    simulate_assignments: Callable[..., "_sb.BatchTimeline"]
    available: Callable[[], bool]
    #: ascending preference order for ``evaluator="auto"``.
    priority: int
    description: str = ""


_EVALUATORS: dict[str, EvaluatorEntry] = {}


def register_evaluator(name: str, *, simulate: Callable[..., SimResult],
                       simulate_batch: Callable[..., "_sb.BatchTimeline"],
                       simulate_assignments: Callable[..., "_sb.BatchTimeline"],
                       priority: int = 100,
                       available: Callable[[], bool] = lambda: True,
                       description: str = "",
                       replace: bool = False) -> None:
    if name in _EVALUATORS and not replace:
        raise ValueError(f"evaluator {name!r} already registered")
    _EVALUATORS[name] = EvaluatorEntry(
        name, simulate, simulate_batch, simulate_assignments, available,
        priority, description)


def evaluator_names() -> tuple[str, ...]:
    """Registered evaluator names in auto-dispatch (priority) order."""
    return tuple(e.name for e in
                 sorted(_EVALUATORS.values(), key=lambda e: e.priority))


def get_evaluator(name: str) -> EvaluatorEntry:
    try:
        return _EVALUATORS[name]
    except KeyError:
        raise UnknownEntryError(
            f"unknown evaluator {name!r}; registered evaluators: "
            f"{', '.join(evaluator_names())} (or {EVAL_AUTO!r})") from None


def resolve_evaluator(name: str = EVAL_AUTO) -> EvaluatorEntry:
    """Resolve an evaluator name (``"auto"`` -> best available entry)."""
    if name == EVAL_AUTO:
        for entry in sorted(_EVALUATORS.values(), key=lambda e: e.priority):
            if entry.available():
                return entry
        raise RuntimeError("no evaluator backend is available")
    entry = get_evaluator(name)
    if not entry.available():
        raise RuntimeError(
            f"evaluator {name!r} is registered but not available here")
    return entry


def _scalar_simulate_batch(platform, workloads_batch, model,
                           validate: bool = True) -> "_sb.BatchTimeline":
    # `validate` is accepted for interface parity; simulate() always
    # validates its workloads itself, so there is nothing extra to do.
    results = [simulate(platform, wls, model, record_timeline=False)
               for wls in workloads_batch]
    return _sb.batch_from_results(results, platform.names)


def _scalar_simulate_assignments(platform, graphs, assignments_batch, model,
                                 iterations=None, depends_on=None,
                                 validate: bool = True) -> "_sb.BatchTimeline":
    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    batch = [
        [Workload(g, tuple(a), iterations=i, depends_on=d)
         for g, a, i, d in zip(graphs, asgs, its, deps)]
        for asgs in assignments_batch
    ]
    return _scalar_simulate_batch(platform, batch, model, validate=validate)


_JAX_OK: bool | None = None


def _jax_available() -> bool:
    """Probe (once) whether the jax evaluator backend can run here."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            from . import simulate_jax
            _JAX_OK = simulate_jax.HAVE_JAX
        except Exception:  # pragma: no cover - import storms on broken jax
            _JAX_OK = False
    return _JAX_OK


def _jax_simulate_batch(*args, **kwargs):
    from . import simulate_jax
    return simulate_jax.simulate_batch(*args, **kwargs)


def _jax_simulate_assignments(*args, **kwargs):
    from . import simulate_jax
    return simulate_jax.simulate_assignments(*args, **kwargs)


register_evaluator(
    "batch", priority=0,
    simulate=simulate,                       # single candidates stay scalar
    simulate_batch=_sb.simulate_batch,
    simulate_assignments=_sb.simulate_assignments,
    description="NumPy lockstep population evaluator (core.simulate_batch)")
register_evaluator(
    "scalar", priority=10,
    simulate=simulate,
    simulate_batch=_scalar_simulate_batch,
    simulate_assignments=_scalar_simulate_assignments,
    description="authoritative event-driven simulator, looped per candidate")
# priority > batch: "auto" keeps resolving to the NumPy path (no jit warmup
# surprises in interactive use); searches opt into XLA with evaluator="jax".
# Either way the scalar simulator stays authoritative for final incumbents.
register_evaluator(
    "jax", priority=50, available=_jax_available,
    simulate=simulate,                       # final incumbents stay scalar
    simulate_batch=_jax_simulate_batch,
    simulate_assignments=_jax_simulate_assignments,
    description="jax.jit+vmap lockstep evaluator over the lowered "
                "ProblemSpec (core.simulate_jax; float64 via scoped "
                "enable_x64)")


# ---------------------------------------------------------------------------
# contention-model codecs (Plan serialization)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelCodec:
    name: str
    cls: type
    encode: Callable[[Any], dict]
    decode: Callable[[Mapping[str, Any]], Any]


_MODEL_CODECS: dict[str, ModelCodec] = {}


def register_contention_model(name: str, cls: type, *,
                              encode: Callable[[Any], dict] | None = None,
                              decode: Callable[..., Any] | None = None,
                              replace: bool = False) -> None:
    """Register a named (encode, decode) codec for a contention-model class.

    Defaults assume a flat dataclass: encode via ``vars()`` of the public
    fields, decode via ``cls(**cfg)``.
    """
    if name in _MODEL_CODECS and not replace:
        raise ValueError(f"contention model {name!r} already registered")
    enc = encode or (lambda m: {
        k: v for k, v in vars(m).items() if not k.startswith("_")})
    dec = decode or (lambda cfg: cls(**cfg))
    _MODEL_CODECS[name] = ModelCodec(name, cls, enc, dec)


def contention_model_names() -> tuple[str, ...]:
    return tuple(sorted(_MODEL_CODECS))


#: kind recorded for models without a codec: the plan still solves, hashes
#: and caches in-process, but the artifact refuses to deserialize.
OPAQUE_MODEL = "opaque"

_log = get_logger(__name__)
_OPAQUE_WARNED: set[str] = set()


def encode_model(model: Any) -> dict:
    """Serialize a contention model to ``{"kind": ..., **params}``.

    Per-domain model mappings (``{"EMC": model, ...}``, accepted everywhere
    a single model is) encode recursively.  A model class without a
    registered codec encodes as an *opaque* fingerprint — deterministic
    (dataclass ``repr``) so request hashing and in-process plan caching
    keep working, but :func:`decode_model` refuses it: register a codec to
    make such plans round-trip through JSON.
    """
    if isinstance(model, _abc.Mapping):
        return {"kind": "per-domain",
                "domains": {k: encode_model(v)
                            for k, v in sorted(model.items())}}
    for codec in _MODEL_CODECS.values():
        if type(model) is codec.cls:
            return {"kind": codec.name, **codec.encode(model)}
    fingerprint = repr(model)
    if re.search(r" at 0x[0-9a-f]+>", fingerprint):
        # default object repr embeds the instance address: equal-valued
        # models hash differently, so caching silently degrades to per-
        # instance.  Correct (no wrong hits) but worth flagging once.
        name = type(model).__name__
        if name not in _OPAQUE_WARNED:
            _OPAQUE_WARNED.add(name)
            _log.warning(
                "contention model %s has neither a registered codec nor a "
                "value-based __repr__; plan caching is per-instance only — "
                "register a codec with register_contention_model(...)", name)
    return {"kind": OPAQUE_MODEL, "type": type(model).__name__,
            "repr": fingerprint}


def decode_model(cfg: Mapping[str, Any]) -> Any:
    """Inverse of :func:`encode_model`."""
    cfg = dict(cfg)
    kind = cfg.pop("kind")
    if kind == "per-domain":
        return {k: decode_model(v) for k, v in cfg["domains"].items()}
    if kind == OPAQUE_MODEL:
        raise TypeError(
            f"this plan was solved with contention model {cfg['type']!r} "
            f"which has no registered codec; call "
            f"registry.register_contention_model(...) for it (before "
            f"solving) to make its plans deserializable")
    if kind not in _MODEL_CODECS:
        # built-in codecs that live outside core.contention register on
        # import of their home module — pull it in before giving up.
        from . import dynamic  # noqa: F401  (registers "scaled")
    if kind not in _MODEL_CODECS:
        raise UnknownEntryError(
            f"unknown contention model kind {kind!r}; registered "
            f"contention models: {', '.join(contention_model_names())} — "
            f"import the module that registers it before loading this "
            f"plan") from None
    return _MODEL_CODECS[kind].decode(cfg)


register_contention_model(
    "proportional", ProportionalShareModel,
    encode=lambda m: {"capacity": m.capacity, "sensitivity": m.sensitivity})
register_contention_model(
    "piecewise", PiecewiseModel,
    encode=lambda m: {"own_knots": list(m.own_knots),
                      "ext_knots": list(m.ext_knots),
                      "table": [list(r) for r in m.table]},
    decode=lambda cfg: PiecewiseModel(
        tuple(cfg["own_knots"]), tuple(cfg["ext_knots"]),
        tuple(tuple(r) for r in cfg["table"])))


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

_BASELINES: dict[str, Callable] = dict(_baselines.BASELINES)


def register_baseline(name: str, fn: Callable, *,
                      replace: bool = False) -> None:
    if name in _BASELINES and not replace:
        raise ValueError(f"baseline {name!r} already registered")
    _BASELINES[name] = fn


def baseline_names() -> tuple[str, ...]:
    return tuple(_BASELINES)


def get_baseline(name: str) -> Callable:
    try:
        return _BASELINES[name]
    except KeyError:
        raise UnknownEntryError(
            f"unknown baseline {name!r}; registered baselines: "
            f"{', '.join(baseline_names())}") from None

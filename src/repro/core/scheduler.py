"""The Scheduler object API: resolved platform + model -> cached Plans.

One :class:`Scheduler` owns a resolved :class:`~repro.core.accelerators.
Platform`, a default contention model and a :class:`~repro.core.plan.
PlanCache`; every schedule it produces is a :class:`~repro.core.plan.Plan`
with provenance, produced by a named registry solver entry and cached by
request content hash — repeated ``solve()`` calls for the same problem are
O(1) and re-schedules triggered at runtime (§4.4) are cached and logged
through the same path.

    from repro.core import Scheduler

    sched = Scheduler("xavier-agx")
    plan = sched.solve(["vgg19", "resnet152"], objective="latency")
    plan.save("artifacts/plans/vgg-resnet.json")   # pre-solve offline
    rows = sched.compare(["vgg19", "resnet152"])   # Table-6 shaped

The legacy free functions in :mod:`repro.core.api` are thin deprecated
shims over one shared Scheduler per (platform, model).
"""
from __future__ import annotations

import inspect
import time
from typing import Mapping, Sequence

from . import registry
from .accelerators import PLATFORMS, Platform
from .contention import ContentionModel, ProportionalShareModel
from .graph import DNNGraph
from .plan import (Plan, PlanCache, ScheduleRequest, platform_fingerprint)
from .profiles import get_graph
from .simulate import SimResult, Workload, simulate, validate_assignment
from ..obs import get_logger, get_registry, get_tracer

log = get_logger(__name__)

#: calibrated default for the SoC EMC domains — reproduces the paper's
#: observed co-run slowdown magnitudes (up to ~70% performance loss, §5.2)
#: at the Table-2 demand levels.
DEFAULT_SOC_MODEL = ProportionalShareModel(capacity=1.0, sensitivity=3.0)
#: ICI over-subscription is served fairly by the fabric; no extra sensitivity.
DEFAULT_POD_MODEL = ProportionalShareModel(capacity=1.0, sensitivity=1.0)


def resolve_platform(platform: str | Platform) -> Platform:
    if isinstance(platform, Platform):
        return platform
    return PLATFORMS[platform]()


def default_model(platform: Platform) -> ContentionModel:
    return DEFAULT_POD_MODEL if "ICI" in platform.domains else DEFAULT_SOC_MODEL


def resolve_graphs(dnns: Sequence[str | DNNGraph],
                   platform: Platform) -> list[DNNGraph]:
    return [d if isinstance(d, DNNGraph) else get_graph(d, platform)
            for d in dnns]


def failed(row: object) -> bool:
    """True for a structured error row in :meth:`Scheduler.compare` output."""
    return isinstance(row, dict) and "error" in row


def _error_row(exc: BaseException) -> dict:
    return {"error": {"type": type(exc).__name__, "message": str(exc)}}


def _accepts_kwarg(fn, name: str) -> bool:
    """True if ``fn`` can be called with keyword argument ``name``."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):          # builtins / C callables
        return False
    if name in params:
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values())


class Scheduler:
    """Holds a resolved platform + contention model; produces cached Plans."""

    def __init__(self, platform: str | Platform = "agx-orin",
                 model: ContentionModel | None = None,
                 cache: PlanCache | None = None,
                 evaluator: str = registry.EVAL_AUTO):
        self.platform = resolve_platform(platform)
        self.model = model or default_model(self.platform)
        self.cache = cache if cache is not None else PlanCache()
        #: how solvers/compare score candidate schedules: "batch" | "jax" |
        #: "scalar" | "auto" (best available).  Not part of the problem
        #: identity — evaluators cache under the same request hash; the
        #: Plan records which one actually searched.
        if evaluator != registry.EVAL_AUTO:
            # fail construction, not first solve, on a typo — the raised
            # UnknownEntryError lists the registered evaluator names.
            registry.get_evaluator(evaluator)
        self.evaluator = evaluator
        #: actual solver invocations (== cache misses that reached a solver).
        self.solves = 0

    def __repr__(self) -> str:
        return (f"Scheduler(platform={self.platform.name!r}, "
                f"model={type(self.model).__name__}, "
                f"evaluator={self.evaluator!r}, "
                f"cached={len(self.cache)}, solves={self.solves})")

    @classmethod
    def from_bundle(cls, bundle, **kwargs) -> "Scheduler":
        """Scheduler solving from a measured :class:`~repro.profiling.
        ProfileBundle` (or a path to one): the bundle's platform plus its
        calibrated contention model.  Schedule the bundle's measured
        graphs by passing them to :meth:`solve`."""
        from ..profiling.bundle import scheduler_from_bundle
        return scheduler_from_bundle(bundle, **kwargs)

    # ------------------------------------------------------------------
    def graphs(self, dnns: Sequence[str | DNNGraph]) -> list[DNNGraph]:
        """Resolve paper-profile names / pass through pre-built graphs."""
        return resolve_graphs(dnns, self.platform)

    def request(self, dnns: Sequence[str | DNNGraph],
                objective: str = "latency", *,
                model: ContentionModel | None = None,
                solver: str = registry.AUTO,
                max_transitions: int | None = 3,
                iterations: Sequence[int] | None = None,
                depends_on: Sequence[int | None] | None = None,
                deadline_s: float | None = None,
                solver_knobs: Mapping | None = None,
                **knobs) -> ScheduleRequest:
        """Build a validated request against this scheduler's platform.

        Extra keyword arguments are solver-entry knobs (e.g. anneal's
        ``population``/``devices``/``budget_ms``); they require an
        explicit ``solver=`` and are validated against that entry's
        declared vocabulary — an unknown name raises
        :class:`~repro.core.registry.UnknownEntryError` listing the valid
        knobs.
        """
        merged = dict(solver_knobs or {})
        merged.update(knobs)
        return ScheduleRequest(
            graphs=tuple(self.graphs(dnns)),
            platform=self.platform,
            model=model or self.model,
            objective=objective,
            solver=solver,
            max_transitions=max_transitions,
            iterations=tuple(iterations or ()),
            depends_on=tuple(depends_on or ()),
            deadline_s=deadline_s,
            solver_knobs=tuple(sorted(merged.items())),
        )

    # ------------------------------------------------------------------
    def resolve(self, request: ScheduleRequest, *,
                evaluator: str | None = None) -> Plan:
        """Cache-or-solve entry point — every schedule goes through here.

        ``evaluator`` overrides the scheduler-wide knob for this call; it
        steers *how* solvers score candidates ("batch" population scoring
        vs the looped "scalar" authoritative path), never *what* problem is
        solved, so it does not participate in the request hash.
        """
        h = request.request_hash()
        with get_tracer().span("scheduler.resolve", "solve",
                               request=h[:12]) as sp:
            plan = self.cache.get(h)
            if plan is not None:
                sp.set(cache="hit", solver=plan.solver)
                get_registry().counter(
                    "scheduler_cache_hits",
                    "resolve() calls served from the plan cache").inc()
                log.info(
                    "plan cache hit %s (solver=%s, %.3fs solve amortized)",
                    h[:12], plan.solver, plan.solve_time_s)
                return plan
            ev = registry.resolve_evaluator(evaluator or self.evaluator).name
            kind, sol, dt = self._dispatch(request, ev)
            self.solves += 1
            sp.set(cache="miss", solver=kind, evaluator=ev,
                   objective=request.objective,
                   objective_value=sol.objective, solve_s=round(dt, 6))
            get_registry().counter(
                "scheduler_solves",
                "resolve() calls that reached a solver").inc()
            plan = Plan(request=request, solution=sol, solver=kind,
                        solve_time_s=dt, request_hash=h,
                        platform_fingerprint=platform_fingerprint(
                            request.platform),
                        evaluator=ev,
                        # getattr: third-party Solutions may predate params.
                        solver_params=dict(getattr(sol, "params", {}) or {}))
            self.cache.put(plan)
            log.info("solved %s with %s/%s in %.3fs (%s=%.6g, optimal=%s)",
                     h[:12], kind, ev, dt, sol.kind, sol.objective,
                     sol.optimal)
            return plan

    def _dispatch(self, request: ScheduleRequest, evaluator: str):
        errors = []
        for entry in registry.dispatch_order(request.solver):
            t0 = time.perf_counter()
            kwargs = dict(
                objective=request.objective,
                max_transitions=request.max_transitions,
                iterations=list(request.iterations),
                depends_on=list(request.depends_on),
                deadline_s=request.deadline_s)
            if _accepts_kwarg(entry.fn, "evaluator"):
                kwargs["evaluator"] = evaluator
            else:
                # third-party solvers registered against the pre-evaluator
                # signature keep working; they just search their own way.
                log.debug("solver %s does not accept evaluator=; skipping",
                          entry.name)
            # per-entry knobs were validated at request construction
            # against this entry's declared vocabulary.
            kwargs.update(dict(request.solver_knobs))
            try:
                with get_tracer().span(f"solver.{entry.name}", "solve",
                                       objective=request.objective):
                    sol = entry.fn(request.platform, list(request.graphs),
                                   request.model, **kwargs)
            except ValueError as exc:
                # e.g. exhaustive search space too large: degrade down the
                # registry's priority order (z3 -> bb -> greedy).
                errors.append(f"{entry.name}: {exc}")
                log.info("solver %s declined (%s), trying next entry",
                         entry.name, exc)
                continue
            return entry.name, sol, time.perf_counter() - t0
        raise RuntimeError(
            f"no solver produced a schedule for {request.request_hash()[:12]}"
            f": {'; '.join(errors)}")

    def solve(self, dnns: Sequence[str | DNNGraph],
              objective: str = "latency", *,
              evaluator: str | None = None, **kwargs) -> Plan:
        """Request + resolve in one call (kwargs as in :meth:`request`)."""
        return self.resolve(self.request(dnns, objective, **kwargs),
                            evaluator=evaluator)

    # ------------------------------------------------------------------
    def evaluate_baseline(self, name: str, dnns: Sequence[str | DNNGraph],
                          *, model: ContentionModel | None = None,
                          iterations: Sequence[int] | None = None,
                          depends_on: Sequence[int | None] | None = None,
                          ) -> tuple[list[Workload], SimResult]:
        """Evaluate one registered baseline under the exact simulator."""
        graphs = self.graphs(dnns)
        wls = registry.get_baseline(name)(
            self.platform, graphs, iterations=iterations,
            depends_on=depends_on)
        return wls, simulate(self.platform, wls, model or self.model)

    def evaluate_baselines(self, dnns: Sequence[str | DNNGraph], *,
                           model: ContentionModel | None = None,
                           iterations: Sequence[int] | None = None,
                           depends_on: Sequence[int | None] | None = None,
                           evaluator: str | None = None,
                           ) -> dict[str, SimResult | dict]:
        """Evaluate *every* registered baseline in one batch pass.

        Rows that fail to build or validate become structured
        ``{"error": ...}`` dicts (see :func:`failed`); the rest are scored
        together through the selected evaluator's batch path — one
        vectorized sweep instead of one event-driven run per baseline.
        """
        graphs = self.graphs(dnns)
        entry = registry.resolve_evaluator(evaluator or self.evaluator)
        rows: dict[str, SimResult | dict] = {}
        built: list[tuple[str, list[Workload]]] = []
        for name in registry.baseline_names():
            try:
                wls = registry.get_baseline(name)(
                    self.platform, graphs, iterations=iterations,
                    depends_on=depends_on)
                for wl in wls:
                    validate_assignment(self.platform, wl)
            except (ValueError, KeyError, RuntimeError) as exc:
                rows[name] = _error_row(exc)
            else:
                built.append((name, wls))
        if built:
            try:
                bt = entry.simulate_batch(
                    self.platform, [wls for _, wls in built],
                    model or self.model, validate=False)
            except (ValueError, KeyError, RuntimeError) as exc:
                # one pathological candidate fails the whole batch call —
                # degrade to per-row scalar evaluation so the failure stays
                # a structured row instead of taking down the sweep.
                log.warning("batch baseline sweep failed (%s); retrying "
                            "row-by-row through the scalar simulator", exc)
                for name, wls in built:
                    try:
                        rows[name] = simulate(self.platform, wls,
                                              model or self.model)
                    except (ValueError, KeyError, RuntimeError) as row_exc:
                        rows[name] = _error_row(row_exc)
            else:
                for i, (name, _) in enumerate(built):
                    rows[name] = bt.result(i)
        return rows

    def compare(self, dnns: Sequence[str | DNNGraph],
                objective: str = "latency", *,
                model: ContentionModel | None = None,
                solver: str = registry.AUTO,
                max_transitions: int | None = 3,
                iterations: Sequence[int] | None = None,
                depends_on: Sequence[int | None] | None = None,
                deadline_s: float | None = 20.0,
                evaluator: str | None = None,
                ) -> dict[str, SimResult | Plan | dict]:
        """HaX-CoNN vs. every registered baseline (Table-6 row shape).

        Baseline rows are :class:`SimResult` (scored through the batch
        evaluator in one sweep); the ``"haxconn"`` row is a :class:`Plan`.
        A failing row is recorded as a structured ``{"error": {"type",
        "message"}}`` dict (see :func:`failed`) so "infeasible on this
        platform" is distinguishable from "crashed".
        """
        graphs = self.graphs(dnns)
        rows: dict[str, SimResult | Plan | dict] = dict(
            self.evaluate_baselines(
                graphs, model=model, iterations=iterations,
                depends_on=depends_on, evaluator=evaluator))
        try:
            rows["haxconn"] = self.solve(
                graphs, objective, model=model, solver=solver,
                max_transitions=max_transitions, iterations=iterations,
                depends_on=depends_on, deadline_s=deadline_s,
                evaluator=evaluator)
        except (ValueError, KeyError, RuntimeError,
                registry.SolverUnavailable) as exc:
            rows["haxconn"] = _error_row(exc)
        return rows

"""Exact branch-and-bound / exhaustive solver (pure Python, no z3).

Used as the optimality *oracle* in tests and as the fallback when z3 is not
installed.  Enumerates per-DNN assignments with a bounded number of
inter-accelerator transitions (``max_transitions``; the paper's optimal
schedules in Table 6 all use exactly one transition per DNN, and
``max_transitions=len(graph)`` recovers the full space), prunes joint
combinations with an admissible contention-free lower bound, and evaluates
survivors with the exact simulator.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Mapping, Sequence

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import SimResult, Workload, simulate


@dataclass
class Solution:
    workloads: list[Workload]
    result: SimResult
    objective: float
    kind: str
    evaluated: int
    optimal: bool

    @property
    def assignments(self) -> list[tuple[str, ...]]:
        return [w.assignment for w in self.workloads]


def enumerate_assignments(
    graph: DNNGraph, accs: Sequence[str], max_transitions: int
) -> list[tuple[str, ...]]:
    """All legal assignments of ``graph`` with <= ``max_transitions``."""
    accs = [a for a in accs if a in graph.accelerators]
    n = len(graph)
    legal_after = [graph[i].can_transition_after for i in range(n)]
    out: list[tuple[str, ...]] = []

    def rec(i: int, cur: list[str], trans: int):
        if i == n:
            out.append(tuple(cur))
            return
        for a in accs:
            if i > 0 and a != cur[-1]:
                if trans >= max_transitions or not legal_after[i - 1]:
                    continue
                cur.append(a)
                rec(i + 1, cur, trans + 1)
            else:
                cur.append(a)
                rec(i + 1, cur, trans)
            cur.pop()

    rec(0, [], 0)
    return out


def lower_bound_time(platform: Platform, graph: DNNGraph,
                     assignment: Sequence[str]) -> float:
    """Contention- and queueing-free completion time (admissible)."""
    t = sum(graph[i].time_on(a) for i, a in enumerate(assignment))
    for i in range(len(assignment) - 1):
        if assignment[i] != assignment[i + 1]:
            t += platform.transition_cost_ms(graph[i].out_bytes,
                                             assignment[i], assignment[i + 1])
    return t


def joint_lower_bound(platform: Platform, graphs: Sequence[DNNGraph],
                      assignments: Sequence[Sequence[str]],
                      iterations: Sequence[int]) -> float:
    """Admissible makespan LB: max of per-DNN path bounds and per-acc load."""
    per_dnn = [
        lower_bound_time(platform, g, a) * it
        for g, a, it in zip(graphs, assignments, iterations)
    ]
    load: dict[str, float] = {a: 0.0 for a in platform.names}
    for g, asg, it in zip(graphs, assignments, iterations):
        for i, a in enumerate(asg):
            load[a] += g[i].time_on(a) * it
    return max(max(per_dnn), max(load.values()))


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int = 2,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    max_candidates: int = 2_000_000,
) -> Solution:
    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    cand = [enumerate_assignments(g, platform.names, max_transitions)
            for g in graphs]
    total = 1
    for c in cand:
        total *= len(c)
    if total > max_candidates:
        raise ValueError(
            f"search space {total} too large for exhaustive solve; "
            f"reduce max_transitions or merge layer groups"
        )

    # Order joint candidates by lower bound so the incumbent tightens fast.
    best: Solution | None = None
    evaluated = 0
    combos = sorted(
        itertools.product(*cand),
        key=lambda asgs: joint_lower_bound(platform, graphs, asgs, its),
    )
    for asgs in combos:
        lb = joint_lower_bound(platform, graphs, asgs, its)
        if best is not None and objective in ("latency", "throughput"):
            # both objectives are monotone in makespan; lb bounds makespan.
            if lb >= best.result.makespan - 1e-12:
                break  # sorted by LB: nothing later can win
        wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
               for g, a, it, dep in zip(graphs, asgs, its, deps)]
        res = simulate(platform, wls, model, record_timeline=False)
        evaluated += 1
        obj = res.objective(objective)
        if best is None or obj < best.objective:
            best = Solution(wls, res, obj, objective, evaluated, optimal=True)
    assert best is not None
    best.evaluated = evaluated
    return best

"""Exact branch-and-bound / exhaustive solver (pure Python, no z3).

Used as the optimality *oracle* in tests and as the fallback when z3 is not
installed.  Enumerates per-DNN assignments with a bounded number of
inter-accelerator transitions (``max_transitions``; the paper's optimal
schedules in Table 6 all use exactly one transition per DNN, and
``max_transitions=len(graph)`` recovers the full space), prunes joint
combinations with an admissible contention-free lower bound, and evaluates
survivors with the exact simulator.

Evaluation backends (the registry ``evaluator`` knob):

* ``"batch"`` (default via ``"auto"``) — lower bounds for the whole joint
  space are computed vectorized, candidates are visited in ascending-bound
  order in chunks, and each chunk is scored in one
  ``simulate_assignments`` call of the selected evaluator entry (NumPy
  lockstep for ``"batch"``, the XLA jit+vmap loop for ``"jax"`` — chunk
  populations pad to powers of two there, so the tail chunks reuse
  compiled executables).  The final incumbent is re-simulated through the
  authoritative scalar simulator, so the returned :class:`Solution` never
  depends on a fast path.
* ``"scalar"`` — the original one-candidate-at-a-time loop.

All backends visit candidates in the same order and accept the same strict
improvements, so they return the same schedule (a population path may score
a few extra candidates past the scalar path's break point; it can only
confirm the incumbent).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from .accelerators import Platform
from .contention import ContentionModel
from .graph import DNNGraph
from .simulate import SimResult, Workload, simulate


@dataclass
class Solution:
    workloads: list[Workload]
    result: SimResult
    objective: float
    kind: str
    evaluated: int
    optimal: bool
    #: solver-specific provenance (seed, steps, population, ...); travels
    #: into Plan artifacts as ``solver_params``.  Exact solvers leave it
    #: empty; stochastic ones record what reproduces their run.
    params: dict = field(default_factory=dict)

    @property
    def assignments(self) -> list[tuple[str, ...]]:
        return [w.assignment for w in self.workloads]


def enumerate_assignments(
    graph: DNNGraph, accs: Sequence[str], max_transitions: int
) -> list[tuple[str, ...]]:
    """All legal assignments of ``graph`` with <= ``max_transitions``."""
    accs = [a for a in accs if a in graph.accelerators]
    n = len(graph)
    legal_after = [graph[i].can_transition_after for i in range(n)]
    out: list[tuple[str, ...]] = []

    def rec(i: int, cur: list[str], trans: int):
        if i == n:
            out.append(tuple(cur))
            return
        for a in accs:
            if i > 0 and a != cur[-1]:
                if trans >= max_transitions or not legal_after[i - 1]:
                    continue
                cur.append(a)
                rec(i + 1, cur, trans + 1)
            else:
                cur.append(a)
                rec(i + 1, cur, trans)
            cur.pop()

    rec(0, [], 0)
    return out


def lower_bound_time(platform: Platform, graph: DNNGraph,
                     assignment: Sequence[str]) -> float:
    """Contention- and queueing-free completion time (admissible)."""
    t = sum(graph[i].time_on(a) for i, a in enumerate(assignment))
    for i in range(len(assignment) - 1):
        if assignment[i] != assignment[i + 1]:
            t += platform.transition_cost_ms(graph[i].out_bytes,
                                             assignment[i], assignment[i + 1])
    return t


def joint_lower_bound(platform: Platform, graphs: Sequence[DNNGraph],
                      assignments: Sequence[Sequence[str]],
                      iterations: Sequence[int]) -> float:
    """Admissible makespan LB: max of per-DNN path bounds and per-acc load."""
    per_dnn = [
        lower_bound_time(platform, g, a) * it
        for g, a, it in zip(graphs, assignments, iterations)
    ]
    load: dict[str, float] = {a: 0.0 for a in platform.names}
    for g, asg, it in zip(graphs, assignments, iterations):
        for i, a in enumerate(asg):
            load[a] += g[i].time_on(a) * it
    return max(max(per_dnn), max(load.values()))


def solve(
    platform: Platform,
    graphs: Sequence[DNNGraph],
    model: ContentionModel | Mapping[str, ContentionModel],
    objective: str = "latency",
    max_transitions: int = 2,
    iterations: Sequence[int] | None = None,
    depends_on: Sequence[int | None] | None = None,
    max_candidates: int = 2_000_000,
    evaluator: str = "auto",
    chunk: int = 512,
) -> Solution:
    from . import registry

    its = list(iterations or [1] * len(graphs))
    deps = list(depends_on or [None] * len(graphs))
    cand = [enumerate_assignments(g, platform.names, max_transitions)
            for g in graphs]
    total = 1
    for c in cand:
        total *= len(c)
    if total > max_candidates:
        raise ValueError(
            f"search space {total} too large for exhaustive solve; "
            f"reduce max_transitions or merge layer groups"
        )

    entry = registry.resolve_evaluator(evaluator)
    if entry.name != "scalar":
        return _solve_batched(entry, platform, graphs, model, objective,
                              cand, its, deps, total, chunk)

    # Order joint candidates by lower bound so the incumbent tightens fast.
    best: Solution | None = None
    evaluated = 0
    combos = sorted(
        itertools.product(*cand),
        key=lambda asgs: joint_lower_bound(platform, graphs, asgs, its),
    )
    for asgs in combos:
        lb = joint_lower_bound(platform, graphs, asgs, its)
        if best is not None and objective in ("latency", "throughput"):
            # both objectives are monotone in makespan; lb bounds makespan.
            if lb >= best.result.makespan - 1e-12:
                break  # sorted by LB: nothing later can win
        wls = [Workload(g, tuple(a), iterations=it, depends_on=dep)
               for g, a, it, dep in zip(graphs, asgs, its, deps)]
        res = simulate(platform, wls, model, record_timeline=False)
        evaluated += 1
        obj = res.objective(objective)
        if best is None or obj < best.objective:
            best = Solution(wls, res, obj, objective, evaluated, optimal=True)
    assert best is not None
    best.evaluated = evaluated
    return best


def _joint_lower_bounds(platform: Platform, graphs: Sequence[DNNGraph],
                        cand: Sequence[Sequence[tuple[str, ...]]],
                        its: Sequence[int]) -> np.ndarray:
    """Vectorized :func:`joint_lower_bound` over the full joint space.

    Returns a flat (prod K_i,) array in C order — i.e. the same order
    ``itertools.product(*cand)`` enumerates, so a stable argsort reproduces
    the scalar path's visit order exactly.
    """
    names = list(platform.names)
    a_idx = {a: j for j, a in enumerate(names)}
    shape = tuple(len(c) for c in cand)
    w = len(graphs)
    paths = []            # per graph: (K_i,) critical-path bound
    loads = []            # per graph: (K_i, A) per-accelerator load
    for g, clist, it in zip(graphs, cand, its):
        pl = np.empty(len(clist))
        ld = np.zeros((len(clist), len(names)))
        for k, asg in enumerate(clist):
            pl[k] = lower_bound_time(platform, g, asg) * it
            for i, a in enumerate(asg):
                ld[k, a_idx[a]] += g[i].time_on(a) * it
        paths.append(pl)
        loads.append(ld)

    def bshape(i: int, trailing: tuple[int, ...] = ()) -> tuple[int, ...]:
        return tuple(shape[j] if j == i else 1 for j in range(w)) + trailing

    per_dnn = np.zeros(shape)
    for i in range(w):
        per_dnn = np.maximum(per_dnn, paths[i].reshape(bshape(i)))
    load = np.zeros(shape + (len(names),))
    for i in range(w):
        load = load + loads[i].reshape(bshape(i, (len(names),)))
    return np.maximum(per_dnn, load.max(axis=-1)).ravel()


def _solve_batched(entry, platform: Platform, graphs: Sequence[DNNGraph],
                   model, objective: str,
                   cand: Sequence[Sequence[tuple[str, ...]]],
                   its: Sequence[int], deps: Sequence[int | None],
                   total: int, chunk: int) -> Solution:
    shape = tuple(len(c) for c in cand)
    lb = _joint_lower_bounds(platform, graphs, cand, its)
    order = np.argsort(lb, kind="stable")
    prune = objective in ("latency", "throughput")

    best_flat = -1
    best_obj = np.inf
    best_makespan = np.inf
    evaluated = 0
    pos = 0
    while pos < total:
        take = order[pos:pos + chunk]
        if best_flat >= 0 and prune:
            # lb ascending along `order`: candidates at/after the first one
            # with lb >= incumbent makespan cannot win (both objectives are
            # monotone in makespan; lb bounds makespan from below).
            keep = lb[take] < best_makespan - 1e-12
            if not keep.all():
                take = take[:int(np.argmin(keep))]
            if len(take) == 0:
                break
        idxs = np.unravel_index(take, shape)
        asgs_chunk = [[cand[i][idxs[i][j]] for i in range(len(graphs))]
                      for j in range(len(take))]
        bt = entry.simulate_assignments(
            platform, graphs, asgs_chunk, model,
            iterations=its, depends_on=deps, validate=False)
        objs = bt.objective(objective)
        evaluated += len(take)
        j = int(np.argmin(objs))    # first among ties = scalar visit order
        if objs[j] < best_obj:
            best_obj = float(objs[j])
            best_makespan = float(bt.makespan[j])
            best_flat = int(take[j])
        pos += len(take)

    assert best_flat >= 0
    best_idx = np.unravel_index(best_flat, shape)
    wls = [Workload(g, tuple(cand[i][best_idx[i]]), iterations=it,
                    depends_on=dep)
           for i, (g, it, dep) in enumerate(zip(graphs, its, deps))]
    # the scalar simulator is authoritative: the recorded result (and the
    # objective stored with it) never comes from the fast path.
    res = entry.simulate(platform, wls, model, record_timeline=False)
    return Solution(wls, res, res.objective(objective), objective,
                    evaluated, optimal=True)

"""Model facade: init/specs, jit-able step functions, dry-run input specs."""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeCell
from . import kvcache, transformer


class Model:
    def __init__(self, cfg: ModelConfig, rules: Mapping[str, object] | None
                 = None, backend: str = "auto"):
        self.cfg = cfg
        self.rules = dict(rules if rules is not None else cfg.rules)
        self.backend = backend

    # ------------------------------------------------------------------
    def init(self, key):
        params, _ = transformer.init_stack(self.cfg, key)
        return params

    def _abstract_init(self):
        box = {}

        def f(k):
            p, s = transformer.init_stack(self.cfg, k)
            box["specs"] = s          # static logical tuples, not jax types
            return p

        params = jax.eval_shape(f, jax.random.PRNGKey(0))
        return params, box["specs"]

    def specs(self):
        """Pytree of logical-axis tuples mirroring init()'s params."""
        return self._abstract_init()[1]

    def abstract_params(self):
        return self._abstract_init()[0]

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        return transformer.loss_fn(self.cfg, params, batch, self.rules,
                                   backend=self.backend)

    def forward(self, params, batch, last_only=False):
        logits, _, aux = transformer.forward(
            self.cfg, params, batch, self.rules, backend=self.backend,
            last_only=last_only)
        return logits, aux

    def prefill(self, params, batch, capacity: int | None = None):
        """Returns (last-token logits, decode caches).

        ``capacity``: cache slots to allocate (default prompt length + 64
        so a generation loop can append without reallocation)."""
        seq = (batch["embeds"] if self.cfg.embeds_only
               else batch["token_ids"]).shape[1]
        logits, caches, _ = transformer.forward(
            self.cfg, params, batch, self.rules, backend=self.backend,
            collect_kv=True, last_only=True,
            cache_capacity=capacity or seq + 64)
        return logits, caches

    def decode_step(self, params, caches, batch):
        return transformer.decode_step(self.cfg, params, caches, batch,
                                       self.rules, backend=self.backend)

    # ------------------------------------------------------------------
    # dry-run stand-ins: ShapeDtypeStructs, no allocation
    # ------------------------------------------------------------------
    def input_specs(self, shape: str | ShapeCell):
        cell = SHAPES[shape] if isinstance(shape, str) else shape
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        act = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        def token_inputs(seq):
            if cfg.embeds_only:     # [audio]/encoder stub: frame embeddings
                return {"embeds": sds((B, seq, cfg.d_model), act)}
            d = {"token_ids": sds((B, seq), i32)}
            if cfg.mm_prefix:       # [vlm] stub: precomputed patch embeds
                d["mm_embeds"] = sds((B, cfg.mm_prefix, cfg.mm_embed_dim),
                                     act)
            return d

        if cell.kind == "train":
            batch = token_inputs(S)
            batch["labels"] = sds((B, S), i32)
            return batch
        if cell.kind == "prefill":
            return token_inputs(S)
        # decode: one new token + caches holding `seq_len` history
        batch = {"lengths": sds((B,), i32)}
        if cfg.embeds_only:
            batch["embeds"] = sds((B, 1, cfg.d_model), act)
        else:
            # decode is always past the multimodal prefix: token ids only
            batch["token_ids"] = sds((B, 1), i32)
        return batch

    def cache_specs(self, shape: str | ShapeCell):
        """Abstract decode caches with capacity = cell.seq_len."""
        cell = SHAPES[shape] if isinstance(shape, str) else shape
        caches = jax.eval_shape(
            lambda: self.init_cache(cell.global_batch, cell.seq_len))
        return caches

    def init_cache(self, batch: int, capacity: int):
        cfg = self.cfg
        H = cfg.n_heads if cfg.n_heads else cfg.d_model // 64
        dh_rwkv = cfg.d_model // H

        def one(kind):
            if kind in ("attn", "local"):
                cap = (min(cfg.local_window, capacity) if kind == "local"
                       else capacity)
                return {
                    "k": kvcache.init_layer(batch, cap, cfg.n_kv_heads,
                                            cfg.d_head, cfg.kv_cache_dtype),
                    "v": kvcache.init_layer(batch, cap, cfg.n_kv_heads,
                                            cfg.d_head, cfg.kv_cache_dtype),
                }
            if kind == "rglru":
                return {"h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
                        "conv": jnp.zeros((batch, 3, cfg.d_rnn),
                                          jnp.dtype(cfg.dtype))}
            if kind == "rwkv":
                return {"S": jnp.zeros((batch, H, dh_rwkv, dh_rwkv),
                                       jnp.float32),
                        "x_t": jnp.zeros((batch, cfg.d_model),
                                         jnp.dtype(cfg.dtype)),
                        "x_c": jnp.zeros((batch, cfg.d_model),
                                         jnp.dtype(cfg.dtype))}
            raise ValueError(kind)

        kinds = cfg.layer_kinds
        P = len(cfg.block_pattern)
        n_groups = (len(kinds) // P) if cfg.scan_layers else 0
        n_scanned = n_groups * P
        groups = None
        if n_groups:
            groups = tuple(
                jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                             *[one(cfg.block_pattern[pos])
                               for _ in range(n_groups)])
                for pos in range(P))
        tail = tuple(one(kinds[i]) for i in range(n_scanned, len(kinds)))
        return {"groups": groups if groups is not None else None,
                "tail": tail}


def build(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)

"""Temporal-mix blocks without attention: RG-LRU (Griffin) and RWKV-6.

Both reduce to the kernels in :mod:`repro.kernels`: RG-LRU to the gated
linear recurrence `h_t = a_t h_{t-1} + b_t`, RWKV-6 to the matrix-state
recurrence.  Decode carries constant-size state (the long_500k enabler).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import sharding
from .layers import dense_init, rmsnorm

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU residual block (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------

def init_rglru(cfg: ModelConfig, key):
    d, r = cfg.d_model, cfg.d_rnn
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["ln"], s["ln"] = jnp.zeros((d,), pdt), ("embed",)
    p["w_in"], s["w_in"] = dense_init(ks[0], (d, r), ("embed", "rnn"), pdt)
    p["w_gate"], s["w_gate"] = dense_init(ks[1], (d, r), ("embed", "rnn"), pdt)
    p["conv_w"], s["conv_w"] = (jnp.zeros((4, r), pdt), (None, "rnn"))
    p["conv_b"], s["conv_b"] = jnp.zeros((r,), pdt), ("rnn",)
    p["wa"], s["wa"] = dense_init(ks[2], (r, r), ("rnn", None), pdt)
    p["wx"], s["wx"] = dense_init(ks[3], (r, r), ("rnn", None), pdt)
    # Λ init so a = sigmoid(Λ) ∈ (0.9, 0.999) as in Griffin
    lam = jnp.log(jnp.linspace(0.9, 0.999, r) /
                  (1 - jnp.linspace(0.9, 0.999, r)))
    p["lam"], s["lam"] = lam.astype(pdt), ("rnn",)
    p["w_out"], s["w_out"] = dense_init(ks[4], (r, d), ("rnn", "embed"), pdt)
    return p, s


def _causal_conv4(x, w, b, state=None):
    """Depthwise causal width-4 conv. x: (B,S,r); state: (B,3,r) history."""
    B, S, r = x.shape
    if state is None:
        hist = jnp.zeros((B, 3, r), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)                # (B, S+3, r)
    out = sum(xp[:, 3 - i: 3 - i + S] * w[3 - i][None, None, :]
              for i in range(4))
    new_state = xp[:, -3:]                                 # last 3 inputs
    return out + b, new_state


def rglru_block(cfg: ModelConfig, p, rules, x, *, state=None,
                backend="auto"):
    """Returns (y, new_state); state = {"h": (B,r) f32, "conv": (B,3,r)}."""
    dt = jnp.dtype(cfg.dtype)
    h_in = rmsnorm(x, p["ln"]).astype(dt)

    def W(name, logical):
        return sharding.weight_use(p[name].astype(dt), rules, logical)

    gate = jax.nn.gelu(h_in @ W("w_gate", ("embed", "rnn")))     # (B,S,r)
    u = h_in @ W("w_in", ("embed", "rnn"))
    u = sharding.constrain(u, rules, ("batch", "seq", "rnn"))
    u, conv_state = _causal_conv4(u, p["conv_w"].astype(dt),
                                  p["conv_b"].astype(dt),
                                  None if state is None else state["conv"])
    # RG-LRU gates
    rgate = jax.nn.sigmoid(u @ W("wa", ("rnn", None)))
    igate = jax.nn.sigmoid(u @ W("wx", ("rnn", None)))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) \
        * rgate.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (igate * u).astype(jnp.float32)
    h0 = None if state is None else state["h"]
    h_seq, h_last = ops.linear_scan(a.astype(dt), gated_in.astype(dt),
                                    h0, backend=backend)
    h_seq = sharding.constrain(h_seq, rules, ("batch", "seq", "rnn"))
    y = (h_seq * gate) @ W("w_out", ("rnn", "embed"))
    y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
    return x + y, {"h": h_last, "conv": conv_state}


# ---------------------------------------------------------------------------
# RWKV-6 block: time mix + channel mix
# ---------------------------------------------------------------------------

def init_rwkv(cfg: ModelConfig, key):
    d, ff = cfg.d_model, cfg.d_ff
    H = cfg.n_heads if cfg.n_heads else d // 64
    dh = d // H
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    p, s = {}, {}
    # time mix
    p["ln_t"], s["ln_t"] = jnp.zeros((d,), pdt), ("embed",)
    for i, nm in enumerate(("wr", "wk", "wv", "wg")):
        p[nm], s[nm] = dense_init(ks[i], (d, d), ("embed", "rnn"), pdt)
    p["wo_t"], s["wo_t"] = dense_init(ks[4], (d, d), ("rnn", "embed"), pdt)
    for i, nm in enumerate(("mu_r", "mu_k", "mu_v", "mu_w")):
        p[nm], s[nm] = (jnp.full((d,), 0.5, pdt), ("embed",))
    # data-dependent decay (low-rank, Finch)
    p["w0"], s["w0"] = jnp.full((d,), -6.0, pdt), ("rnn",)
    p["w_lora_a"], s["w_lora_a"] = dense_init(ks[5], (d, 64),
                                              ("embed", None), pdt)
    p["w_lora_b"], s["w_lora_b"] = (jnp.zeros((64, d), pdt), (None, "rnn"))
    p["u"], s["u"] = (jnp.zeros((H, dh), pdt), ("heads", "head_dim"))
    # channel mix
    p["ln_c"], s["ln_c"] = jnp.zeros((d,), pdt), ("embed",)
    p["mu_cr"], s["mu_cr"] = jnp.full((d,), 0.5, pdt), ("embed",)
    p["mu_ck"], s["mu_ck"] = jnp.full((d,), 0.5, pdt), ("embed",)
    p["ck"], s["ck"] = dense_init(ks[6], (d, ff), ("embed", "mlp"), pdt)
    p["cv"], s["cv"] = dense_init(ks[7], (ff, d), ("mlp", "embed"), pdt)
    p["cr"], s["cr"] = dense_init(ks[8], (d, d), ("embed", "rnn"), pdt)
    return p, s


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of the previous chunk (or zeros)."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return shifted, x[:, -1]


def rwkv_block(cfg: ModelConfig, p, rules, x, *, state=None, backend="auto"):
    """Returns (y, new_state); state = {"S": (B,H,dh,dh) f32,
    "x_t": (B,d), "x_c": (B,d)} (token-shift carries)."""
    dt = jnp.dtype(cfg.dtype)
    B, S, d = x.shape
    H = cfg.n_heads if cfg.n_heads else d // 64
    dh = d // H
    zeros = jnp.zeros((B, d), dt)
    st = state or {"S": jnp.zeros((B, H, dh, dh), jnp.float32),
                   "x_t": zeros, "x_c": zeros}

    # ---- time mix ----
    h = rmsnorm(x, p["ln_t"]).astype(dt)
    shifted, x_t_last = _token_shift(h, st["x_t"].astype(dt))

    def W(name, logical=("embed", "rnn")):
        return sharding.weight_use(p[name].astype(dt), rules, logical)

    def lerp(mu):
        m = p[mu].astype(dt)
        return h * (1 - m) + shifted * m

    r = (lerp("mu_r") @ W("wr")).reshape(B, S, H, dh)
    k = (lerp("mu_k") @ W("wk")).reshape(B, S, H, dh)
    v = (lerp("mu_v") @ W("wv")).reshape(B, S, H, dh)
    g = jax.nn.silu(h @ W("wg"))
    xw = lerp("mu_w")
    w_log = (p["w0"].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32)
                        @ p["w_lora_a"].astype(jnp.float32))
             @ p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, dh)     # decay in (0,1)
    r = sharding.constrain(r, rules, ("batch", "seq", "heads", "head_dim"))
    y, S_new = ops.rwkv6(r, k, v, w.astype(dt), p["u"].astype(dt),
                         st["S"], backend=backend)
    y = (y.reshape(B, S, d) * g) @ W("wo_t", ("rnn", "embed"))
    y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
    x = x + y

    # ---- channel mix ----
    hc = rmsnorm(x, p["ln_c"]).astype(dt)
    shifted_c, x_c_last = _token_shift(hc, st["x_c"].astype(dt))
    mk = p["mu_ck"].astype(dt)
    mr = p["mu_cr"].astype(dt)
    kk = (hc * (1 - mk) + shifted_c * mk) @ W("ck", ("embed", "mlp"))
    kk = jax.nn.relu(kk)
    kk = kk * kk
    kk = sharding.constrain(kk, rules, ("batch", "seq", "mlp"))
    rr = jax.nn.sigmoid((hc * (1 - mr) + shifted_c * mr)
                        @ W("cr", ("embed", "rnn")))
    y2 = rr * (kk @ W("cv", ("mlp", "embed")))
    y2 = sharding.constrain(y2, rules, ("batch", "seq", "embed"))
    return x + y2, {"S": S_new, "x_t": x_t_last, "x_c": x_c_last}

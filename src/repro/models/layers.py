"""Primitive layers: norms, rotary embeddings, attention blocks, MLPs.

Functional style: ``init_*`` builds ``(params, specs)`` where ``specs``
mirrors the param tree with tuples of *logical* axis names consumed by
:mod:`repro.models.sharding`.  Forward functions are pure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import sharding

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, shape, logical, dtype, fan_in_axes=(0,)):
    fan_in = 1
    for a in fan_in_axes:
        fan_in *= shape[a]
    return _normal(key, shape, fan_in ** -0.5, dtype), tuple(logical)


# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, scale, eps: float = 1e-6):
    """RMSNorm, f32 math inside, activation-dtype cotangents outside.

    A plain autodiff rmsnorm leaks f32 (B,S,d) cotangents onto the backward
    spine (via the x->f32 cast), doubling the bytes of every TP all-reduce
    behind it (observed in the v0 roofline).  The custom VJP computes the
    backward in f32 but hands back dx in x's dtype.
    """
    return _rms_fwd(x, scale, eps)[0]


def _rms_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    y = (xf * inv * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    return y, (x, scale, inv)


def _rms_bwd(eps, res, g):
    x, scale, inv = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    n = xf * inv
    gn = gf * (1.0 + scale.astype(jnp.float32))
    dx = inv * (gn - n * jnp.mean(gn * n, -1, keepdims=True))
    dscale = (gf * n).reshape(-1, x.shape[-1]).sum(0)
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


def rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq      # (B,S,half)
    cos = jnp.cos(ang)[..., None, :]                           # (B,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block (full / local / bidirectional; GQA; qkv bias)
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 5)
    pdt = jnp.dtype(cfg.param_dtype)
    p, s = {}, {}
    p["ln"], s["ln"] = jnp.zeros((d,), pdt), ("embed",)
    p["wq"], s["wq"] = dense_init(ks[0], (d, hq, dh),
                                  ("embed", "heads", "head_dim"), pdt)
    p["wk"], s["wk"] = dense_init(ks[1], (d, hkv, dh),
                                  ("embed", "kv_heads", "head_dim"), pdt)
    p["wv"], s["wv"] = dense_init(ks[2], (d, hkv, dh),
                                  ("embed", "kv_heads", "head_dim"), pdt)
    p["wo"], s["wo"] = dense_init(ks[3], (hq, dh, d),
                                  ("heads", "head_dim", "embed"), pdt,
                                  fan_in_axes=(0, 1))
    if cfg.qkv_bias:
        p["bq"], s["bq"] = jnp.zeros((hq, dh), pdt), ("heads", "head_dim")
        p["bk"], s["bk"] = jnp.zeros((hkv, dh), pdt), ("kv_heads", "head_dim")
        p["bv"], s["bv"] = jnp.zeros((hkv, dh), pdt), ("kv_heads", "head_dim")
    return p, s


def attention_block(cfg: ModelConfig, p, rules, x, positions, *,
                    kind: str, cache=None, lengths=None, backend="auto"):
    """Pre-norm attention residual block.

    Train/prefill: ``cache is None`` — self-attention over x; returns
    (y, (k, v)) so prefill can build the cache.
    Decode: ``cache = (k_cache, v_cache)`` (kvcache.KVLayer views) and
    ``lengths`` (B,) = tokens already cached; the new token's k/v are
    inserted at ``lengths`` and attention runs over ``lengths + 1``.
    """
    dt = jnp.dtype(cfg.dtype)
    h = rmsnorm(x, p["ln"]).astype(dt)

    def W(name, logical):
        return sharding.weight_use(p[name].astype(dt), rules, logical)

    q = jnp.einsum("bsd,dhk->bshk", h, W("wq", ("embed", "heads",
                                                "head_dim")))
    k = jnp.einsum("bsd,dhk->bshk", h, W("wk", ("embed", "kv_heads",
                                                "head_dim")))
    v = jnp.einsum("bsd,dhk->bshk", h, W("wv", ("embed", "kv_heads",
                                                "head_dim")))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = sharding.constrain(q, rules, ("batch", "seq", "heads", "head_dim"))
    k = sharding.constrain(k, rules, ("batch", "seq", "kv_heads", "head_dim"))

    causal = not cfg.bidirectional
    window = cfg.local_window if kind == "local" else None
    if cache is None:
        out = ops.attention(q, k, v, causal=causal, window=window,
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv, backend=backend)
        new_kv = (k, v)
    else:
        from . import kvcache
        kc, vc = cache
        kc = kvcache.insert(kc, k[:, 0], lengths, window if kind == "local"
                            else None)
        vc = kvcache.insert(vc, v[:, 0], lengths, window if kind == "local"
                            else None)
        if kind == "local":
            eff_len = jnp.minimum(lengths + 1, kvcache.size(kc))
        else:
            eff_len = lengths + 1
        out = ops.decode_attention(q, kvcache.dequant(kc),
                                   kvcache.dequant(vc), eff_len,
                                   backend=backend)
        new_kv = (kc, vc)
    out = sharding.constrain(out, rules, ("batch", "seq", "heads",
                                          "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out,
                   sharding.weight_use(p["wo"].astype(dt), rules,
                                       ("heads", "head_dim", "embed")))
    y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
    return x + y, new_kv


# ---------------------------------------------------------------------------
# MLP block (swiglu / squared_relu / gelu)
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key):
    d, ff = cfg.d_model, cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln"], s["ln"] = jnp.zeros((d,), pdt), ("embed",)
    if cfg.act == "swiglu":
        p["wi_gate"], s["wi_gate"] = dense_init(ks[0], (d, ff),
                                                ("embed", "mlp"), pdt)
    p["wi"], s["wi"] = dense_init(ks[1], (d, ff), ("embed", "mlp"), pdt)
    p["wo"], s["wo"] = dense_init(ks[2], (ff, d), ("mlp", "embed"), pdt)
    return p, s


def _act(cfg, gate, up):
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "squared_relu":
        r = jax.nn.relu(up)
        return r * r
    if cfg.act == "gelu":
        return jax.nn.gelu(up)
    raise ValueError(cfg.act)


def mlp_block(cfg: ModelConfig, p, rules, x):
    dt = jnp.dtype(cfg.dtype)
    h = rmsnorm(x, p["ln"]).astype(dt)
    up = h @ sharding.weight_use(p["wi"].astype(dt), rules,
                                 ("embed", "mlp"))
    gate = (h @ sharding.weight_use(p["wi_gate"].astype(dt), rules,
                                    ("embed", "mlp"))
            if cfg.act == "swiglu" else None)
    a = _act(cfg, gate, up)
    a = sharding.constrain(a, rules, ("batch", "seq", "mlp"))
    y = a @ sharding.weight_use(p["wo"].astype(dt), rules,
                                ("mlp", "embed"))
    y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
    return x + y


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def init_embeddings(cfg: ModelConfig, key):
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    if not cfg.embeds_only:
        p["tok"], s["tok"] = (_normal(ks[0], (cfg.vocab, cfg.d_model), 0.02,
                                      pdt), ("vocab", "embed"))
    p["final_ln"], s["final_ln"] = jnp.zeros((cfg.d_model,), pdt), ("embed",)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab),
                                          ("embed", "vocab"), pdt)
    if cfg.mm_prefix:
        p["mm_proj"], s["mm_proj"] = dense_init(
            ks[2], (cfg.mm_embed_dim, cfg.d_model), ("embed", None), pdt)
    return p, s


def embed_tokens(cfg: ModelConfig, p, rules, batch):
    dt = jnp.dtype(cfg.dtype)
    if cfg.embeds_only:
        x = batch["embeds"].astype(dt)
    else:
        tok = sharding.weight_use(p["tok"].astype(dt), rules,
                                  ("vocab", "embed"))
        x = tok[batch["token_ids"]]
        if cfg.mm_prefix and "mm_embeds" in batch:
            proj = batch["mm_embeds"].astype(dt) @ p["mm_proj"].astype(dt)
            prefix = min(cfg.mm_prefix, x.shape[1])
            x = x.at[:, :prefix].set(proj[:, :prefix])
    return sharding.constrain(x, rules, ("batch", "seq", "embed"))


def logits_head(cfg: ModelConfig, p, rules, x):
    h = rmsnorm(x, p["final_ln"])
    if cfg.tie_embeddings:
        w = sharding.weight_use(p["tok"], rules, ("vocab", "embed")).T
    else:
        w = sharding.weight_use(p["head"], rules, ("embed", "vocab"))
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        w.astype(jnp.float32))
    return sharding.constrain(logits, rules, ("batch", "seq", "vocab"))


def cross_entropy(cfg: ModelConfig, logits, labels, mask=None):
    """Mean token NLL + z-loss; logits f32 (B,S,V)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    zl = cfg.z_loss * logz ** 2
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(per_tok)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    return loss, {"nll": (nll * mask).sum() / denom,
                  "z": (zl * mask).sum() / denom}

"""Logical-axis sharding (MaxText-style rules).

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "mlp", ...); a per-config rule table maps each logical
axis to a physical mesh axis (or a tuple, or None).  Rules are resolved
against whatever mesh is active, so the same model code runs on the
single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh, and
the 1-device CPU mesh used by smoke tests.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _resolve_axis(rule, mesh_axes: tuple[str, ...]):
    """Map one logical axis's rule onto the axes present in the mesh."""
    if rule is None:
        return None
    if isinstance(rule, str):
        return rule if rule in mesh_axes else None
    # tuple of candidate axes: keep those present (e.g. batch over pod+data)
    present = tuple(a for a in rule if a in mesh_axes)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def spec(rules: Mapping[str, object], logical: Sequence[str | None],
         mesh: Mesh | None = None) -> P:
    """PartitionSpec for an array whose dims carry ``logical`` axis names."""
    mesh = mesh or _current_mesh()
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    out, used = [], set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axis = _resolve_axis(rules.get(name), mesh_axes)
        # a physical mesh axis may appear at most once in a PartitionSpec
        if axis is None:
            out.append(None)
        elif isinstance(axis, tuple):
            fresh = tuple(a for a in axis if a not in used)
            used.update(fresh)
            out.append(fresh if fresh else None)
        elif axis in used:
            out.append(None)
        else:
            used.add(axis)
            out.append(axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _current_mesh() -> Mesh | None:
    """The mesh installed by ``with mesh:`` around the current trace."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x, rules: Mapping[str, object],
              logical: Sequence[str | None]):
    """with_sharding_constraint by logical axes; no-op outside a mesh."""
    mesh = _current_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(rules, logical, mesh)))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def named_sharding(mesh: Mesh, rules: Mapping[str, object],
                   logical: Sequence[str | None],
                   shape: Sequence[int] | None = None) -> NamedSharding:
    """NamedSharding for logical axes; with ``shape`` given, mesh axes that
    do not divide the corresponding dim are dropped (jit input shardings
    must be even — e.g. qwen1.5's 40 heads cannot split 16 ways, so the
    head axis falls back to replication and GSPMD reshards internally)."""
    s = spec(rules, logical, mesh)
    if shape is not None:
        parts = []
        for i, axis in enumerate(s):
            if i < len(shape) and shape[i] % _axis_size(mesh, axis) != 0:
                parts.append(None)
            else:
                parts.append(axis)
        s = P(*parts)
    return NamedSharding(mesh, s)


def _is_logical(x):
    # NB: the empty tuple is a container (e.g. an empty "tail"), not a
    # scalar spec — scalar params don't occur in the model trees.
    return isinstance(x, tuple) and len(x) > 0 and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(mesh: Mesh, rules: Mapping[str, object], spec_tree,
                   shape_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``shape_tree``: matching pytree of arrays/ShapeDtypeStructs enabling the
    divisibility fallback for jit input shardings.
    """
    if shape_tree is None:
        return jax.tree.map(lambda lg: named_sharding(mesh, rules, lg),
                            spec_tree, is_leaf=_is_logical)
    flat_specs, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_logical)
    flat_shapes = treedef.flatten_up_to(shape_tree)
    out = [named_sharding(mesh, rules, lg, x.shape)
           for lg, x in zip(flat_specs, flat_shapes)]
    return treedef.unflatten(out)


def weight_use(w, rules: Mapping[str, object],
               logical: Sequence[str | None]):
    """FSDP weight-gather: constrain a *stored-sharded* weight to its
    compute sharding (tensor-parallel axes only) at the use site.

    Without this, GSPMD may satisfy a contraction over an fsdp-sharded
    ("embed"->data) weight dim by computing partial sums and ALL-REDUCING
    THE ACTIVATIONS (e.g. f32[B,S,d] per projection — the dominant
    collective in the v0 baseline roofline).  Constraining the weight to
    embed->None forces the intended all-gather of the (much smaller)
    weight instead; the gradient flows back through the constraint and is
    reduce-scattered to the storage sharding by the optimizer update.
    """
    rules2 = dict(rules)
    rules2["embed"] = None
    return constrain(w, rules2, logical)


def resolved_size(rules: Mapping[str, object], logical: str) -> int:
    """Product of mesh-axis sizes a logical axis resolves to (1 off-mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    axis = _resolve_axis(rules.get(logical), tuple(mesh.axis_names))
    return _axis_size(mesh, axis)

"""Mixture-of-Experts channel mixing: top-k routing, permutation dispatch.

Sort-based (gshard-one-hot-free) dispatch: token·expert assignments are
sorted by expert id, positions within each expert group come from a cumsum
over bincounts, tokens beyond the per-expert capacity are dropped (their
combine weight is zero — the residual path carries them), and expert FFNs
run as one batched einsum over the (experts, capacity, d) buffer.  Experts
shard over the "experts" logical axis (EP over the model mesh axis); the
inner FFN dim can additionally shard over "expert_mlp" (2-D sharding for
huge serving models).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import sharding
from .layers import rmsnorm, dense_init


def _ffn(cfg, x_in, wi, wg, wo):
    """Expert FFN over (E_loc, C, d) inputs with (E_loc, d, ff) weights."""
    up = jnp.einsum("ecd,edf->ecf", x_in, wi)
    if cfg.act == "swiglu":
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_in, wg)) * up
    elif cfg.act == "squared_relu":
        r = jax.nn.relu(up)
        act = r * r
    else:
        act = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", act, wo)


def _moe_ep_shardmap(cfg, h, gate_vals, expert_idx, wi, wg, wo, rules, mesh):
    """Expert-parallel dispatch with explicit all_to_all (shard_map).

    Per device: local tokens are sorted by expert, packed into a
    (tp, E_loc, C, d) send buffer grouped by owner rank, exchanged with
    ``all_to_all`` over the expert axis, run through the local experts, and
    returned by the inverse all_to_all — the canonical EP schedule.  GSPMD
    left to its own devices on the HLO scatter materializes the same
    exchange as (T*k, d) all-reduces over the model axis (v1 baseline:
    ~70x the structural-floor bytes).
    """
    mo = cfg.moe
    dt = h.dtype
    T, d = h.shape
    E, k = mo.n_experts, mo.top_k
    mesh_axes = tuple(mesh.axis_names)
    tp_axis = rules.get("experts")
    batch_axes = tuple(a for a in (rules.get("batch") or ())
                       if a in mesh_axes)
    tp = mesh.shape[tp_axis]
    E_loc = E // tp
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    T_loc = T // dp
    # tokens are replicated over the expert axis: each tp rank dispatches
    # its own 1/tp chunk (otherwise every owner receives tp identical
    # copies and expert compute inflates tp-fold).
    chunk = T_loc // tp
    cap = int(max(1, round(chunk * k * mo.capacity_factor / E)))

    def body(h_l, gates_l, idx_l, wi_l, wg_l, wo_l):
        r = jax.lax.axis_index(tp_axis)
        h_c = jax.lax.dynamic_slice_in_dim(h_l, r * chunk, chunk, 0)
        gates_c = jax.lax.dynamic_slice_in_dim(gates_l, r * chunk, chunk, 0)
        idx_c = jax.lax.dynamic_slice_in_dim(idx_l, r * chunk, chunk, 0)
        flat_e = idx_c.reshape(chunk * k)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        counts = (sorted_e[:, None] == jnp.arange(E)[None]).sum(0)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(chunk * k) - starts[sorted_e]
        keep = pos < cap
        tok = order // k
        owner = sorted_e // E_loc
        e_loc = sorted_e % E_loc
        we = jnp.where(keep, owner, 0)
        wl = jnp.where(keep, e_loc, 0)
        wc = jnp.where(keep, pos, 0)
        src = jnp.where(keep[:, None], h_c[tok], 0)
        send = jnp.zeros((tp, E_loc, cap, d), dt).at[we, wl, wc].add(src)
        recv = jax.lax.all_to_all(send, tp_axis, 0, 0, tiled=False)             if tp > 1 else send
        x_in = recv.transpose(1, 0, 2, 3).reshape(E_loc, tp * cap, d)
        out = _ffn(cfg, x_in, wi_l, wg_l, wo_l)
        out_send = out.reshape(E_loc, tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(out_send, tp_axis, 0, 0, tiled=False)             if tp > 1 else out_send
        gathered = back[we, wl, wc]
        gathered = jnp.where(keep[:, None], gathered, 0)
        gates_sorted = gates_c.reshape(chunk * k)[order]
        y_c = jnp.zeros((chunk, d), dt).at[tok].add(
            gathered * gates_sorted[:, None].astype(dt))
        if tp > 1:   # reassemble the T_loc tokens from the tp chunks
            return jax.lax.all_gather(y_c, tp_axis, axis=0, tiled=True)
        return y_c

    bspec = P(batch_axes if len(batch_axes) > 1 else
              (batch_axes[0] if batch_axes else None))
    wspec = P(tp_axis)
    y = jax.shard_map(
        body, mesh=mesh,
        in_specs=(bspec, bspec, bspec, wspec, wspec, wspec),
        out_specs=bspec, check_vma=False,
    )(h, gate_vals.astype(dt), expert_idx, wi,
      wg if wg is not None else wi, wo)
    return y


def init_moe(cfg: ModelConfig, key):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln"], s["ln"] = jnp.zeros((d,), pdt), ("embed",)
    p["router"], s["router"] = dense_init(ks[0], (d, E),
                                          ("embed", "experts"), pdt)
    if cfg.act == "swiglu":
        p["wi_gate"], s["wi_gate"] = dense_init(
            ks[1], (E, d, ff), ("experts", "embed", "expert_mlp"), pdt,
            fan_in_axes=(1,))
    p["wi"], s["wi"] = dense_init(ks[2], (E, d, ff),
                                  ("experts", "embed", "expert_mlp"), pdt,
                                  fan_in_axes=(1,))
    p["wo"], s["wo"] = dense_init(ks[3], (E, ff, d),
                                  ("experts", "expert_mlp", "embed"), pdt,
                                  fan_in_axes=(1,))
    return p, s


def moe_block(cfg: ModelConfig, p, rules, x):
    """x: (B, S, d) -> (B, S, d) residual-added; returns (y, aux_losses).

    Dispatch is *block-local*: tokens are reshaped to (DP, T_loc, d) where
    DP is the resolved size of the "batch" sharding axes, and sorting /
    position assignment / scatter / combine all happen within a block.
    Every dispatch index then lives on the data shard that owns the block,
    so GSPMD keeps the scatter/gather local instead of materializing
    cross-shard scatter-adds as (T*k, d) all-reduces (the dominant
    collective of the v1 baseline: 12.9 GB/op on dbrx).  Capacity is
    enforced per block (standard local-capacity semantics); DP=1 (tests,
    single host) reduces to the global formulation exactly.
    """
    mo = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    B, S, d = x.shape
    T = B * S
    E, k = mo.n_experts, mo.top_k
    mesh = sharding._current_mesh()
    tp = sharding.resolved_size(rules, "experts")
    dp = sharding.resolved_size(rules, "batch")
    if T % dp:
        dp = 1
    T_loc = T // dp

    h = rmsnorm(x, p["ln"]).astype(dt).reshape(T, d)
    logits = (h.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (load balance + router z) ----
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * k))
    aux = mo.aux_loss_weight * E * jnp.sum(me * ce)
    zloss = mo.router_z_weight * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    # shard_map EP pays off at train/prefill token counts; at decode scale
    # (T ~ batch) the jnp path's scatters are a few MB and the 2-D expert
    # weight sharding (expert_mlp -> data) must stay resident.
    if (mesh is not None and tp > 1 and E % tp == 0 and T % dp == 0
            and (T // dp) % tp == 0 and T // dp >= 2048):
        wi = sharding.weight_use(p["wi"].astype(dt), rules,
                                 ("experts", "embed", "expert_mlp"))
        wg = (sharding.weight_use(p["wi_gate"].astype(dt), rules,
                                  ("experts", "embed", "expert_mlp"))
              if cfg.act == "swiglu" else None)
        wo = sharding.weight_use(p["wo"].astype(dt), rules,
                                 ("experts", "expert_mlp", "embed"))
        y = _moe_ep_shardmap(cfg, h, gate_vals, expert_idx, wi, wg, wo,
                             rules, mesh)
        y = y.reshape(B, S, d)
        y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
        return x + y, {"moe_aux": aux, "moe_z": zloss}

    # ---- block-local sort-based dispatch with per-block capacity ----
    cap = int(max(1, round(T_loc * k * mo.capacity_factor / E)))
    h_blk = h.reshape(dp, T_loc, d)
    flat_e = expert_idx.reshape(dp, T_loc * k)
    order = jnp.argsort(flat_e, axis=1)                          # per block
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = (sorted_e[:, :, None] == jnp.arange(E)[None, None]).sum(1)
    starts = jnp.cumsum(counts, axis=1) - counts                 # (dp, E)
    pos = (jnp.arange(T_loc * k)[None]
           - jnp.take_along_axis(starts, sorted_e, axis=1))
    keep = pos < cap
    tok_loc = order // k                                         # (dp, Tk)
    blk = jnp.broadcast_to(jnp.arange(dp)[:, None], tok_loc.shape)

    write_e = jnp.where(keep, sorted_e, 0)
    write_c = jnp.where(keep, pos, 0)
    src = jnp.take_along_axis(h_blk, tok_loc[..., None], axis=1)
    src = jnp.where(keep[..., None], src, 0)
    buf = jnp.zeros((E, dp, cap, d), dt)
    buf = buf.at[write_e, blk, write_c].add(src.astype(dt))
    buf = sharding.constrain(buf, rules, ("experts", "batch", None, "embed"))

    # ---- expert FFNs (weights gathered from fsdp storage) ----
    wi = sharding.weight_use(p["wi"].astype(dt), rules,
                             ("experts", "embed", "expert_mlp"))
    up = jnp.einsum("ebcd,edf->ebcf", buf, wi)
    if cfg.act == "swiglu":
        wg = sharding.weight_use(p["wi_gate"].astype(dt), rules,
                                 ("experts", "embed", "expert_mlp"))
        act = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", buf, wg)) * up
    elif cfg.act == "squared_relu":
        r = jax.nn.relu(up)
        act = r * r
    else:
        act = jax.nn.gelu(up)
    act = sharding.constrain(act, rules,
                             ("experts", "batch", None, "expert_mlp"))
    wo = sharding.weight_use(p["wo"].astype(dt), rules,
                             ("experts", "expert_mlp", "embed"))
    out_buf = jnp.einsum("ebcf,efd->ebcd", act, wo)
    out_buf = sharding.constrain(out_buf, rules,
                                 ("experts", "batch", None, "embed"))

    # ---- block-local combine ----
    gathered = out_buf[write_e, blk, write_c]                    # (dp,Tk,d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    gates_sorted = jnp.take_along_axis(
        gate_vals.reshape(dp, T_loc * k), order, axis=1)
    y = jnp.zeros((dp, T_loc, d), dt).at[blk, tok_loc].add(
        gathered * gates_sorted[..., None].astype(dt))
    y = y.reshape(B, S, d)
    y = sharding.constrain(y, rules, ("batch", "seq", "embed"))
    return x + y, {"moe_aux": aux, "moe_z": zloss}

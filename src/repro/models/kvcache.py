"""KV-cache storage: full or ring-buffer (local attention), bf16 or int8.

A cache *layer view* is a dict ``{"data": (B, S, Hkv, D)}`` plus, when
quantized, ``{"scale": (B, S, Hkv, 1) float32}``.  int8 quantization is
per (position, head) absmax — a beyond-paper memory optimization that keeps
the 40-kv-head qwen1.5-32b decode_32k cell inside 16 GB/chip (recorded in
EXPERIMENTS.md §Perf).  Ring buffers exploit softmax permutation-invariance:
slots are overwritten modulo the window and masking is by valid count only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_layer(batch: int, seq: int, n_kv: int, d: int, dtype: str):
    if dtype == "int8":
        return {"data": jnp.zeros((batch, seq, n_kv, d), jnp.int8),
                "scale": jnp.zeros((batch, seq, n_kv, 1), jnp.float32)}
    return {"data": jnp.zeros((batch, seq, n_kv, d), jnp.dtype(dtype))}


def size(layer) -> int:
    return layer["data"].shape[1]


def _quant(x):
    """x: (..., D) -> (int8 data, f32 scale(..., 1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequant(layer):
    if "scale" in layer:
        return (layer["data"].astype(jnp.float32) * layer["scale"]
                ).astype(jnp.bfloat16)
    return layer["data"]


def insert(layer, new, lengths, window: int | None = None):
    """Insert one token's kv. new: (B, Hkv, D); lengths: (B,) tokens cached."""
    b = new.shape[0]
    slot = lengths % size(layer) if window is not None else lengths
    rows = jnp.arange(b)
    if "scale" in layer:
        q, s = _quant(new)
        return {"data": layer["data"].at[rows, slot].set(q),
                "scale": layer["scale"].at[rows, slot].set(s)}
    return {"data": layer["data"].at[rows, slot].set(
        new.astype(layer["data"].dtype))}


def from_prefill(k, v, capacity: int, dtype: str, window: int | None = None):
    """Build cache layers from prefill-computed k, v: (B, S, Hkv, D).

    For local attention only the last ``window`` positions are kept (ring
    layout with slot = pos % window so subsequent inserts line up).
    """
    B, S, H, D = k.shape

    def build(x):
        if window is not None:
            cap = min(window, capacity)
            layer = init_layer(B, cap, H, D, dtype)
            take = min(S, cap)
            chunk = x[:, S - take:]                         # last positions
            pos = (jnp.arange(S - take, S) % cap)
            if "scale" in layer:
                q, s = _quant(chunk)
                return {"data": layer["data"].at[:, pos].set(q),
                        "scale": layer["scale"].at[:, pos].set(s)}
            return {"data": layer["data"].at[:, pos].set(
                chunk.astype(layer["data"].dtype))}
        layer = init_layer(B, capacity, H, D, dtype)
        if "scale" in layer:
            q, s = _quant(x)
            return {"data": layer["data"].at[:, :S].set(q),
                    "scale": layer["scale"].at[:, :S].set(s)}
        return {"data": layer["data"].at[:, :S].set(
            x.astype(layer["data"].dtype))}

    return build(k), build(v)

from .model import Model, build  # noqa: F401

"""Layer stack assembly: scan-over-groups, remat, heterogeneous patterns.

The repeating ``cfg.block_pattern`` (e.g. ("rglru","rglru","attn") for
recurrentgemma) defines a *supergroup*; parameters of all full supergroups
are stacked on a leading group axis and executed with ``jax.lax.scan``
(compact HLO, fast SPMD compile); pattern-remainder tail layers run
unrolled.  Every layer kind exposes the same interface:

    apply_layer(cfg, kind, params, rules, x, positions,
                cache=None, lengths=None, backend) -> (x, new_cache, aux)

with cache pytrees per kind (attention: kv cache views; rglru: h + conv
state; rwkv: matrix state + token-shift carries).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import kvcache, layers, moe, recurrent


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(cfg: ModelConfig, kind: str, key):
    kt, kc = jax.random.split(key)
    p, s = {}, {}
    if kind in ("attn", "local"):
        p["t"], s["t"] = layers.init_attention(cfg, kt)
    elif kind == "rglru":
        p["t"], s["t"] = recurrent.init_rglru(cfg, kt)
    elif kind == "rwkv":
        p["t"], s["t"] = recurrent.init_rwkv(cfg, kt)
    else:
        raise ValueError(kind)
    if kind != "rwkv":                     # rwkv carries its own channel mix
        if cfg.moe is not None:
            p["c"], s["c"] = moe.init_moe(cfg, kc)
        else:
            p["c"], s["c"] = layers.init_mlp(cfg, kc)
    return p, s


def apply_layer(cfg: ModelConfig, kind: str, p, rules, x, positions, *,
                cache=None, lengths=None, collect_kv=False, backend="auto",
                cache_capacity=None):
    aux = {}
    new_cache = None
    if kind in ("attn", "local"):
        att_cache = None if cache is None else (cache["k"], cache["v"])
        x, kv = layers.attention_block(
            cfg, p["t"], rules, x, positions, kind=kind, cache=att_cache,
            lengths=lengths, backend=backend)
        if cache is not None:
            new_cache = {"k": kv[0], "v": kv[1]}
        elif collect_kv:
            cap = cache_capacity or x.shape[1]
            kc, vc = kvcache.from_prefill(
                kv[0], kv[1], cap, cfg.kv_cache_dtype,
                cfg.local_window if kind == "local" else None)
            new_cache = {"k": kc, "v": vc}
    elif kind == "rglru":
        x, st = recurrent.rglru_block(cfg, p["t"], rules, x,
                                      state=cache, backend=backend)
        new_cache = st if (cache is not None or collect_kv) else None
    elif kind == "rwkv":
        x, st = recurrent.rwkv_block(cfg, p["t"], rules, x,
                                     state=cache, backend=backend)
        new_cache = st if (cache is not None or collect_kv) else None
    if "c" in p:
        if cfg.moe is not None:
            x, aux = moe.moe_block(cfg, p["c"], rules, x)
        else:
            x = layers.mlp_block(cfg, p["c"], rules, x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_stack(cfg: ModelConfig, key):
    """Returns (params, specs).  params = {"emb", "groups", "tail"}."""
    kinds = cfg.layer_kinds
    P = len(cfg.block_pattern) if cfg.scan_layers else 1
    pattern = cfg.block_pattern if cfg.scan_layers else (None,)
    n_groups = len(kinds) // P if cfg.scan_layers else 0
    n_scanned = n_groups * P

    keys = jax.random.split(key, len(kinds) + 1)
    p_emb, s_emb = layers.init_embeddings(cfg, keys[-1])
    params = {"emb": p_emb}
    specs = {"emb": s_emb}

    if cfg.scan_layers and n_groups > 0:
        groups, gspecs = [], None
        for pos in range(P):
            per_pos = []
            for g in range(n_groups):
                li = g * P + pos
                lp, ls = init_layer(cfg, kinds[li], keys[li])
                per_pos.append(lp)
                gspecs_pos = ls
            stacked = _stack(per_pos)
            groups.append(stacked)
            if gspecs is None:
                gspecs = []
            # prepend the scan ("layers") axis to every logical tuple
            gspecs.append(jax.tree.map(
                lambda lg: ("layers",) + lg, gspecs_pos,
                is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
                and all(isinstance(e, (str, type(None))) for e in x)))
        params["groups"] = tuple(groups)
        specs["groups"] = tuple(gspecs)
    else:
        n_scanned = 0
        params["groups"] = ()
        specs["groups"] = ()

    tail_p, tail_s = [], []
    for li in range(n_scanned, len(kinds)):
        lp, ls = init_layer(cfg, kinds[li], keys[li])
        tail_p.append(lp)
        tail_s.append(ls)
    params["tail"] = tuple(tail_p)
    specs["tail"] = tuple(tail_s)
    return params, specs


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _is_spec_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def forward(cfg: ModelConfig, params, batch, rules, *, backend="auto",
            collect_kv=False, last_only=False, cache_capacity=None):
    """Full-sequence forward (train / prefill).

    Returns (logits, caches, aux) — caches is None unless collect_kv.
    """
    x = layers.embed_tokens(cfg, params["emb"], rules, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kinds = cfg.layer_kinds
    P = len(cfg.block_pattern)
    aux_total = {"moe_aux": 0.0, "moe_z": 0.0}

    group_caches = None
    if params["groups"]:
        def group_body(carry, group_params):
            x, aux_in = carry
            new_caches = []
            for pos in range(P):
                kind = cfg.block_pattern[pos]
                x, cache, aux = apply_layer(
                    cfg, kind, group_params[pos], rules, x, positions,
                    collect_kv=collect_kv, backend=backend,
                    cache_capacity=cache_capacity)
                new_caches.append(cache)
                for k in aux:
                    aux_in = dict(aux_in, **{k: aux_in.get(k, 0.0) + aux[k]})
            return (x, aux_in), tuple(new_caches) if collect_kv else None

        body = jax.checkpoint(group_body) if cfg.remat else group_body
        (x, aux_total), group_caches = jax.lax.scan(
            body, (x, aux_total), params["groups"])

    tail_caches = []
    n_scanned = len(kinds) - len(params["tail"])
    for i, lp in enumerate(params["tail"]):
        kind = kinds[n_scanned + i]

        def tail_fn(x_, lp_, _kind=kind):
            return apply_layer(cfg, _kind, lp_, rules, x_, positions,
                               collect_kv=collect_kv, backend=backend,
                               cache_capacity=cache_capacity)

        if cfg.remat:   # cost-parity with the checkpointed scan groups
            tail_fn = jax.checkpoint(tail_fn)
        x, cache, aux = tail_fn(x, lp)
        tail_caches.append(cache)
        for k in aux:
            aux_total[k] = aux_total.get(k, 0.0) + aux[k]

    if last_only:
        x = x[:, -1:]
    logits = layers.logits_head(cfg, params["emb"], rules, x)
    caches = ({"groups": group_caches, "tail": tuple(tail_caches)}
              if collect_kv else None)
    return logits, caches, aux_total


def decode_step(cfg: ModelConfig, params, caches, batch, rules, *,
                backend="auto"):
    """One-token decode. batch: {"token_ids": (B,1) or "embeds",
    "lengths": (B,)}.  Returns (logits (B,1,V), new caches)."""
    lengths = batch["lengths"]
    x = layers.embed_tokens(cfg, params["emb"], rules, batch)
    positions = lengths[:, None]                      # (B,1) absolute pos
    kinds = cfg.layer_kinds
    P = len(cfg.block_pattern)

    new_group_caches = None
    if params["groups"]:
        def group_body(x, scanned):
            group_params, group_cache = scanned
            new_caches = []
            for pos in range(P):
                kind = cfg.block_pattern[pos]
                x, cache, _ = apply_layer(
                    cfg, kind, group_params[pos], rules, x, positions,
                    cache=group_cache[pos], lengths=lengths, backend=backend)
                new_caches.append(cache)
            return x, tuple(new_caches)

        x, new_group_caches = jax.lax.scan(
            group_body, x, (params["groups"], caches["groups"]))

    new_tail = []
    n_scanned = len(kinds) - len(params["tail"])
    for i, lp in enumerate(params["tail"]):
        kind = kinds[n_scanned + i]
        x, cache, _ = apply_layer(cfg, kind, lp, rules, x, positions,
                                  cache=caches["tail"][i], lengths=lengths,
                                  backend=backend)
        new_tail.append(cache)

    logits = layers.logits_head(cfg, params["emb"], rules, x)
    return logits, {"groups": new_group_caches, "tail": tuple(new_tail)}


def loss_fn(cfg: ModelConfig, params, batch, rules, *, backend="auto"):
    logits, _, aux = forward(cfg, params, batch, rules, backend=backend)
    labels = batch["labels"]
    mask = batch.get("mask")
    loss, metrics = layers.cross_entropy(cfg, logits, labels, mask)
    for k, v in aux.items():
        loss = loss + v
        metrics[k] = v
    metrics["loss"] = loss
    return loss, metrics

"""Export a JAX model (config × input shape) as a schedulable DNN graph.

This is the bridge from the model substrate to the HaX-CoNN core: layers
are grouped into atomic units (supergroup-aligned chunks; embedding and the
logits head are their own groups since transitions there are natural
pipeline points), each carrying analytic FLOPs / HBM bytes / boundary
activation sizes — the same quantities §3.2 measures with IProfiler on the
SoC, derived here from the architecture (and cross-checked against the
dry-run probes).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.accelerators import Platform
from repro.core.characterize import GroupCosts, characterize
from repro.core.graph import DNNGraph


def _layer_flops(cfg: ModelConfig, kind: str, tokens: int, kv_len: float
                 ) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    fl = 0.0
    if kind in ("attn", "local"):
        fl += 2 * tokens * d * (hq + 2 * hkv) * dh       # qkv proj
        fl += 2 * tokens * hq * dh * d                   # out proj
        span = min(cfg.local_window, kv_len) if kind == "local" else kv_len
        fl += 4 * tokens * hq * span * dh                # QK^T + PV
    elif kind == "rglru":
        r = cfg.d_rnn
        fl += 2 * tokens * (2 * d * r + r * d + 2 * r * r) + 10 * tokens * r
    elif kind == "rwkv":
        fl += 2 * tokens * 5 * d * d
        fl += 6 * tokens * cfg.n_heads * (d // cfg.n_heads) ** 2
    if kind != "rwkv":
        n_mats = 3 if cfg.act == "swiglu" else 2
        eff = cfg.moe.top_k if cfg.moe else 1
        fl += 2 * tokens * n_mats * d * ff * eff
        if cfg.moe:
            fl += 2 * tokens * d * cfg.moe.n_experts
    else:
        fl += 2 * tokens * 2 * d * ff
    return fl


def _layer_bytes(cfg: ModelConfig, kind: str, tokens: int, kv_len: float,
                 decode: bool) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    act_b = 2
    w_b = 2                                               # serving bf16
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kv_b = 1 if cfg.kv_cache_dtype == "int8" else 2
    by = 0.0
    # weights touched once
    if kind in ("attn", "local"):
        by += ((hq + 2 * hkv) * dh * d + hq * dh * d) * w_b
    elif kind == "rglru":
        by += (3 * d * cfg.d_rnn + 2 * cfg.d_rnn ** 2) * w_b
    elif kind == "rwkv":
        by += 5 * d * d * w_b
    if kind != "rwkv":
        n_mats = 3 if cfg.act == "swiglu" else 2
        n_exp = cfg.moe.n_experts if (cfg.moe and not decode) else \
            (cfg.moe.top_k if cfg.moe else 1)
        by += n_mats * d * ff * n_exp * w_b
    else:
        by += 2 * d * ff * w_b
    # activations
    by += tokens * (8 * d + 2 * ff) * act_b
    # kv cache
    if kind in ("attn", "local"):
        span = min(cfg.local_window, kv_len) if kind == "local" else kv_len
        if decode:
            by += tokens * span * 2 * hkv * dh * kv_b
        else:
            by += tokens * 2 * hkv * dh * kv_b            # write
    return by


def export_graph(cfg: ModelConfig, cell: ShapeCell, platform: Platform,
                 layers_per_group: int | None = None,
                 name: str | None = None) -> DNNGraph:
    decode = cell.kind == "decode"
    tokens = cell.global_batch * (1 if decode else cell.seq_len)
    kv_len = cell.seq_len
    P = len(cfg.block_pattern)
    if layers_per_group is None:
        layers_per_group = max(P, (cfg.n_layers + 7) // 8 // P * P or P)
    act_out = tokens * cfg.d_model * 2                    # boundary bytes

    act_b = 2
    costs = [GroupCosts(
        name="embed",
        flops=2.0 * tokens * cfg.d_model,
        hbm_bytes=tokens * cfg.d_model * 2 + cfg.vocab * cfg.d_model * 2
        / max(1, cfg.vocab // 4096),       # gathered rows only
        shared_bytes=tokens * cfg.d_model * act_b,
        out_bytes=act_out,
    )]
    kinds = cfg.layer_kinds
    i = 0
    gi = 0
    while i < len(kinds):
        span = kinds[i:i + layers_per_group]
        fl = sum(_layer_flops(cfg, k, tokens, kv_len) for k in span)
        by = sum(_layer_bytes(cfg, k, tokens, kv_len, decode) for k in span)
        # shared (ICI) traffic: ~2 activation all-reduces per layer under
        # TP serving, plus the EP all-to-all for MoE layers.
        coll = len(span) * 2 * tokens * cfg.d_model * act_b
        if cfg.moe is not None:
            coll += len(span) * 2 * tokens * cfg.moe.top_k \
                * cfg.d_model * act_b
        costs.append(GroupCosts(
            name=f"L{i}-{i + len(span) - 1}",
            flops=fl, hbm_bytes=by, shared_bytes=coll, out_bytes=act_out,
        ))
        i += len(span)
        gi += 1
    head_tokens = cell.global_batch if cell.kind != "train" else tokens
    costs.append(GroupCosts(
        name="head",
        flops=2.0 * head_tokens * cfg.d_model * cfg.vocab,
        hbm_bytes=cfg.d_model * cfg.vocab * 2 + head_tokens * cfg.vocab * 4,
        shared_bytes=head_tokens * cfg.d_model * 4,
        out_bytes=head_tokens * cfg.vocab * 4,
    ))
    return characterize(name or f"{cfg.name}:{cell.name}", platform, costs)

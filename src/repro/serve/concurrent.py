"""Concurrent multi-model serving with HaX-CoNN schedules — the paper's
technique as a first-class framework feature.

A pod is split into virtual accelerators (submeshes); each model to be
served concurrently is exported as a layer-group graph with analytic
roofline costs per submesh (:mod:`repro.models.graph_export`); the HaX-CoNN
solver maps groups to submeshes, contention-aware on the shared ICI domain,
with resharding transition costs; and the plan is evaluated against every
baseline under the exact contention simulator.

On this CPU-only container the *timing* is simulated (the cost model is the
dry-run-calibrated roofline) while the *compute* runs for real on reduced
configs — `CoServer.run_round` executes both models and reports outputs
plus the schedule's predicted timeline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeCell
from repro.core.accelerators import Platform, tpu_pod_split
from repro.core.graph import DNNGraph
from repro.core.plan import Plan
from repro.core.scheduler import Scheduler, failed
from repro.models import Model
from repro.models.graph_export import export_graph


@dataclass
class ServingPlan:
    graphs: list[DNNGraph]
    solution: object                  # core.solver_bb.Solution
    #: per-baseline SimResult, or a structured {"error": ...} row when that
    #: baseline is infeasible on this platform (see core.scheduler.failed).
    baselines: dict[str, object]
    platform: Platform
    #: serializable provenance artifact of the haxconn solution.
    plan: Plan | None = None

    @property
    def speedup_vs_best_baseline(self) -> float:
        best = min(r.latency_ms for r in self.baselines.values()
                   if not failed(r))
        return best / self.solution.result.latency_ms

    def summary(self) -> str:
        rows = [f"objective={self.solution.kind} "
                f"optimal={self.solution.optimal}"]
        for name, res in self.baselines.items():
            if failed(res):
                rows.append(f"  {name:18s} infeasible: "
                            f"{res['error']['message']}")
            else:
                rows.append(f"  {name:18s} lat={res.latency_ms:9.3f}ms "
                            f"fps={res.throughput_fps:8.1f}")
        sol = self.solution
        rows.append(f"  {'haxconn':18s} lat={sol.result.latency_ms:9.3f}ms "
                    f"fps={sol.result.throughput_fps:8.1f} "
                    f"({100 * (self.speedup_vs_best_baseline - 1):+.1f}%)")
        for wl in sol.workloads:
            trans = [f"{wl.assignment[i]}->{wl.assignment[i + 1]}@{i}"
                     for i in range(len(wl.assignment) - 1)
                     if wl.assignment[i] != wl.assignment[i + 1]]
            rows.append(f"    {wl.graph.name}: {trans or ['no transition']}")
        return "\n".join(rows)


def plan_concurrent_serving(
    cfgs: Sequence[ModelConfig],
    cells: Sequence[str | ShapeCell],
    platform: Platform | None = None,
    objective: str = "latency",
    iterations: Sequence[int] | None = None,
    deadline_s: float = 20.0,
    scheduler: Scheduler | None = None,
) -> ServingPlan:
    """Schedule concurrent inference of several models on a split pod."""
    sched = scheduler or Scheduler(platform or tpu_pod_split())
    plat = sched.platform
    graphs = []
    for cfg, cell in zip(cfgs, cells):
        cell = SHAPES[cell] if isinstance(cell, str) else cell
        graphs.append(export_graph(cfg, cell, plat))
    rows = sched.compare(graphs, objective, max_transitions=2,
                         iterations=iterations, deadline_s=deadline_s)
    plan = rows.pop("haxconn")
    if failed(plan):
        raise RuntimeError(f"no schedule found: {plan['error']['message']}")
    return ServingPlan(graphs, plan.solution, rows, plat, plan=plan)


# ---------------------------------------------------------------------------
# CPU-executable co-serving demo (reduced configs, real compute + sim time)
# ---------------------------------------------------------------------------

@dataclass
class CoServer:
    """Executes scheduled rounds of two (reduced) models for real while
    advancing a simulated clock from the plan's exact timeline."""

    models: list[Model]
    params: list
    plan: ServingPlan
    sim_time_ms: float = 0.0
    rounds: int = 0
    _fwd: list = field(default_factory=list)

    def __post_init__(self):
        self._fwd = [jax.jit(m.forward) for m in self.models]

    def run_round(self, batches) -> list[jnp.ndarray]:
        outs = []
        for fwd, params, batch in zip(self._fwd, self.params, batches):
            logits, _ = fwd(params, batch)
            outs.append(logits)
        self.sim_time_ms += self.plan.solution.result.makespan
        self.rounds += 1
        return outs

    @property
    def simulated_fps(self) -> float:
        per_round = sum(len(w.graph.groups) and 1
                        for w in self.plan.solution.workloads)
        return 1e3 * self.rounds * per_round / max(self.sim_time_ms, 1e-9)

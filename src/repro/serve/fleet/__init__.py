"""Fleet-scale serving: trace-driven traffic, SLO-aware multiplexing.

The fleet subsystem scales the serving stack from "a handful of tenants,
one schedule" (:mod:`repro.serve.gateway`) to "thousands of open-loop
tenants over a small pool of solved SoC plans":

* :mod:`~repro.serve.fleet.traffic` — seeded, bit-deterministic arrival
  traces (Poisson / bursty MMPP / diurnal replay) with a JSON wire format.
* :mod:`~repro.serve.fleet.slo` — per-tenant SLO targets driving
  admission, shedding and plan selection through one shared
  :class:`AdmissionController`.
* :mod:`~repro.serve.fleet.loop` — the virtual-time fleet gateway:
  per-tenant queues, KV-budget admission, earliest-finish SLO routing vs
  round-robin, per-plan §4.4 slowdown monitoring, closed-loop online
  recalibration (streamed telemetry → PCCS re-fit → model adoption) with
  per-tenant duty-cycle throttling as the fallback mitigation, an asyncio
  front-end, and flat-array per-request telemetry (:class:`FleetReport`).
"""
from repro.serve.fleet.loop import (FleetConfig, FleetGateway, FleetReport,
                                    FleetRescheduleEvent, PoolPlan,
                                    build_pool, serve_async)
from repro.serve.fleet.slo import (SLO, AdmissionController, TenantThrottle,
                                   parse_slo)
from repro.serve.fleet.traffic import (ArrivalTrace, GENERATORS,
                                       bursty_trace, diurnal_trace,
                                       parse_trace_spec, poisson_trace)

__all__ = [
    "ArrivalTrace", "GENERATORS", "bursty_trace", "diurnal_trace",
    "parse_trace_spec", "poisson_trace",
    "SLO", "AdmissionController", "TenantThrottle", "parse_slo",
    "FleetConfig", "FleetGateway", "FleetReport", "FleetRescheduleEvent",
    "PoolPlan", "build_pool", "serve_async",
]

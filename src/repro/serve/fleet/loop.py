"""Fleet gateway front-end: multiplex thousands of tenants over a small
pool of contention-aware SoC plans.

The existing :class:`~repro.serve.gateway.MultiTenantGateway` steps a
handful of tenants synchronously — one engine per tenant, real compute.
A fleet control plane faces the opposite shape: *hundreds to thousands*
of open-loop tenants, a *small* pool of solved SoC plans (one per device
split / placement the solver produced), and the questions that matter are
queueing, admission and tail latency, not token values.  This module is
that front-end:

* :class:`PoolPlan` — one solved multi-tenant schedule
  (:func:`~repro.serve.gateway.plan_gateway` product) promoted to a fleet
  serving unit: per-tenant-class predicted decode-step latencies, a slot
  count, KV bytes per request, and the :class:`~repro.core.Scheduler`
  that owns its plan cache (re-solves route through it, so §4.4
  re-schedules are cached/persisted like offline solves).
* :class:`FleetGateway` — a deterministic virtual-time event machine:
  arrivals drain into per-tenant queues, the
  :class:`~repro.serve.fleet.slo.AdmissionController` decides
  shed/admit/defer and routes each request to a pool plan (SLO-aware
  earliest-finish or static round-robin), plan slots serve requests with
  the schedule-predicted service times, and per-request
  queueing/service/slowdown telemetry is recorded in flat arrays.
  Replaying a million-request :class:`~repro.serve.fleet.traffic.
  ArrivalTrace` is a tight Python/heapq loop — no real compute, bit-
  deterministic, fast enough for CI.
* **§4.4 in the fleet loop** — per-plan
  :class:`~repro.core.dynamic.SlowdownMonitor` watches observed step
  latency against the plan's steady-state floor; external contention
  (injected via ``contention_events``) fires the monitor, and the gateway
  re-solves that pool plan under the observed severity
  (:func:`~repro.core.dynamic.reschedule_plan`), adopting the new
  assignment only when it genuinely improves the scaled-model objective.
* **Closed-loop recalibration** — pass a
  :class:`~repro.profiling.online.StreamingRecalibrator` and every
  completion under external demand feeds an ``(own, ext, slowdown)``
  telemetry sample into it; each monitor firing first steps the
  recalibrator, and a published re-fit is adopted into *every* pool
  plan's scheduler before the re-solve, so the §4.4 response prices
  contention against the live surface instead of the stale offline one.
  When re-solving under the re-fitted model still cannot meet a tenant's
  SLO, the tenant is duty-cycled
  (:class:`~repro.serve.fleet.slo.TenantThrottle` +
  ``AdmissionController.duty_admit``) until its miss rate recovers —
  re-solve first, shed load second.
* :func:`serve_async` — an ``asyncio`` front-end over the same machine:
  submissions become awaitable completions, arrivals are paced in wall
  time (``time_scale``), so an interactive service and the virtual-time
  replay share one implementation.

Wall-clock time never enters the model: the clock is the trace's, service
times are the solved schedule's predictions, and a replay is reproducible
bit-for-bit from ``(trace, pool, config)``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Mapping, Sequence

import numpy as np

from repro.core.dynamic import (ScaledContentionModel, SlowdownMonitor,
                                quantize_severity, reschedule_plan)
from repro.core.scheduler import Scheduler
from repro.core.simulate import simulate
from repro.core.solver_bb import Solution
from repro.obs import (GATEWAY_SCHEMA, TENANT_SCHEMA, conform, get_logger,
                       get_tracer)
from repro.serve.gateway import (GatewayConfig, GatewayPlan, TenantSpec,
                                 plan_gateway)
from repro.serve.fleet.slo import SLO, AdmissionController, TenantThrottle
from repro.serve.fleet.traffic import ArrivalTrace

log = get_logger(__name__)

if TYPE_CHECKING:  # pragma: no cover - annotation only (avoids jax import)
    from repro.profiling.online import StreamingRecalibrator

# request status codes (FleetReport.status)
PENDING, RUNNING, DONE, SHED, THROTTLED = 0, 1, 2, 3, 4

#: a contention oracle maps ``(pool_plan, ext_demand)`` to the true
#: per-class severity factors — benchmark harnesses wrap the generating
#: model here so injected *demand* is priced through ground truth while
#: the gateway's own model may have drifted away from it.
ContentionOracle = Callable[["PoolPlan", float], "float | np.ndarray"]


# ---------------------------------------------------------------------------
# PoolPlan
# ---------------------------------------------------------------------------

@dataclass
class PoolPlan:
    """One solved SoC schedule serving a share of the fleet."""

    name: str
    plan: GatewayPlan
    scheduler: Scheduler
    #: concurrent requests this plan serves (the schedule's batch width).
    slots: int
    #: tenant-class names, index-aligned with the step/kv arrays.
    classes: tuple[str, ...] = field(init=False)
    #: current predicted decode-step ms per class (includes any applied
    #: contention severity; the number the loop bills service time from).
    step_ms: np.ndarray = field(init=False)
    #: steady-state floor per class (factor 1.0) — the §4.4 baseline.
    base_step_ms: np.ndarray = field(init=False)
    #: KV bytes one in-flight request pins, per class.
    kv_bytes: np.ndarray = field(init=False)
    #: mean shared-memory demand of each class's decode groups on their
    #: assigned accelerators — the ``own`` coordinate of the telemetry
    #: samples the online recalibrator consumes.
    class_demand: np.ndarray = field(init=False)
    #: external contention severity currently applied per class (1 = none).
    factor_per_class: np.ndarray = field(init=False)
    #: scalar view of the applied severity (mean over classes) — the §4.4
    #: deviation signal and the back-compat knob for scalar callers.
    factor: float = 1.0

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        self.classes = tuple(s.name for s in self.plan.specs)
        self.base_step_ms = np.array(
            [self.plan.predicted_decode_step_ms(c) for c in self.classes])
        if np.any(self.base_step_ms <= 0.0):
            raise ValueError(
                f"pool plan {self.name!r}: non-positive predicted decode "
                f"step — the schedule cannot price service time")
        self.step_ms = self.base_step_ms.copy()
        self.kv_bytes = np.array(
            [float(s.kv_bytes_per_slot) for s in self.plan.specs])
        self.factor_per_class = np.ones(len(self.classes))
        self.class_demand = self._class_demand()

    def service_ms(self, cls: int, max_new: int) -> float:
        """Predicted service time of one request (decode macro steps)."""
        return float(self.step_ms[cls]) * max_new

    # -- §4.4 surface ------------------------------------------------------
    def _steps_under(self, solution: Solution) -> np.ndarray:
        view = dataclasses.replace(self.plan, solution=solution)
        return np.array(
            [view.predicted_decode_step_ms(c) for c in self.classes])

    def _class_demand(self) -> np.ndarray:
        """Mean decode-group memory demand per class under the current
        assignment (fraction of shared-domain capacity)."""
        out = np.zeros(len(self.classes))
        for j, (cls, graph) in enumerate(zip(self.classes,
                                             self.plan.graphs)):
            npf = self.plan.n_prefill_groups[cls]
            asg = self.plan.assignment_of(cls)
            dem = [graph.groups[g].demand_on(asg[g])
                   for g in range(npf, len(graph))]
            out[j] = float(np.mean(dem)) if dem else 0.0
        return out

    def apply_factor(self, factor: "float | np.ndarray") -> None:
        """Apply external contention severity ``factor`` (1.0 = none).

        Models a co-runner the schedule did not plan for — another
        workload on the SoC saturating the shared-memory domains — which
        slows every group on this plan multiplicatively.  A scalar slows
        all classes uniformly; a per-class array (a contention oracle's
        output) prices each class at its own severity.  Observed step
        latency becomes ``base * factor``, which is exactly the deviation
        signal the §4.4 :class:`SlowdownMonitor` consumes; the response
        (:meth:`reschedule`) re-solves under a contention model rescaled
        to the observed severity.
        """
        vec = np.broadcast_to(np.asarray(factor, dtype=float),
                              (len(self.classes),)).copy()
        if np.any(vec <= 0.0):
            raise ValueError("contention factor must be > 0")
        self.factor_per_class = vec
        self.factor = float(vec.mean())
        self.step_ms = self.base_step_ms * vec

    def adopt_model(self, model, *, objective: str = "throughput") -> None:
        """Swap the scheduler's contention model for a re-fitted one.

        The closed loop calls this when the online recalibrator publishes:
        future re-solves price contention against the live surface, and
        the steady-state floor (``base_step_ms``) is re-simulated under it
        so the §4.4 monitor's deviation baseline tracks the new model.
        The applied external severity carries over unchanged.
        """
        self.scheduler.model = model
        sol = self.plan.solution
        res = simulate(self.plan.platform, sol.workloads, model,
                       record_timeline=True)
        new = Solution(sol.workloads, res, res.objective(objective),
                       sol.kind, sol.evaluated, False)
        self.plan = dataclasses.replace(self.plan, solution=new)
        self.base_step_ms = self._steps_under(new)
        self.apply_factor(self.factor_per_class)

    def reschedule(self, observed_factor: float, *, objective: str,
                   max_transitions: int, budget_s: float) -> tuple[bool, float, float]:
        """§4.4 re-solve under the observed severity; adopt only if better.

        Returns ``(changed, old_objective, new_objective)`` — both priced
        under the same scaled model, exactly like
        ``MultiTenantGateway._reschedule``.
        """
        factor = quantize_severity(observed_factor)
        model = ScaledContentionModel(self.scheduler.model, factor)
        old = self.plan.solution
        cur_res = simulate(self.plan.platform, old.workloads, model,
                           record_timeline=True)
        cur_obj = cur_res.objective(objective)
        rplan = reschedule_plan(
            self.scheduler, self.plan.graphs, factor, objective=objective,
            max_transitions=max_transitions,
            iterations=self.plan.iterations, budget_s=budget_s)
        best = rplan.solution
        if best.objective < cur_obj - 1e-9:
            res = simulate(self.plan.platform, best.workloads, model,
                           record_timeline=True)
            new = Solution(best.workloads, res, best.objective, best.kind,
                           best.evaluated, best.optimal)
            art = rplan
        else:
            new = Solution(old.workloads, cur_res, cur_obj, old.kind,
                           best.evaluated, False)
            art = self.plan.plan
        changed = new.assignments != old.assignments
        self.plan = dataclasses.replace(self.plan, solution=new, plan=art)
        # steady-state floor follows the adopted assignment; current step
        # table prices it at the live severity.
        base_model = self.scheduler.model
        base_res = simulate(self.plan.platform, new.workloads, base_model,
                            record_timeline=True)
        self.base_step_ms = self._steps_under(
            Solution(new.workloads, base_res,
                     base_res.objective(objective), new.kind,
                     new.evaluated, False))
        self.class_demand = self._class_demand()
        self.apply_factor(self.factor_per_class)
        return changed, cur_obj, new.objective


def build_pool(specs: Sequence[TenantSpec],
               platforms: Sequence,
               gcfg: GatewayConfig | None = None,
               cache=None, *, slots: int | None = None,
               deadline_s: float | None = 20.0) -> list[PoolPlan]:
    """Solve one :class:`PoolPlan` per platform (pod split / SoC).

    All schedulers share ``cache`` — point it at a
    :class:`~repro.core.plan.ShardedPlanCache` root and a later
    ``build_pool`` over the same platforms boots every plan from disk
    with zero solver invocations (each plan is one O(load-a-JSON) read;
    shards keep concurrent control planes from contending on one index).
    """
    pool = []
    for plat in platforms:
        cfg = dataclasses.replace(gcfg or GatewayConfig(), platform=plat)
        sched = Scheduler(cfg.platform, cfg.model, cache=cache)
        gwplan = plan_gateway(specs, cfg, deadline_s=deadline_s,
                              scheduler=sched)
        pool.append(PoolPlan(
            name=getattr(plat, "name", str(plat)), plan=gwplan,
            scheduler=sched,
            slots=slots or sum(s.max_slots for s in specs)))
    return pool


# ---------------------------------------------------------------------------
# FleetGateway
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetConfig:
    """Knobs of the fleet loop (routing + admission + §4.4)."""

    #: "slo" = earliest-predicted-finish routing; "round_robin" = static
    #: tenant-hash placement (the baseline the benchmark compares against).
    policy: str = "slo"
    default_slo: SLO = SLO(p99_ms=1000.0)
    #: fleet-wide KV budget (bytes); None disables memory admission.
    memory_budget_bytes: float | None = None
    max_queue_per_tenant: int = 64
    shed_factor: float = 4.0
    objective: str = "throughput"
    max_transitions: int = 2
    # ---- §4.4 knobs (per pool plan) ----
    slowdown_threshold: float = 1.5
    patience: int = 8
    cooldown: int = 256
    warmup: int = 0
    reschedule_budget_s: float = 0.25
    # ---- throttle knobs (second control axis; see slo.TenantThrottle) ----
    #: enable per-tenant duty-cycling of SLO-violating tenants.  Only
    #: engages after at least one §4.4 re-solve — re-solve first, shed
    #: load second.
    throttle: bool = False
    #: fraction of a throttled tenant's arrivals that are still admitted.
    throttle_duty: float = 0.5
    throttle_enter: float = 0.5
    throttle_exit: float = 0.1
    throttle_patience: int = 8
    #: prediction headroom: at reschedule time a tenant is throttled when
    #: its predicted finish (best-plan queueing + service) exceeds
    #: ``throttle_margin * p99_ms`` — engaging at a fraction of the budget
    #: drains the backlog *before* deadlines start blowing.
    throttle_margin: float = 0.5

    def __post_init__(self):
        if self.policy not in ("slo", "round_robin"):
            raise ValueError(
                f"unknown policy {self.policy!r} (slo | round_robin)")
        if not 0.0 < self.throttle_duty < 1.0:
            raise ValueError("throttle_duty must be in (0, 1)")


@dataclass
class FleetRescheduleEvent:
    t_ms: float
    plan: str
    observed_factor: float
    old_objective: float
    new_objective: float
    changed: bool


class _Records:
    """Flat per-request telemetry, growable (asyncio path) but usually
    preallocated to the trace length (replay path)."""

    __slots__ = ("n", "tenant", "cls", "plan", "t_arrive", "t_start",
                 "t_end", "service_ms", "est_ms", "max_new", "status",
                 "ext", "floor_ms")

    def __init__(self, capacity: int):
        capacity = max(16, capacity)
        self.n = 0
        self.tenant = np.zeros(capacity, np.int32)
        self.cls = np.zeros(capacity, np.int16)
        self.plan = np.full(capacity, -1, np.int16)
        self.t_arrive = np.zeros(capacity, np.float64)
        self.t_start = np.full(capacity, np.nan)
        self.t_end = np.full(capacity, np.nan)
        self.service_ms = np.zeros(capacity, np.float64)
        self.est_ms = np.zeros(capacity, np.float64)
        self.max_new = np.zeros(capacity, np.int32)
        self.status = np.zeros(capacity, np.int8)
        # telemetry basis captured at service *start* (demand and floor can
        # both move while a request is in flight; attributing the observed
        # slowdown to completion-time state would poison the re-fit window).
        self.ext = np.zeros(capacity, np.float64)
        self.floor_ms = np.zeros(capacity, np.float64)

    def append(self, tenant: int, cls: int, t: float, max_new: int) -> int:
        if self.n == len(self.tenant):
            for name in self.__slots__[1:]:
                arr = getattr(self, name)
                grown = np.empty(2 * len(arr), arr.dtype)
                grown[:len(arr)] = arr
                setattr(self, name, grown)
        i = self.n
        self.tenant[i] = tenant
        self.cls[i] = cls
        self.t_arrive[i] = t
        self.max_new[i] = max_new
        self.plan[i] = -1
        self.t_start[i] = np.nan
        self.t_end[i] = np.nan
        self.service_ms[i] = 0.0
        self.est_ms[i] = 0.0
        self.status[i] = PENDING
        self.ext[i] = 0.0
        self.floor_ms[i] = 0.0
        self.n += 1
        return i


@dataclass
class FleetReport:
    """Per-request telemetry + aggregates of one replay."""

    n_tenants: int
    classes: tuple[str, ...]
    policy: str
    tenant: np.ndarray
    cls: np.ndarray
    plan: np.ndarray
    t_arrive: np.ndarray
    t_start: np.ndarray
    t_end: np.ndarray
    service_ms: np.ndarray
    max_new: np.ndarray
    status: np.ndarray
    reschedules: list[FleetRescheduleEvent]
    shed: int
    deferred: int
    slos: Mapping[int, SLO]
    default_slo: SLO
    #: (t_ms, bundle_hash, max_rel_err) per published online re-fit.
    recalibrations: list = field(default_factory=list)
    #: (t_ms, tenant, "throttle" | "release") duty-cycle switches.
    throttle_events: list = field(default_factory=list)
    #: arrivals refused by the duty gate (status THROTTLED).
    throttled: int = 0
    #: pool-plan names, index-aligned with the ``plan`` column (trace
    #: export track labels); empty for pre-obs reports.
    plan_names: tuple = ()

    # -- derived -----------------------------------------------------------
    @property
    def n_requests(self) -> int:
        return len(self.tenant)

    @property
    def completed(self) -> int:
        return int(np.sum(self.status == DONE))

    @property
    def done_mask(self) -> np.ndarray:
        return self.status == DONE

    @property
    def latency_ms(self) -> np.ndarray:
        """End-to-end latency of completed requests (queueing + service)."""
        m = self.done_mask
        return self.t_end[m] - self.t_arrive[m]

    @property
    def wait_ms(self) -> np.ndarray:
        m = self.done_mask
        return self.t_start[m] - self.t_arrive[m]

    @property
    def slowdown(self) -> np.ndarray:
        """Latency / pure-service ratio per completed request (>= 1)."""
        m = self.done_mask
        return (self.t_end[m] - self.t_arrive[m]) / self.service_ms[m]

    def percentile(self, q: float) -> float:
        lat = self.latency_ms
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    @property
    def p50_ms(self) -> float:
        return self.percentile(50.0)

    @property
    def p99_ms(self) -> float:
        return self.percentile(99.0)

    @property
    def makespan_ms(self) -> float:
        ends = self.t_end[self.done_mask]
        if not len(ends):
            return 0.0
        return float(ends.max() - self.t_arrive.min())

    @property
    def sustained_rps(self) -> float:
        mk = self.makespan_ms
        return 1e3 * self.completed / mk if mk > 0.0 else 0.0

    # -- SLO accounting ----------------------------------------------------
    def _slo_for(self, tenant: int) -> SLO:
        return self.slos.get(tenant, self.default_slo)

    def slo_report(self) -> dict:
        """Per-tenant p99 / completion rate vs target, aggregated.

        A tenant violates when its observed p99 exceeds its budget or its
        completion throughput (over the trace span) undershoots its floor.
        """
        m = self.done_mask
        lat = self.t_end[m] - self.t_arrive[m]
        ten = self.tenant[m]
        span_s = self.makespan_ms / 1e3
        order = np.argsort(ten, kind="stable")
        ten_sorted, lat_sorted = ten[order], lat[order]
        bounds = np.searchsorted(ten_sorted,
                                 np.arange(self.n_tenants + 1))
        p99_violations = throughput_violations = served_tenants = 0
        for t in range(self.n_tenants):
            lo, hi = bounds[t], bounds[t + 1]
            if hi == lo:
                continue
            served_tenants += 1
            slo = self._slo_for(t)
            if float(np.percentile(lat_sorted[lo:hi], 99.0)) > slo.p99_ms:
                p99_violations += 1
            if (slo.throughput_rps > 0.0 and span_s > 0.0
                    and (hi - lo) / span_s < slo.throughput_rps):
                throughput_violations += 1
        return {"served_tenants": served_tenants,
                "p99_violations": p99_violations,
                "throughput_violations": throughput_violations,
                "shed": self.shed, "throttled": self.throttled}

    def tenant_metrics(self, tenant: int) -> dict:
        """One tenant's telemetry in the canonical
        :data:`~repro.serve.engine.METRIC_KEYS` shape."""
        mine = self.tenant == tenant
        done = mine & self.done_mask
        running = mine & (self.status == RUNNING)
        queued = mine & (self.status == PENDING)
        steps = int(self.max_new[done].sum())
        svc = self.service_ms[done]
        per_step = (svc / self.max_new[done]) if len(svc) else np.array([])
        return conform(TENANT_SCHEMA, {
            "steps": steps,
            "active": int(running.sum()),
            "queue_depth": int(queued.sum()),
            "admitted": int(mine.sum())
            - int((self.status[mine] == SHED).sum())
            - int((self.status[mine] == THROTTLED).sum()),
            "completed": int(done.sum()),
            "deferred": 0,      # deferral is fleet-global (KV budget)
            "tokens_out": steps,
            "last_step_ms": float(per_step[-1]) if len(per_step) else 0.0,
            "mean_step_ms": float(per_step.mean()) if len(per_step) else 0.0,
        })

    # -- trace export ------------------------------------------------------
    def trace_events(self, max_requests: int | None = 50_000,
                     track_id: Callable[[str], int] | None = None
                     ) -> list[dict]:
        """Chrome trace events derived post hoc from the record arrays.

        One queue span (arrival -> service start) and one service span
        (start -> end) per completed request, on the owning pool plan's
        track — derived in bulk from the flat NumPy columns, never
        recorded live, so the replay hot loop stays untouched.

        ``track_id`` maps a track name to a tid (pass
        ``Tracer.track_id`` when ingesting via ``Tracer.add_events`` so
        tids share the tracer's registry and its ``thread_name``
        metadata covers them); without it the events are standalone and
        carry their own metadata records.  At most ``max_requests``
        requests are exported (``None`` = all); truncation is logged
        and visible in the event count, never silent.
        """
        idx = np.flatnonzero(self.status == DONE)
        total = len(idx)
        if max_requests is not None and total > max_requests:
            log.info("trace export truncated to the first %d of %d "
                     "completed requests", max_requests, total)
            idx = idx[:max_requests]
        names = self.plan_names or tuple(
            f"plan{p}" for p in range(int(self.plan.max(initial=-1)) + 1))
        events: list[dict] = []
        if track_id is None:
            tids = {nm: 2 * p + 1 for p, nm in enumerate(names)}
            tids.update({f"{nm}/queue": 2 * p + 2
                         for p, nm in enumerate(names)})
            events += [{"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": t, "args": {"name": nm}}
                       for nm, t in tids.items()]
            track_id = tids.__getitem__
        svc_tid = [track_id(nm) for nm in names]
        q_tid = [track_id(f"{nm}/queue") for nm in names]
        plan = self.plan[idx]
        tenant = self.tenant[idx]
        cls = self.cls[idx]
        ts_q = np.round(self.t_arrive[idx] * 1e3, 3)
        start = self.t_start[idx]
        dur_q = np.round((start - self.t_arrive[idx]) * 1e3, 3)
        ts_s = np.round(start * 1e3, 3)
        dur_s = np.round((self.t_end[idx] - start) * 1e3, 3)
        cls_names = self.classes
        for j in range(len(idx)):
            p = int(plan[j])
            name = cls_names[int(cls[j])] if cls_names else str(int(cls[j]))
            t = int(tenant[j])
            if dur_q[j] > 0.0:
                events.append({
                    "ph": "X", "name": f"queue:{name}", "cat": "queue",
                    "ts": float(ts_q[j]), "dur": float(dur_q[j]),
                    "pid": 1, "tid": q_tid[p], "args": {"tenant": t}})
            events.append({
                "ph": "X", "name": name, "cat": "service",
                "ts": float(ts_s[j]), "dur": float(dur_s[j]),
                "pid": 1, "tid": svc_tid[p],
                "args": {"tenant": t, "wait_ms": float(dur_q[j])}})
        return events

    def summary(self) -> str:
        slo = self.slo_report()
        rows = [
            f"fleet[{self.policy}] requests={self.n_requests} "
            f"completed={self.completed} shed={self.shed} "
            f"deferred={self.deferred}",
            f"  latency p50={self.p50_ms:.1f}ms p99={self.p99_ms:.1f}ms "
            f"sustained={self.sustained_rps:.1f} req/s",
            f"  slo: {slo['p99_violations']}/{slo['served_tenants']} "
            f"tenants over p99 budget, "
            f"{slo['throughput_violations']} under throughput floor",
            f"  reschedules={len(self.reschedules)} "
            f"recalibrations={len(self.recalibrations)} "
            f"throttled={self.throttled}",
        ]
        return "\n".join(rows)


class FleetGateway:
    """Virtual-time multiplexer of an open-loop fleet over a plan pool.

    Deterministic by construction: no RNG, no wall clock — identical
    ``(pool, config, trace, contention_events)`` replay identically.
    """

    def __init__(self, pool: Sequence[PoolPlan], n_tenants: int,
                 cfg: FleetConfig = FleetConfig(),
                 slos: Mapping[int, SLO] | None = None,
                 capacity_hint: int = 0, *,
                 recalibrator: "StreamingRecalibrator | None" = None,
                 contention_oracle: ContentionOracle | None = None):
        if not pool:
            raise ValueError("pool must hold at least one PoolPlan")
        classes = pool[0].classes
        for pp in pool:
            if pp.classes != classes:
                raise ValueError(
                    f"pool plans serve different tenant-class sets: "
                    f"{pp.classes} != {classes}")
        if n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        self.pool = list(pool)
        self.classes = classes
        self.n_tenants = n_tenants
        self.cfg = cfg
        self.controller = AdmissionController(
            budget_bytes=cfg.memory_budget_bytes,
            default_slo=cfg.default_slo, slos=slos,
            max_queue_per_tenant=cfg.max_queue_per_tenant,
            shed_factor=cfg.shed_factor)
        self.monitors = [
            SlowdownMonitor(threshold=cfg.slowdown_threshold,
                            patience=cfg.patience, cooldown=cfg.cooldown,
                            warmup=cfg.warmup)
            for _ in pool]
        self.reschedules: list[FleetRescheduleEvent] = []
        # closed-loop recalibration + throttling state
        self.recalibrator = recalibrator
        self.contention_oracle = contention_oracle
        self.recalibrations: list[tuple[float, str, float]] = []
        self.throttle_events: list[tuple[float, int, str]] = []
        self._throttles: dict[int, TenantThrottle] = {}
        #: external antagonist demand currently applied per plan (the
        #: ``ext`` coordinate of recalibration telemetry; 0 = none known).
        self._ext_demand = [0.0] * len(pool)
        # runtime state
        self._rec = _Records(capacity_hint)
        self._now = 0.0
        self._seq = 0
        self._heap: list[tuple[float, int, int]] = []      # (end, seq, req)
        self._free_slots = [pp.slots for pp in self.pool]
        #: per-plan FIFO of queued request indices (drained into slots).
        self._plan_q: list[deque[int]] = [deque() for _ in self.pool]
        #: per-plan outstanding predicted work (ms) — the routing signal.
        self._load_ms = np.zeros(len(self.pool))
        #: per-tenant queued-request depth (admission signal).
        self._tenant_depth = np.zeros(n_tenants, np.int32)
        #: asyncio futures resolved at completion (serve_async only).
        self._futures: dict[int, asyncio.Future] = {}

    # -- class mapping -----------------------------------------------------
    def class_of(self, tenant: int) -> int:
        return tenant % len(self.classes)

    @property
    def now_ms(self) -> float:
        return self._now

    # -- arrivals ----------------------------------------------------------
    def submit(self, t_ms: float, tenant: int, max_new: int) -> int:
        """One open-loop arrival at virtual time ``t_ms``.

        Returns the request index, or -1 when the request was shed.
        Arrival times must be non-decreasing (the trace invariant).
        """
        self.advance(t_ms)
        if not 0 <= tenant < self.n_tenants:
            raise ValueError(f"tenant {tenant} out of range")
        cls = self.class_of(tenant)
        if not self.controller.duty_admit(tenant):
            i = self._rec.append(tenant, cls, t_ms, max_new)
            self._rec.status[i] = THROTTLED
            self._resolve_future(i)
            return -1
        waits = [self._load_ms[p] / self.pool[p].slots
                 for p in range(len(self.pool))]
        if self.controller.should_shed(
                tenant, int(self._tenant_depth[tenant]), min(waits)):
            i = self._rec.append(tenant, cls, t_ms, max_new)
            self._rec.status[i] = SHED
            self._resolve_future(i)
            return -1
        if self.cfg.policy == "round_robin":
            p = tenant % len(self.pool)
        else:
            services = [pp.service_ms(cls, max_new) for pp in self.pool]
            p = self.controller.select_plan(waits, services)
        i = self._rec.append(tenant, cls, t_ms, max_new)
        self._rec.plan[i] = p
        est = self.pool[p].service_ms(cls, max_new)
        self._rec.est_ms[i] = est
        self._load_ms[p] += est
        self._tenant_depth[tenant] += 1
        self._plan_q[p].append(i)
        self._try_start(p)
        return i

    # -- event machine -----------------------------------------------------
    def advance(self, t_ms: float) -> None:
        """Process completions up to virtual time ``t_ms``."""
        if t_ms < self._now - 1e-9:
            raise ValueError(
                f"time went backwards: {t_ms} < {self._now}")
        heap = self._heap
        while heap and heap[0][0] <= t_ms:
            end, _, i = heapq.heappop(heap)
            self._now = max(self._now, end)
            self._complete(i, end)
        self._now = max(self._now, t_ms)

    def drain(self) -> None:
        """Run the clock forward until every admitted request completed."""
        while self._heap:
            end, _, i = heapq.heappop(self._heap)
            self._now = max(self._now, end)
            self._complete(i, end)

    def _try_start(self, p: int) -> None:
        pp = self.pool[p]
        q = self._plan_q[p]
        while q and self._free_slots[p] > 0:
            i = q[0]
            cls = int(self._rec.cls[i])
            if not self.controller.try_acquire(float(pp.kv_bytes[cls])):
                break                         # deferred: retried on frees
            q.popleft()
            self._free_slots[p] -= 1
            self._tenant_depth[self._rec.tenant[i]] -= 1
            service = pp.service_ms(cls, int(self._rec.max_new[i]))
            start = max(self._now, float(self._rec.t_arrive[i]))
            self._rec.t_start[i] = start
            self._rec.service_ms[i] = service
            self._rec.ext[i] = self._ext_demand[p]
            self._rec.floor_ms[i] = float(pp.base_step_ms[cls])
            self._rec.t_end[i] = start + service
            self._rec.status[i] = RUNNING
            self._seq += 1
            heapq.heappush(self._heap, (start + service, self._seq, i))

    def _complete(self, i: int, end: float) -> None:
        p = int(self._rec.plan[i])
        cls = int(self._rec.cls[i])
        pp = self.pool[p]
        self._rec.status[i] = DONE
        self._free_slots[p] += 1
        self._load_ms[p] = max(0.0, self._load_ms[p] - self._rec.est_ms[i])
        self.controller.release(float(pp.kv_bytes[cls]))
        self._resolve_future(i)
        # §4.4: observed per-step latency vs the steady-state floor.
        observed = self._rec.service_ms[i] / max(1, self._rec.max_new[i])
        floor = float(pp.base_step_ms[cls])
        # closed loop, axis 1: stream (own, ext, slowdown) telemetry into
        # the recalibrator whenever external demand is known — priced
        # against the demand/floor in effect when service *started*.
        ext = float(self._rec.ext[i])
        floor_at_start = float(self._rec.floor_ms[i])
        if (self.recalibrator is not None and ext > 0.0
                and floor_at_start > 0.0):
            self.recalibrator.observe(float(pp.class_demand[cls]), ext,
                                      observed / floor_at_start)
        # closed loop, axis 2: duty-cycle tenants whose SLOs keep missing
        # *after* re-solving had its chance (gate on a past reschedule).
        if self.cfg.throttle and self.reschedules:
            tenant = int(self._rec.tenant[i])
            slo = self.controller.slo_for(tenant)
            missed = (end - float(self._rec.t_arrive[i])) > slo.p99_ms
            th = self._throttles.get(tenant)
            if th is None:
                th = self._throttles[tenant] = TenantThrottle(
                    enter_miss_rate=self.cfg.throttle_enter,
                    exit_miss_rate=self.cfg.throttle_exit,
                    patience=self.cfg.throttle_patience)
            hold = th.throttled and self._pressure() >= \
                self.cfg.slowdown_threshold
            action = th.observe(missed, hold=hold)
            if action == "throttle":
                self.controller.set_duty(tenant, self.cfg.throttle_duty)
                self.throttle_events.append((end, tenant, action))
                get_tracer().instant("fleet.throttle", "dynamic",
                                     ts_ms=end, track="fleet",
                                     tenant=tenant,
                                     duty=self.cfg.throttle_duty)
            elif action == "release":
                self.controller.set_duty(tenant, 1.0)
                self.throttle_events.append((end, tenant, action))
                get_tracer().instant("fleet.release", "dynamic",
                                     ts_ms=end, track="fleet",
                                     tenant=tenant)
        if self.monitors[p].observe(observed, floor):
            self._reschedule(p, end)
        # a freed slot (or KV budget) may unblock any plan's queue.
        for other in range(len(self.pool)):
            if self._plan_q[other] and self._free_slots[other] > 0:
                self._try_start(other)

    def _reschedule(self, p: int, t_ms: float) -> None:
        pp = self.pool[p]
        # the re-fit runs *before* the re-solve: a published bundle is
        # adopted into every pool plan's scheduler, so the §4.4 response
        # below prices contention against the live surface.
        if self.recalibrator is not None:
            published = self.recalibrator.step()
            if published is not None:
                err = (self.recalibrator.events[-1].max_rel_err
                       if self.recalibrator.events else float("nan"))
                self.recalibrations.append(
                    (t_ms, published.bundle_hash(), err))
                get_tracer().instant(
                    "fleet.recalibration", "recalibrate", ts_ms=t_ms,
                    track="fleet", bundle=published.bundle_hash()[:12],
                    max_rel_err=round(err, 6))
                for other in self.pool:
                    other.adopt_model(published.model,
                                      objective=self.cfg.objective)
        factor = quantize_severity(self.monitors[p].ratio)
        changed, old_obj, new_obj = pp.reschedule(
            factor, objective=self.cfg.objective,
            max_transitions=self.cfg.max_transitions,
            budget_s=self.cfg.reschedule_budget_s)
        self.reschedules.append(FleetRescheduleEvent(
            t_ms, pp.name, factor, old_obj, new_obj, changed))
        get_tracer().instant("fleet.reschedule", "dynamic", ts_ms=t_ms,
                             track="fleet", plan=pp.name, factor=factor,
                             changed=changed)
        self.monitors[p].reset()
        # a changed assignment moves class demand; re-price the injected
        # antagonist through the oracle against the new placement.
        ext = self._ext_demand[p]
        if changed and self.contention_oracle is not None and ext > 0.0:
            pp.apply_factor(self.contention_oracle(pp, ext))
        if self.cfg.throttle:
            self._throttle_check(t_ms)

    def _pressure(self) -> float:
        """Worst currently-applied contention factor across the pool —
        the signal that decides whether a throttled tenant's low miss
        rate is genuine recovery or just the duty cycle working."""
        return max(float(np.max(pp.factor_per_class)) for pp in self.pool)

    def _throttle_check(self, t_ms: float) -> None:
        """Prediction-driven engagement, run after each §4.4 re-solve:
        a tenant whose best-plan predicted finish (queueing estimate +
        re-fit-priced service) still exceeds ``throttle_margin`` of its
        latency budget gets duty-cycled *now*, before observed deadline
        misses pile up.  Release stays observation-driven
        (:meth:`TenantThrottle.observe` hysteresis in ``_complete``),
        but is *held* while ``_pressure`` stays above the monitor
        threshold — admitted traffic under a duty cycle looks healthy
        because of the throttle, not despite it."""
        waits = [self._load_ms[p] / self.pool[p].slots
                 for p in range(len(self.pool))]
        finish_by_cls = [
            min(w + pp.service_ms(c, pp.plan.specs[c].max_new)
                for w, pp in zip(waits, self.pool))
            for c in range(len(self.classes))]
        for tenant in range(self.n_tenants):
            budget = self.controller.slo_for(tenant).p99_ms
            if (finish_by_cls[self.class_of(tenant)]
                    <= self.cfg.throttle_margin * budget):
                continue
            th = self._throttles.get(tenant)
            if th is None:
                th = self._throttles[tenant] = TenantThrottle(
                    enter_miss_rate=self.cfg.throttle_enter,
                    exit_miss_rate=self.cfg.throttle_exit,
                    patience=self.cfg.throttle_patience)
            if th.engage():
                self.controller.set_duty(tenant, self.cfg.throttle_duty)
                self.throttle_events.append((t_ms, tenant, "throttle"))
                get_tracer().instant("fleet.throttle", "dynamic",
                                     ts_ms=t_ms, track="fleet",
                                     tenant=tenant,
                                     duty=self.cfg.throttle_duty)

    # -- external contention (tests / benchmarks / replay harnesses) ------
    def set_contention(self, plan: int, factor: float) -> None:
        """Inject external memory contention on one pool plan: all service
        from now on is priced under ``ScaledContentionModel(base, factor)``
        — the knob replay harnesses use to trigger the §4.4 loop."""
        self.pool[plan].apply_factor(factor)

    def set_demand(self, plan: int, ext_demand: float) -> None:
        """Inject external antagonist *demand* (fraction of shared-domain
        capacity) on one pool plan.

        Unlike :meth:`set_contention` (a raw severity factor), demand is
        priced through the ``contention_oracle`` — ground truth in a drift
        benchmark — into per-class factors, and it gives recalibration
        telemetry its ``ext`` coordinate: completions under non-zero
        demand stream ``(own, ext, observed slowdown)`` samples into the
        recalibrator.
        """
        if ext_demand < 0.0:
            raise ValueError("ext_demand must be >= 0")
        if self.contention_oracle is None:
            raise ValueError(
                "set_demand requires a contention_oracle to price demand "
                "into severity (use set_contention for raw factors)")
        self._ext_demand[plan] = float(ext_demand)
        pp = self.pool[plan]
        if ext_demand > 0.0:
            pp.apply_factor(self.contention_oracle(pp, float(ext_demand)))
        else:
            pp.apply_factor(1.0)

    # -- replay ------------------------------------------------------------
    def replay(self, trace: ArrivalTrace,
               contention_events: Sequence[tuple[float, int, float]] = (),
               drain: bool = True,
               demand_events: Sequence[tuple[float, int, float]] = (),
               ) -> FleetReport:
        """Replay an arrival trace through the loop (virtual time).

        ``contention_events`` is a sorted sequence of ``(t_ms, plan_idx,
        factor)`` external-severity switches merged into the arrival
        stream; ``demand_events`` are ``(t_ms, plan_idx, ext_demand)``
        antagonist-demand switches routed through :meth:`set_demand`
        (they drive the closed recalibration loop and require a
        ``contention_oracle``).  With ``drain`` the clock runs until the
        last admitted request completes.
        """
        if trace.n_tenants > self.n_tenants:
            raise ValueError(
                f"trace has {trace.n_tenants} tenants, gateway admits "
                f"{self.n_tenants}")
        events = sorted(
            [(t, p, v, False) for t, p, v in contention_events]
            + [(t, p, v, True) for t, p, v in demand_events])

        def fire(t_ev: float, plan: int, val: float, is_demand: bool):
            self.advance(t_ev)
            if is_demand:
                self.set_demand(plan, val)
            else:
                self.set_contention(plan, val)

        e = 0
        t_arr, tenants, mnew = trace.t_ms, trace.tenant, trace.max_new
        with get_tracer().span("fleet.replay", "fleet",
                               requests=len(trace),
                               policy=self.cfg.policy) as sp:
            for k in range(len(trace)):
                t = float(t_arr[k])
                while e < len(events) and events[e][0] <= t:
                    fire(*events[e])
                    e += 1
                self.submit(t, int(tenants[k]), int(mnew[k]))
            for ev in events[e:]:
                fire(*ev)
            if drain:
                self.drain()
            sp.set(reschedules=len(self.reschedules),
                   recalibrations=len(self.recalibrations),
                   shed=self.controller.shed)
        return self.report()

    def report(self) -> FleetReport:
        r = self._rec
        n = r.n
        return FleetReport(
            n_tenants=self.n_tenants, classes=self.classes,
            policy=self.cfg.policy,
            tenant=r.tenant[:n].copy(), cls=r.cls[:n].copy(),
            plan=r.plan[:n].copy(), t_arrive=r.t_arrive[:n].copy(),
            t_start=r.t_start[:n].copy(), t_end=r.t_end[:n].copy(),
            service_ms=r.service_ms[:n].copy(),
            max_new=r.max_new[:n].copy(), status=r.status[:n].copy(),
            reschedules=list(self.reschedules),
            shed=self.controller.shed, deferred=self.controller.deferred,
            slos=dict(self.controller.slos),
            default_slo=self.controller.default_slo,
            recalibrations=list(self.recalibrations),
            throttle_events=list(self.throttle_events),
            throttled=self.controller.throttled,
            plan_names=tuple(pp.name for pp in self.pool))

    def metrics(self) -> dict:
        """Live telemetry in the gateway's ``metrics()`` shape: per-tenant
        rows under ``"tenants"`` (canonical :data:`~repro.serve.engine.
        METRIC_KEYS`), fleet aggregates on top."""
        rep = self.report()
        return conform(GATEWAY_SCHEMA, {
            "steps": int(rep.max_new[rep.done_mask].sum()),
            "kv_bytes_in_use": self.controller.kv_bytes_in_use,
            "deferred_admissions": self.controller.deferred,
            "reschedules": len(self.reschedules),
        }, tenants={int(t): rep.tenant_metrics(int(t))
                    for t in np.unique(rep.tenant)})

    def export_trace(self, tracer=None,
                     max_requests: int | None = 50_000) -> int:
        """Ingest the replay's derived per-request spans into ``tracer``
        (default: the global tracer).  Returns the event count added.
        The live replay recorded only rare instants (reschedule /
        throttle / recalibration publish); this bulk pass adds the
        per-plan queue/service spans from the record arrays."""
        tracer = tracer or get_tracer()
        if not tracer.enabled:
            return 0
        events = self.report().trace_events(max_requests=max_requests,
                                            track_id=tracer.track_id)
        tracer.add_events(events)
        return len(events)

    # -- asyncio front-end -------------------------------------------------
    def _resolve_future(self, i: int) -> None:
        fut = self._futures.pop(i, None)
        if fut is not None and not fut.done():
            fut.set_result(self._rec.status[i] == DONE)

    async def submit_async(self, tenant: int, max_new: int,
                           t_ms: float | None = None) -> bool:
        """Submit one request and await its completion (False = shed)."""
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        t = self._now if t_ms is None else t_ms
        # register before submitting: shed resolves the future inline.
        self._futures[self._rec.n] = fut
        i = self.submit(t, tenant, max_new)
        if i < 0:
            return await fut
        return await fut


async def serve_async(gateway: FleetGateway, trace: ArrivalTrace,
                      time_scale: float = 0.0) -> FleetReport:
    """Drive the fleet loop as an asyncio service.

    Arrivals are paced in wall time (``sleep(gap_ms * time_scale / 1e3)``;
    0 replays as fast as the event loop can schedule) and each submission
    is a task awaiting its own completion — the front-end shape a network
    server would use, over the same deterministic virtual-time core.
    """
    async def one(t: float, tenant: int, max_new: int):
        return await gateway.submit_async(tenant, max_new, t_ms=t)

    tasks = []
    prev = float(trace.t_ms[0]) if len(trace) else 0.0
    for k in range(len(trace)):
        t = float(trace.t_ms[k])
        if time_scale > 0.0 and t > prev:
            await asyncio.sleep((t - prev) * time_scale / 1e3)
        prev = t
        tasks.append(asyncio.ensure_future(
            one(t, int(trace.tenant[k]), int(trace.max_new[k]))))
        # yield to let completions resolve between submissions.
        await asyncio.sleep(0)
    gateway.drain()
    if tasks:
        await asyncio.gather(*tasks)
    return gateway.report()

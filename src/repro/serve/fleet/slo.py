"""Per-tenant SLO targets driving admission, shedding and plan selection.

MoCA (PAPERS.md) frames multi-tenant accelerator runtimes around per-tenant
QoS targets that *drive* resource decisions rather than merely being
reported afterwards.  This module is that control surface for the fleet
loop:

* :class:`SLO` — a tenant's targets: tail-latency budget (``p99_ms``) and
  optional throughput floor (``throughput_rps``), plus a ``priority``
  weight used when load must be shed.
* :class:`AdmissionController` — the shared budget + SLO gate.  It owns
  the fleet-wide KV-memory budget (the same accounting as
  ``MultiTenantGateway``'s ``memory_budget_bytes``), decides
  admit/defer/shed per arriving request, and performs SLO-aware plan
  selection (route each request to the pool plan minimizing its predicted
  finish time against the tenant's deadline).  :meth:`engine_gate` adapts
  the controller to the existing :class:`~repro.serve.engine.ServingEngine`
  ``admission_gate`` hook, so a real engine and the fleet's virtual-time
  loop enforce one budget through one object.

Decision semantics (one request):

1. **shed** — refused outright, never queued: the tenant's queue is at its
   bound, or the predicted queueing delay already blows the latency budget
   by ``shed_factor``.  Open-loop arrivals cannot be back-pressured, so
   shedding early protects admitted requests instead of letting everyone
   time out (a rejected request is an SLO outcome too — it is counted).
2. **admit** — enqueued; a KV slot is *acquired* only when service starts
   (``try_acquire``/``release``), so queued requests never pin memory.
3. **defer** — an admitted request whose service start is blocked on the
   KV budget; it stays queued and is retried as budget frees.
4. **throttle** — contention *mitigation*, the closed loop's second
   control axis (MoCA's per-tenant throttling; the duty-cycle mechanism of
   :class:`~repro.profiling.probes.MemoryProbe` applied as a control
   action instead of an antagonist): when re-solving under the re-fitted
   contention model still cannot meet a tenant's SLO, the tenant is
   duty-cycled — only ``duty`` of its arrivals are admitted, via a
   deterministic token bucket — until its deadline-miss rate recovers.
   :class:`TenantThrottle` is the hysteresis state machine deciding
   engage/release, with separate enter/exit thresholds plus patience on
   both edges so throttle/unthrottle does not flap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from repro.obs import ADMISSION_SCHEMA, conform


@dataclass(frozen=True)
class SLO:
    """One tenant's service-level objectives."""

    #: end-to-end (queueing + service) tail-latency budget.
    p99_ms: float
    #: minimum sustained completion rate the tenant is promised; 0 = best
    #: effort.  Checked post-hoc per replay (see FleetReport.slo_report).
    throughput_rps: float = 0.0
    #: relative weight when shedding: lower priority sheds first.
    priority: float = 1.0

    def __post_init__(self):
        if self.p99_ms <= 0.0:
            raise ValueError("p99_ms must be > 0")
        if self.throughput_rps < 0.0 or self.priority <= 0.0:
            raise ValueError("throughput_rps must be >= 0 and priority > 0")

    def to_dict(self) -> dict:
        return {"p99_ms": self.p99_ms,
                "throughput_rps": self.throughput_rps,
                "priority": self.priority}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SLO":
        return cls(p99_ms=d["p99_ms"],
                   throughput_rps=d.get("throughput_rps", 0.0),
                   priority=d.get("priority", 1.0))


def parse_slo(spec: str) -> SLO:
    """CLI helper: ``p99=400[,rps=5][,priority=2]`` -> :class:`SLO`."""
    keys = {"p99": "p99_ms", "p99_ms": "p99_ms",
            "rps": "throughput_rps", "throughput_rps": "throughput_rps",
            "priority": "priority"}
    kwargs: dict[str, float] = {}
    for item in filter(None, spec.split(",")):
        key, _, val = item.partition("=")
        if key not in keys:
            raise ValueError(f"unknown SLO field {key!r} in {spec!r} "
                             f"(one of {', '.join(sorted(set(keys)))})")
        kwargs[keys[key]] = float(val)
    if "p99_ms" not in kwargs:
        raise ValueError(f"SLO spec {spec!r} must set p99=<ms>")
    return SLO(**kwargs)


@dataclass
class TenantThrottle:
    """Hysteresis engage/release controller for one tenant's duty cycle.

    ``observe`` folds each completion's deadline outcome into an EWMA
    miss rate and returns ``"throttle"`` once the rate stays above
    ``enter_miss_rate`` for ``patience`` consecutive completions,
    ``"release"`` once a throttled tenant stays below ``exit_miss_rate``
    for ``patience`` completions, and ``None`` otherwise.  The gap between
    the two thresholds plus the patience on both edges is the hysteresis:
    a tenant hovering at the boundary never flaps.
    """

    #: EWMA deadline-miss rate that engages the throttle.
    enter_miss_rate: float = 0.5
    #: EWMA miss rate a throttled tenant must fall below to release.
    exit_miss_rate: float = 0.1
    #: consecutive observations beyond a threshold before switching.
    patience: int = 8
    #: EWMA weight of the newest completion.
    alpha: float = 0.2

    miss_ewma: float = field(init=False, default=0.0)
    throttled: bool = field(init=False, default=False)
    switches: int = field(init=False, default=0)
    _strikes: int = field(init=False, default=0)

    def __post_init__(self):
        if not 0.0 <= self.exit_miss_rate < self.enter_miss_rate <= 1.0:
            raise ValueError(
                "need 0 <= exit_miss_rate < enter_miss_rate <= 1 "
                "(the gap is the hysteresis)")
        if self.patience < 1:
            raise ValueError("patience must be >= 1")

    def engage(self) -> bool:
        """Force-engage (prediction-driven, at reschedule time): the
        re-solved plan's predicted finish still blows the tenant's budget,
        so don't wait for observed misses to accumulate.  Seeds the miss
        EWMA at 1 so release still requires a sustained run of on-time
        completions.  Returns False when already throttled."""
        if self.throttled:
            return False
        self.throttled = True
        self._strikes = 0
        self.miss_ewma = 1.0
        self.switches += 1
        return True

    def observe(self, missed: bool, hold: bool = False) -> str | None:
        """Fold one completion's deadline outcome; maybe switch state.

        ``hold=True`` pins an engaged throttle regardless of the miss
        rate: under a duty cycle the *admitted* traffic looks healthy
        precisely because of the throttle, so while the condition that
        caused the engagement persists (e.g. priced contention still
        above the monitor threshold) a low miss EWMA must not trigger
        release — that would re-flood the queues the duty cycle just
        drained and flap."""
        self.miss_ewma = (self.alpha * (1.0 if missed else 0.0)
                          + (1.0 - self.alpha) * self.miss_ewma)
        if not self.throttled and self.miss_ewma > self.enter_miss_rate:
            self._strikes += 1
            if self._strikes >= self.patience:
                self.throttled, self._strikes = True, 0
                self.switches += 1
                return "throttle"
        elif self.throttled and self.miss_ewma < self.exit_miss_rate:
            if hold:
                self._strikes = 0
                return None
            self._strikes += 1
            if self._strikes >= self.patience:
                self.throttled, self._strikes = False, 0
                self.switches += 1
                return "release"
        else:
            self._strikes = 0
        return None


class AdmissionController:
    """Shared KV budget + SLO policy for a fleet of tenants.

    ``slos`` maps tenant id (or the special key ``"default"``) to its
    :class:`SLO`; tenants without an entry use ``default_slo``.
    """

    def __init__(self, budget_bytes: float | None = None,
                 default_slo: SLO = SLO(p99_ms=1000.0),
                 slos: Mapping[int, SLO] | None = None,
                 max_queue_per_tenant: int = 64,
                 shed_factor: float = 4.0):
        if max_queue_per_tenant < 1:
            raise ValueError("max_queue_per_tenant must be >= 1")
        if shed_factor <= 0.0:
            raise ValueError("shed_factor must be > 0")
        self.budget_bytes = budget_bytes
        self.default_slo = default_slo
        self.slos = dict(slos or {})
        self.max_queue_per_tenant = max_queue_per_tenant
        self.shed_factor = shed_factor
        self.kv_bytes_in_use = 0.0
        #: per-tenant duty cycle (absent/1.0 = unthrottled).
        self.duty: dict[int, float] = {}
        self._duty_acc: dict[int, float] = {}
        # counters (telemetry)
        self.shed = 0
        self.deferred = 0
        self.throttled = 0

    # -- SLO lookup --------------------------------------------------------
    def slo_for(self, tenant: int) -> SLO:
        return self.slos.get(tenant, self.default_slo)

    def deadline_ms(self, tenant: int, arrival_ms: float) -> float:
        return arrival_ms + self.slo_for(tenant).p99_ms

    # -- KV budget (same accounting as the gateway's memory_budget_bytes) --
    def kv_admit(self, nbytes: float) -> bool:
        if self.budget_bytes is None:
            return True
        return self.kv_bytes_in_use + nbytes <= self.budget_bytes

    def try_acquire(self, nbytes: float) -> bool:
        if not self.kv_admit(nbytes):
            self.deferred += 1
            return False
        self.kv_bytes_in_use += nbytes
        return True

    def release(self, nbytes: float) -> None:
        self.kv_bytes_in_use = max(0.0, self.kv_bytes_in_use - nbytes)

    def engine_gate(self, bytes_per_slot: float) -> Callable[[object], bool]:
        """Adapter for the existing ``ServingEngine(admission_gate=...)``
        hook: the returned callable prices one slot admission against this
        controller's shared budget (deferral keeps the engine's FIFO)."""
        def gate(_req: object) -> bool:
            ok = self.kv_admit(bytes_per_slot)
            if not ok:
                self.deferred += 1
            return ok
        return gate

    # -- duty-cycle throttling (MoCA-style mitigation) ---------------------
    def set_duty(self, tenant: int, duty: float) -> None:
        """Set (or clear, with ``duty >= 1``) a tenant's admission duty
        cycle.  The accumulator resets so a fresh throttle takes effect on
        the very next arrival."""
        if not 0.0 < duty:
            raise ValueError("duty must be > 0")
        if duty >= 1.0:
            self.duty.pop(tenant, None)
            self._duty_acc.pop(tenant, None)
        else:
            self.duty[tenant] = duty
            self._duty_acc[tenant] = 0.0

    def duty_of(self, tenant: int) -> float:
        return self.duty.get(tenant, 1.0)

    def duty_admit(self, tenant: int) -> bool:
        """Deterministic token bucket: admit exactly ``duty`` of a
        throttled tenant's arrivals (the duty-cycle mechanism of
        ``profiling.probes.MemoryProbe``, applied as mitigation).  Each
        arrival deposits ``duty``; an arrival is admitted when the bucket
        holds a full token.  No randomness: the admit pattern for
        ``duty=0.5`` is strictly alternating."""
        duty = self.duty.get(tenant)
        if duty is None:
            return True
        acc = self._duty_acc.get(tenant, 0.0) + duty
        if acc >= 1.0 - 1e-12:
            self._duty_acc[tenant] = acc - 1.0
            return True
        self._duty_acc[tenant] = acc
        self.throttled += 1
        return False

    # -- admission / shedding ---------------------------------------------
    def should_shed(self, tenant: int, queue_depth: int,
                    est_wait_ms: float) -> bool:
        """Refuse an arriving request outright (never queued)?

        Sheds when the tenant's queue is at its bound or predicted
        queueing alone exceeds ``shed_factor / priority`` times the
        latency budget — higher-priority tenants tolerate deeper backlog
        before shedding.
        """
        if queue_depth >= self.max_queue_per_tenant:
            self.shed += 1
            return True
        slo = self.slo_for(tenant)
        if est_wait_ms > self.shed_factor * slo.priority * slo.p99_ms:
            self.shed += 1
            return True
        return False

    # -- plan selection ----------------------------------------------------
    def select_plan(self, est_wait_ms: Sequence[float],
                    service_ms: Sequence[float]) -> int:
        """SLO-aware routing: earliest predicted finish over the pool.

        ``est_wait_ms[p]`` is plan p's current queueing estimate and
        ``service_ms[p]`` this request's predicted service time there
        (plans are heterogeneous: the same tenant class runs at different
        speeds on different SoC plans).  Minimizing predicted finish is
        what makes the SLO policy beat static round-robin on tail latency:
        it respects both instantaneous load *and* plan affinity.
        """
        best, best_cost = 0, float("inf")
        for p, (w, s) in enumerate(zip(est_wait_ms, service_ms)):
            cost = w + s
            if cost < best_cost:
                best, best_cost = p, cost
        return best

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        """Admission telemetry in the canonical
        :data:`~repro.obs.ADMISSION_SCHEMA` shape."""
        return conform(ADMISSION_SCHEMA, {
            "kv_bytes_in_use": self.kv_bytes_in_use,
            "budget_bytes": self.budget_bytes,
            "shed": self.shed, "deferred": self.deferred,
            "throttled": self.throttled,
            "duty": dict(self.duty)})

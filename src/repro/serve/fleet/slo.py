"""Per-tenant SLO targets driving admission, shedding and plan selection.

MoCA (PAPERS.md) frames multi-tenant accelerator runtimes around per-tenant
QoS targets that *drive* resource decisions rather than merely being
reported afterwards.  This module is that control surface for the fleet
loop:

* :class:`SLO` — a tenant's targets: tail-latency budget (``p99_ms``) and
  optional throughput floor (``throughput_rps``), plus a ``priority``
  weight used when load must be shed.
* :class:`AdmissionController` — the shared budget + SLO gate.  It owns
  the fleet-wide KV-memory budget (the same accounting as
  ``MultiTenantGateway``'s ``memory_budget_bytes``), decides
  admit/defer/shed per arriving request, and performs SLO-aware plan
  selection (route each request to the pool plan minimizing its predicted
  finish time against the tenant's deadline).  :meth:`engine_gate` adapts
  the controller to the existing :class:`~repro.serve.engine.ServingEngine`
  ``admission_gate`` hook, so a real engine and the fleet's virtual-time
  loop enforce one budget through one object.

Decision semantics (one request):

1. **shed** — refused outright, never queued: the tenant's queue is at its
   bound, or the predicted queueing delay already blows the latency budget
   by ``shed_factor``.  Open-loop arrivals cannot be back-pressured, so
   shedding early protects admitted requests instead of letting everyone
   time out (a rejected request is an SLO outcome too — it is counted).
2. **admit** — enqueued; a KV slot is *acquired* only when service starts
   (``try_acquire``/``release``), so queued requests never pin memory.
3. **defer** — an admitted request whose service start is blocked on the
   KV budget; it stays queued and is retried as budget frees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence


@dataclass(frozen=True)
class SLO:
    """One tenant's service-level objectives."""

    #: end-to-end (queueing + service) tail-latency budget.
    p99_ms: float
    #: minimum sustained completion rate the tenant is promised; 0 = best
    #: effort.  Checked post-hoc per replay (see FleetReport.slo_report).
    throughput_rps: float = 0.0
    #: relative weight when shedding: lower priority sheds first.
    priority: float = 1.0

    def __post_init__(self):
        if self.p99_ms <= 0.0:
            raise ValueError("p99_ms must be > 0")
        if self.throughput_rps < 0.0 or self.priority <= 0.0:
            raise ValueError("throughput_rps must be >= 0 and priority > 0")

    def to_dict(self) -> dict:
        return {"p99_ms": self.p99_ms,
                "throughput_rps": self.throughput_rps,
                "priority": self.priority}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SLO":
        return cls(p99_ms=d["p99_ms"],
                   throughput_rps=d.get("throughput_rps", 0.0),
                   priority=d.get("priority", 1.0))


def parse_slo(spec: str) -> SLO:
    """CLI helper: ``p99=400[,rps=5][,priority=2]`` -> :class:`SLO`."""
    keys = {"p99": "p99_ms", "p99_ms": "p99_ms",
            "rps": "throughput_rps", "throughput_rps": "throughput_rps",
            "priority": "priority"}
    kwargs: dict[str, float] = {}
    for item in filter(None, spec.split(",")):
        key, _, val = item.partition("=")
        if key not in keys:
            raise ValueError(f"unknown SLO field {key!r} in {spec!r} "
                             f"(one of {', '.join(sorted(set(keys)))})")
        kwargs[keys[key]] = float(val)
    if "p99_ms" not in kwargs:
        raise ValueError(f"SLO spec {spec!r} must set p99=<ms>")
    return SLO(**kwargs)


class AdmissionController:
    """Shared KV budget + SLO policy for a fleet of tenants.

    ``slos`` maps tenant id (or the special key ``"default"``) to its
    :class:`SLO`; tenants without an entry use ``default_slo``.
    """

    def __init__(self, budget_bytes: float | None = None,
                 default_slo: SLO = SLO(p99_ms=1000.0),
                 slos: Mapping[int, SLO] | None = None,
                 max_queue_per_tenant: int = 64,
                 shed_factor: float = 4.0):
        if max_queue_per_tenant < 1:
            raise ValueError("max_queue_per_tenant must be >= 1")
        if shed_factor <= 0.0:
            raise ValueError("shed_factor must be > 0")
        self.budget_bytes = budget_bytes
        self.default_slo = default_slo
        self.slos = dict(slos or {})
        self.max_queue_per_tenant = max_queue_per_tenant
        self.shed_factor = shed_factor
        self.kv_bytes_in_use = 0.0
        # counters (telemetry)
        self.shed = 0
        self.deferred = 0

    # -- SLO lookup --------------------------------------------------------
    def slo_for(self, tenant: int) -> SLO:
        return self.slos.get(tenant, self.default_slo)

    def deadline_ms(self, tenant: int, arrival_ms: float) -> float:
        return arrival_ms + self.slo_for(tenant).p99_ms

    # -- KV budget (same accounting as the gateway's memory_budget_bytes) --
    def kv_admit(self, nbytes: float) -> bool:
        if self.budget_bytes is None:
            return True
        return self.kv_bytes_in_use + nbytes <= self.budget_bytes

    def try_acquire(self, nbytes: float) -> bool:
        if not self.kv_admit(nbytes):
            self.deferred += 1
            return False
        self.kv_bytes_in_use += nbytes
        return True

    def release(self, nbytes: float) -> None:
        self.kv_bytes_in_use = max(0.0, self.kv_bytes_in_use - nbytes)

    def engine_gate(self, bytes_per_slot: float) -> Callable[[object], bool]:
        """Adapter for the existing ``ServingEngine(admission_gate=...)``
        hook: the returned callable prices one slot admission against this
        controller's shared budget (deferral keeps the engine's FIFO)."""
        def gate(_req: object) -> bool:
            ok = self.kv_admit(bytes_per_slot)
            if not ok:
                self.deferred += 1
            return ok
        return gate

    # -- admission / shedding ---------------------------------------------
    def should_shed(self, tenant: int, queue_depth: int,
                    est_wait_ms: float) -> bool:
        """Refuse an arriving request outright (never queued)?

        Sheds when the tenant's queue is at its bound or predicted
        queueing alone exceeds ``shed_factor / priority`` times the
        latency budget — higher-priority tenants tolerate deeper backlog
        before shedding.
        """
        if queue_depth >= self.max_queue_per_tenant:
            self.shed += 1
            return True
        slo = self.slo_for(tenant)
        if est_wait_ms > self.shed_factor * slo.priority * slo.p99_ms:
            self.shed += 1
            return True
        return False

    # -- plan selection ----------------------------------------------------
    def select_plan(self, est_wait_ms: Sequence[float],
                    service_ms: Sequence[float]) -> int:
        """SLO-aware routing: earliest predicted finish over the pool.

        ``est_wait_ms[p]`` is plan p's current queueing estimate and
        ``service_ms[p]`` this request's predicted service time there
        (plans are heterogeneous: the same tenant class runs at different
        speeds on different SoC plans).  Minimizing predicted finish is
        what makes the SLO policy beat static round-robin on tail latency:
        it respects both instantaneous load *and* plan affinity.
        """
        best, best_cost = 0, float("inf")
        for p, (w, s) in enumerate(zip(est_wait_ms, service_ms)):
            cost = w + s
            if cost < best_cost:
                best, best_cost = p, cost
        return best

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        return {"kv_bytes_in_use": self.kv_bytes_in_use,
                "budget_bytes": self.budget_bytes,
                "shed": self.shed, "deferred": self.deferred}

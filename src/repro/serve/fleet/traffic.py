"""Seeded open-loop arrival traces for fleet-scale serving.

Production traffic is *open-loop*: users do not wait for the previous
response before sending the next request, so the arrival process — not the
server — sets the offered load, and queueing explodes the moment sustained
arrival rate crosses service capacity.  This module generates the three
arrival shapes the serving literature calls out as production-like (MoCA's
multi-tenant QoS mixes; the mobile-SoC LLM characterization's bursty and
diurnal request streams, see PAPERS.md):

* :func:`poisson_trace` — memoryless constant-rate arrivals (the classic
  M/G/k offered load);
* :func:`bursty_trace` — a 2-state Markov-modulated Poisson process
  (MMPP-2): exponentially-dwelling calm/burst states with different rates,
  producing the heavy-tailed queueing that defeats mean-rate provisioning;
* :func:`diurnal_trace` — a piecewise-constant daily rate profile replayed
  over as many days as needed (non-homogeneous Poisson per bucket).

Every generator is **bit-deterministic for a fixed seed** (one
``numpy.random.default_rng(seed)`` stream, fixed draw order) and returns an
:class:`ArrivalTrace` — a frozen, array-backed, content-hashable artifact
with a versioned JSON format (:meth:`ArrivalTrace.save` /
:meth:`ArrivalTrace.load`), so a million-request load test is a few dozen
bytes of generator parameters plus a seed, and a *measured* production
trace can be replayed through the same interface.

Times are milliseconds, rates requests/second; ``tenant`` is an integer id
in ``[0, n_tenants)`` — the fleet loop maps tenants onto model classes.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

from repro.core.plan import canonical_hash

FORMAT = 1
KINDS = ("poisson", "bursty", "diurnal", "custom")

#: default relative load per hour-of-day for :func:`diurnal_trace` — a
#: stylized consumer curve: overnight trough, morning ramp, evening peak.
DIURNAL_PROFILE = (
    0.15, 0.10, 0.08, 0.08, 0.10, 0.15, 0.25, 0.40, 0.55, 0.65, 0.70, 0.75,
    0.80, 0.75, 0.70, 0.70, 0.75, 0.85, 1.00, 0.95, 0.80, 0.60, 0.40, 0.25,
)


@dataclass(frozen=True)
class ArrivalTrace:
    """A frozen, array-backed open-loop arrival trace."""

    kind: str
    seed: int
    n_tenants: int
    #: generator parameters (JSON-serializable; provenance only).
    params: Mapping[str, Any]
    t_ms: np.ndarray                     # (N,) float64, non-decreasing
    tenant: np.ndarray                   # (N,) int32 in [0, n_tenants)
    prompt_len: np.ndarray               # (N,) int32 >= 1
    max_new: np.ndarray                  # (N,) int32 >= 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown trace kind {self.kind!r}; "
                             f"one of {', '.join(KINDS)}")
        if self.n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        arrays = {
            "t_ms": np.ascontiguousarray(self.t_ms, np.float64),
            "tenant": np.ascontiguousarray(self.tenant, np.int32),
            "prompt_len": np.ascontiguousarray(self.prompt_len, np.int32),
            "max_new": np.ascontiguousarray(self.max_new, np.int32),
        }
        n = len(arrays["t_ms"])
        for name, arr in arrays.items():
            if arr.ndim != 1 or len(arr) != n:
                raise ValueError(f"{name} must be 1-D with {n} entries")
            arr.setflags(write=False)
            object.__setattr__(self, name, arr)
        if n:
            if np.any(np.diff(arrays["t_ms"]) < 0.0):
                raise ValueError("arrival times must be non-decreasing")
            if arrays["t_ms"][0] < 0.0:
                raise ValueError("arrival times must be >= 0")
            t = arrays["tenant"]
            if t.min() < 0 or t.max() >= self.n_tenants:
                raise ValueError(f"tenant ids must be in [0, "
                                 f"{self.n_tenants})")
            if arrays["prompt_len"].min() < 1 or arrays["max_new"].min() < 1:
                raise ValueError("prompt_len and max_new must be >= 1")
        object.__setattr__(self, "params", dict(self.params))

    # -- views -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.t_ms)

    @property
    def duration_ms(self) -> float:
        return float(self.t_ms[-1] - self.t_ms[0]) if len(self) else 0.0

    @property
    def mean_rate_rps(self) -> float:
        if len(self) < 2 or self.duration_ms <= 0.0:
            return 0.0
        return 1e3 * (len(self) - 1) / self.duration_ms

    def burstiness(self) -> float:
        """Coefficient of variation of inter-arrival gaps (1.0 = Poisson)."""
        gaps = np.diff(self.t_ms)
        if len(gaps) < 2 or gaps.mean() <= 0.0:
            return 0.0
        return float(gaps.std() / gaps.mean())

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "format": FORMAT,
            "kind": self.kind,
            "seed": self.seed,
            "n_tenants": self.n_tenants,
            "params": dict(self.params),
            "t_ms": [float(t) for t in self.t_ms],
            "tenant": [int(t) for t in self.tenant],
            "prompt_len": [int(p) for p in self.prompt_len],
            "max_new": [int(m) for m in self.max_new],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ArrivalTrace":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"unsupported trace format {d.get('format')!r} "
                f"(this build reads format {FORMAT})")
        return cls(kind=d["kind"], seed=d["seed"],
                   n_tenants=d["n_tenants"], params=dict(d["params"]),
                   t_ms=np.asarray(d["t_ms"], np.float64),
                   tenant=np.asarray(d["tenant"], np.int32),
                   prompt_len=np.asarray(d["prompt_len"], np.int32),
                   max_new=np.asarray(d["max_new"], np.int32))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "ArrivalTrace":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ArrivalTrace":
        return cls.from_json(pathlib.Path(path).read_text())

    def trace_hash(self) -> str:
        """Content hash of the canonical JSON form (replay provenance)."""
        return canonical_hash(self.to_dict())


# ---------------------------------------------------------------------------
# shared sampling helpers
# ---------------------------------------------------------------------------

def _tenant_weights(n_tenants: int, skew: float) -> np.ndarray:
    """Zipf-like tenant popularity: p(i) ∝ (i+1)^-skew (skew=0 uniform)."""
    w = (np.arange(n_tenants, dtype=np.float64) + 1.0) ** -float(skew)
    return w / w.sum()


def _sample_request_columns(rng: np.random.Generator, n: int,
                            n_tenants: int, skew: float,
                            prompt_len: tuple[int, int],
                            max_new: tuple[int, int]):
    tenant = rng.choice(n_tenants, size=n,
                        p=_tenant_weights(n_tenants, skew)).astype(np.int32)
    plen = rng.integers(prompt_len[0], prompt_len[1] + 1,
                        size=n).astype(np.int32)
    mnew = rng.integers(max_new[0], max_new[1] + 1, size=n).astype(np.int32)
    return tenant, plen, mnew


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

def poisson_trace(rate_rps: float, n_requests: int, n_tenants: int,
                  seed: int = 0, *, skew: float = 0.0,
                  prompt_len: tuple[int, int] = (8, 64),
                  max_new: tuple[int, int] = (4, 32),
                  start_ms: float = 0.0) -> ArrivalTrace:
    """Memoryless constant-rate arrivals (homogeneous Poisson process)."""
    if rate_rps <= 0.0:
        raise ValueError("rate_rps must be > 0")
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1e3 / rate_rps, size=n_requests)
    t = start_ms + np.cumsum(gaps)
    tenant, plen, mnew = _sample_request_columns(
        rng, n_requests, n_tenants, skew, prompt_len, max_new)
    return ArrivalTrace(
        kind="poisson", seed=seed, n_tenants=n_tenants,
        params={"rate_rps": rate_rps, "n_requests": n_requests,
                "skew": skew, "prompt_len": list(prompt_len),
                "max_new": list(max_new), "start_ms": start_ms},
        t_ms=t, tenant=tenant, prompt_len=plen, max_new=mnew)


def bursty_trace(base_rps: float, burst_rps: float, n_requests: int,
                 n_tenants: int, seed: int = 0, *,
                 mean_calm_s: float = 20.0, mean_burst_s: float = 4.0,
                 skew: float = 0.0,
                 prompt_len: tuple[int, int] = (8, 64),
                 max_new: tuple[int, int] = (4, 32)) -> ArrivalTrace:
    """2-state Markov-modulated Poisson process (calm rate / burst rate).

    The state dwells exponentially (``mean_calm_s`` / ``mean_burst_s``)
    and arrivals within a dwell are homogeneous Poisson at the state's
    rate — the canonical bursty load model: mean rate can be far below
    capacity while bursts transiently oversubscribe it.
    """
    if base_rps <= 0.0 or burst_rps <= 0.0:
        raise ValueError("rates must be > 0")
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    rng = np.random.default_rng(seed)
    times: list[np.ndarray] = []
    total, t0, state = 0, 0.0, 0        # state 0 = calm, 1 = burst
    while total < n_requests:
        dwell_ms = rng.exponential(
            1e3 * (mean_burst_s if state else mean_calm_s))
        rate = burst_rps if state else base_rps
        k = int(rng.poisson(rate * dwell_ms / 1e3))
        if k:
            seg = np.sort(rng.uniform(t0, t0 + dwell_ms, size=k))
            times.append(seg)
            total += k
        t0 += dwell_ms
        state ^= 1
    t = np.concatenate(times)[:n_requests]
    tenant, plen, mnew = _sample_request_columns(
        rng, n_requests, n_tenants, skew, prompt_len, max_new)
    return ArrivalTrace(
        kind="bursty", seed=seed, n_tenants=n_tenants,
        params={"base_rps": base_rps, "burst_rps": burst_rps,
                "n_requests": n_requests, "mean_calm_s": mean_calm_s,
                "mean_burst_s": mean_burst_s, "skew": skew,
                "prompt_len": list(prompt_len), "max_new": list(max_new)},
        t_ms=t, tenant=tenant, prompt_len=plen, max_new=mnew)


def diurnal_trace(peak_rps: float, n_requests: int, n_tenants: int,
                  seed: int = 0, *, day_s: float = 86_400.0,
                  profile: tuple[float, ...] = DIURNAL_PROFILE,
                  skew: float = 0.0,
                  prompt_len: tuple[int, int] = (8, 64),
                  max_new: tuple[int, int] = (4, 32)) -> ArrivalTrace:
    """Daily rate-profile replay (non-homogeneous Poisson, piecewise rate).

    ``profile`` gives one relative rate per equal bucket of the day (24
    hourly buckets by default); the instantaneous rate in bucket ``b`` is
    ``peak_rps * profile[b] / max(profile)``.  Days repeat until
    ``n_requests`` arrivals are generated — a compressed ``day_s`` (e.g.
    60 s) replays the whole diurnal swing inside one benchmark run.
    """
    if peak_rps <= 0.0:
        raise ValueError("peak_rps must be > 0")
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    prof = np.asarray(profile, np.float64)
    if prof.ndim != 1 or len(prof) < 1 or prof.min() < 0.0 or prof.max() <= 0:
        raise ValueError("profile must be non-negative with a positive peak")
    rng = np.random.default_rng(seed)
    bucket_ms = 1e3 * day_s / len(prof)
    rates = peak_rps * prof / prof.max()
    times: list[np.ndarray] = []
    total, t0, b = 0, 0.0, 0
    while total < n_requests:
        rate = rates[b % len(rates)]
        k = int(rng.poisson(rate * bucket_ms / 1e3)) if rate > 0 else 0
        if k:
            seg = np.sort(rng.uniform(t0, t0 + bucket_ms, size=k))
            times.append(seg)
            total += k
        t0 += bucket_ms
        b += 1
    t = np.concatenate(times)[:n_requests]
    tenant, plen, mnew = _sample_request_columns(
        rng, n_requests, n_tenants, skew, prompt_len, max_new)
    return ArrivalTrace(
        kind="diurnal", seed=seed, n_tenants=n_tenants,
        params={"peak_rps": peak_rps, "n_requests": n_requests,
                "day_s": day_s, "profile": [float(p) for p in prof],
                "skew": skew, "prompt_len": list(prompt_len),
                "max_new": list(max_new)},
        t_ms=t, tenant=tenant, prompt_len=plen, max_new=mnew)


GENERATORS = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}

#: CLI spec aliases -> generator kwargs (``parse_trace_spec``).
_SPEC_KEYS = {
    "rate": "rate_rps", "base": "base_rps", "burst": "burst_rps",
    "peak": "peak_rps", "n": "n_requests", "tenants": "n_tenants",
    "seed": "seed", "skew": "skew", "calm_s": "mean_calm_s",
    "burst_s": "mean_burst_s", "day_s": "day_s",
}
_INT_KEYS = {"n_requests", "n_tenants", "seed"}


def parse_trace_spec(spec: str) -> ArrivalTrace:
    """Build a trace from a CLI spec: a JSON file path, or
    ``kind:key=value,...`` (e.g. ``poisson:rate=200,n=1000,tenants=64`` or
    ``bursty:base=50,burst=400,n=5000,tenants=128,seed=7``)."""
    path = pathlib.Path(spec)
    if path.exists():
        return ArrivalTrace.load(path)
    kind, _, rest = spec.partition(":")
    if kind not in GENERATORS:
        raise ValueError(
            f"unknown trace spec {spec!r}: not a file, and kind {kind!r} "
            f"is not one of {', '.join(GENERATORS)}")
    kwargs: dict[str, Any] = {}
    for item in filter(None, rest.split(",")):
        key, _, val = item.partition("=")
        name = _SPEC_KEYS.get(key, key)
        kwargs[name] = int(val) if name in _INT_KEYS else float(val)
    missing = ({"rate_rps"} if kind == "poisson"
               else {"base_rps", "burst_rps"} if kind == "bursty"
               else {"peak_rps"})
    missing |= {"n_requests", "n_tenants"}
    missing -= set(kwargs)
    if missing:
        raise ValueError(f"trace spec {spec!r} is missing "
                         f"{', '.join(sorted(missing))}")
    n = kwargs.pop("n_requests")
    tenants = kwargs.pop("n_tenants")
    if kind == "poisson":
        return poisson_trace(kwargs.pop("rate_rps"), n, tenants, **kwargs)
    if kind == "bursty":
        return bursty_trace(kwargs.pop("base_rps"),
                            kwargs.pop("burst_rps"), n, tenants, **kwargs)
    return diurnal_trace(kwargs.pop("peak_rps"), n, tenants, **kwargs)

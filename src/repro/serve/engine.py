"""Batched serving engine: continuous batching over decode slots.

A single-model engine: requests enter a queue; free slots admit them via a
single-request prefill whose cache is spliced into the batched cache; every
``step()`` runs one batched decode for all active slots (per-slot lengths),
greedy-samples, and retires finished requests.  This is the vLLM-style
continuous-batching control loop in miniature — slot admission, per-slot
lengths, cache capacity management — runnable on CPU with reduced configs
and lowerable at full scale via the dry-run.

The engine is non-blocking by design: one ``step()`` call performs at most
one batched decode and returns, so an external multiplexer (the multi-tenant
gateway in :mod:`repro.serve.gateway`) can interleave several engines.  An
optional ``admission_gate`` lets that multiplexer impose global policies
(shared memory budget, fairness) on slot admission without changing the
single-engine control flow.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.obs import TENANT_SCHEMA, conform


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 16
    eos: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


#: canonical per-tenant telemetry keys shared by every serving layer —
#: ``ServingEngine.metrics()``, the per-tenant rows of
#: ``MultiTenantGateway.metrics()`` and ``repro.serve.fleet`` reports all
#: emit exactly this shape, so a multiplexer consumes one dict format
#: regardless of which layer produced it.  Derived from the registry
#: schema in :mod:`repro.obs.metrics` — the schema is the single source
#: of truth, this tuple is the backward-compatible view of it.
METRIC_KEYS = tuple(TENANT_SCHEMA)


@dataclasses.dataclass
class EngineMetrics:
    """Rolling counters a multiplexer can poll between ``step()`` calls."""

    steps: int = 0
    admitted: int = 0
    #: queue->slot admissions refused by the admission gate.
    deferred: int = 0
    tokens_out: int = 0
    #: wall-clock ms of the most recent decode step (prefills excluded).
    last_step_ms: float = 0.0
    decode_ms_total: float = 0.0

    @property
    def mean_step_ms(self) -> float:
        return self.decode_ms_total / self.steps if self.steps else 0.0


class ServingEngine:
    def __init__(self, model: Model, params, max_slots: int = 4,
                 capacity: int = 256,
                 admission_gate: Callable[[Request], bool] | None = None):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.capacity = capacity
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_slots
        self.lengths = np.zeros((max_slots,), np.int32)
        self.last_tok = np.zeros((max_slots,), np.int32)
        self.caches = model.init_cache(max_slots, capacity)
        self._rid = itertools.count()
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, capacity=capacity))
        self.steps = 0
        self.completed: list[Request] = []
        #: consulted before each queue->slot admission; ``False`` defers the
        #: head request (FIFO is preserved: admission stops for this step).
        self.admission_gate = admission_gate
        self.counters = EngineMetrics()

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 16, eos: int | None = None
               ) -> Request:
        req = Request(next(self._rid), np.asarray(prompt, np.int32),
                      max_new=max_new, eos=eos)
        self.queue.append(req)
        return req

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_work(self) -> bool:
        """Anything queued or decoding — i.e. ``step()`` would make progress."""
        return bool(self.queue) or self.active > 0

    def metrics(self) -> dict:
        """Telemetry snapshot in the canonical :data:`METRIC_KEYS` shape.

        Built through :func:`repro.obs.conform` so a missing canonical
        key fails here, at the provider, not in a downstream consumer.
        """
        c = self.counters
        return conform(TENANT_SCHEMA, {
            "steps": c.steps,
            "active": self.active,
            "queue_depth": len(self.queue),
            "admitted": c.admitted,
            "completed": len(self.completed),
            "deferred": c.deferred,
            "tokens_out": c.tokens_out,
            "last_step_ms": c.last_step_ms,
            "mean_step_ms": c.mean_step_ms,
        })

    # ------------------------------------------------------------------
    def _admit(self):
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            if (self.admission_gate is not None
                    and not self.admission_gate(self.queue[0])):
                self.counters.deferred += 1
                break
            req = self.queue.popleft()
            batch = {"token_ids": jnp.asarray(req.prompt)[None]}
            logits, cache1 = self._prefill(self.params, batch)
            # splice the single-request cache into the batched cache.
            # group caches are stacked (n_groups, batch, ...); tail caches
            # are (batch, ...).
            new = dict(self.caches)
            if self.caches["groups"] is not None:
                new["groups"] = jax.tree.map(
                    lambda big, one: big.at[:, slot].set(one[:, 0]),
                    self.caches["groups"], cache1["groups"])
            new["tail"] = jax.tree.map(
                lambda big, one: big.at[slot].set(one[0]),
                self.caches["tail"], cache1["tail"])
            self.caches = new
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(tok)
            self.slots[slot] = req
            self.lengths[slot] = len(req.prompt)
            self.last_tok[slot] = tok
            self.counters.admitted += 1
            self.counters.tokens_out += 1

    def step(self) -> int:
        """Admit + one batched decode step; returns #active slots.

        Non-blocking from the caller's perspective: exactly one batched
        decode dispatch, timed into ``metrics.last_step_ms`` so a
        multiplexer can compare observed step latency against a schedule's
        prediction.
        """
        self._admit()
        if self.active == 0:
            return 0
        t0 = time.perf_counter()
        batch = {"token_ids": jnp.asarray(self.last_tok)[:, None],
                 "lengths": jnp.asarray(self.lengths)}
        logits, self.caches = self._decode(self.params, self.caches, batch)
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.counters.last_step_ms = (time.perf_counter() - t0) * 1e3
        self.counters.decode_ms_total += self.counters.last_step_ms
        self.counters.steps += 1
        self.counters.tokens_out += self.active
        self.steps += 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.lengths[slot] += 1
            tok = int(toks[slot])
            req.tokens.append(tok)
            self.last_tok[slot] = tok
            if (len(req.tokens) >= req.max_new
                    or (req.eos is not None and tok == req.eos)
                    or self.lengths[slot] >= self.capacity - 1):
                req.done = True
                self.completed.append(req)
                self.slots[slot] = None
        return self.active

    def run_until_drained(self, max_steps: int = 10000):
        while (self.queue or self.active) and self.steps < max_steps:
            self.step()
        return self.completed



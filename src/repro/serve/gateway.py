"""Contention-aware multi-tenant serving gateway.

Unifies the single-model continuous-batching engine
(:mod:`repro.serve.engine`), the HaX-CoNN planner
(:class:`repro.core.Scheduler`) and the D-HaX-CoNN dynamic loop
(:mod:`repro.core.dynamic`) into one subsystem that serves *several* models
concurrently on a shared-memory platform:

* **Phase-aware planning** — every tenant is exported as one schedulable
  chain ``prefill groups -> decode macro-groups`` (a decode macro-group is
  ``max_new`` decode steps fused, so its duration is commensurate with
  prefill while its *per-unit-time* shared-memory demand stays the decode
  demand).  The solver may therefore place a tenant's compute-bound prefill
  and memory-bound decode on *different* accelerators — phase
  disaggregation expressed as an ordinary HaX-CoNN transition.
* **Admission control** — a shared KV-memory budget across all tenants;
  each engine's slot admission is gated on the projected global usage, so a
  burst on one model cannot evict another model's working set.
* **Dynamic re-scheduling (§4.4)** — per-tenant
  :class:`~repro.core.dynamic.SlowdownMonitor` watches observed decode
  step latency for deviation from its calibrated steady-state baseline
  (the stand-in for the plan's prediction where wall-clock and simulated
  ms are incommensurate; the predicted step latency itself is reported by
  :meth:`GatewayPlan.predicted_decode_step_ms`).  A sustained deviation
  re-solves via :func:`~repro.core.dynamic.reschedule_plan` —
  ``Scheduler.resolve`` under a contention model rescaled to the observed
  severity, so re-schedules are plan-cached and logged like offline solves.

Timing on this CPU-only container is simulated (the plan's exact
event-driven timeline); token generation is real compute on reduced
configs, exactly like :mod:`repro.serve.concurrent`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import jax

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.accelerators import Platform
from repro.core.contention import ContentionModel
from repro.core.dynamic import (ScaledContentionModel, SlowdownMonitor,
                                quantize_severity, reschedule_plan)
from repro.core.graph import DNNGraph
from repro.core.plan import Plan, PlanCache
from repro.core.scheduler import Scheduler
from repro.core.simulate import SimResult, Workload, simulate
from repro.core.solver_bb import Solution
from repro.models import build
from repro.models.graph_export import export_graph
from repro.obs import GATEWAY_SCHEMA, conform, get_registry, get_tracer
from repro.serve.engine import Request, ServingEngine

_DTYPE_BYTES = {"int8": 1, "float16": 2, "bfloat16": 2, "float32": 4}


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes one decoded token pins in shared memory."""
    n_attn = sum(1 for k in cfg.layer_kinds if k in ("attn", "local"))
    return (2 * cfg.n_kv_heads * cfg.d_head
            * _DTYPE_BYTES.get(cfg.kv_cache_dtype, 2) * n_attn)


@dataclass(frozen=True)
class TenantSpec:
    """One served model plus its traffic/engine shape."""

    name: str
    #: config actually executed (reduced for CPU runs).
    cfg: ModelConfig
    #: config characterized for planning; defaults to ``cfg``.  Passing the
    #: full-size sibling plans the production schedule while executing the
    #: reduced one (same split as :mod:`repro.serve.concurrent`).
    plan_cfg: ModelConfig | None = None
    max_slots: int = 4
    #: KV capacity per slot, tokens.
    capacity: int = 64
    #: typical prompt length (drives the prefill phase graph).
    prompt_len: int = 8
    #: typical decode length (drives the decode macro-group scale).
    max_new: int = 16

    @property
    def planning_cfg(self) -> ModelConfig:
        return self.plan_cfg if self.plan_cfg is not None else self.cfg

    @property
    def kv_bytes_per_slot(self) -> int:
        return self.capacity * kv_bytes_per_token(self.cfg)


@dataclass(frozen=True)
class GatewayConfig:
    platform: str | Platform = "v5e-pod-split"
    objective: str = "throughput"
    model: ContentionModel | None = None
    #: shared KV budget across every tenant, bytes; None disables throttling.
    memory_budget_bytes: float | None = None
    #: registry solver entry planning the schedule ("auto" = z3 -> bb ->
    #: greedy; "anneal" opts into the device-resident search).
    solver: str = "auto"
    #: extra knobs for the named solver entry as sorted (name, value)
    #: pairs — e.g. anneal's ``devices``/``budget_ms``; validated against
    #: the entry's declared vocabulary at request construction.
    solver_knobs: tuple = ()
    max_transitions: int = 2
    #: layer-group granularity of the phase graphs (body groups per phase).
    body_groups: int = 2
    # ---- dynamic loop knobs ----
    #: 2x over the steady-state floor before firing: CPU wall-clock steps
    #: jitter far more than the simulated timeline they stand in for.
    slowdown_threshold: float = 2.0
    patience: int = 3
    cooldown: int = 16
    warmup: int = 4
    reschedule_budget_s: float = 0.5


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

def tenant_phase_graph(spec: TenantSpec, platform: Platform,
                       body_groups: int = 2) -> DNNGraph:
    """Export a tenant as one prefill->decode chain (see module docstring)."""
    cfg = spec.planning_cfg
    per_group = max(1, math.ceil(cfg.n_layers / body_groups))
    pf_cell = ShapeCell(f"{spec.name}-prefill", spec.prompt_len,
                        spec.max_slots, "prefill")
    dc_cell = ShapeCell(f"{spec.name}-decode", spec.capacity,
                        spec.max_slots, "decode")
    pf = export_graph(cfg, pf_cell, platform, layers_per_group=per_group)
    dc = export_graph(cfg, dc_cell, platform, layers_per_group=per_group)
    groups = [dataclasses.replace(g, name=f"prefill:{g.name}")
              for g in pf.groups]
    for g in dc.groups:
        # one macro-group = max_new decode steps: duration/bytes scale, the
        # per-unit-time shared demand (a rate) is unchanged.
        groups.append(dataclasses.replace(
            g,
            name=f"decode:{g.name}",
            times={a: t * spec.max_new for a, t in g.times.items()},
            flops=g.flops * spec.max_new,
            hbm_bytes=g.hbm_bytes * spec.max_new,
            out_bytes=g.out_bytes * spec.max_new,
        ))
    return DNNGraph(spec.name, tuple(groups))


@dataclass
class GatewayPlan:
    """A contention-aware multi-tenant schedule plus its baselines."""

    platform: Platform
    specs: list[TenantSpec]
    graphs: list[DNNGraph]               # one per tenant, tenant order
    iterations: list[int]
    solution: Solution
    round_robin: SimResult
    #: #groups in the prefill phase per tenant (decode groups follow).
    n_prefill_groups: dict[str, int]
    #: the serializable artifact this plan came from (provenance: request
    #: hash, solver entry, solve wall-time); None only for hand-built plans.
    plan: Plan | None = None

    @property
    def speedup_vs_round_robin(self) -> float:
        return (self.solution.result.throughput_fps
                / self.round_robin.throughput_fps)

    def assignment_of(self, tenant: str) -> tuple[str, ...]:
        i = self._idx(tenant)
        return self.solution.workloads[i].assignment

    def phase_assignment(self, tenant: str) -> dict[str, tuple[str, ...]]:
        npf = self.n_prefill_groups[tenant]
        asg = self.assignment_of(tenant)
        return {"prefill": asg[:npf], "decode": asg[npf:]}

    def predicted_decode_step_ms(self, tenant: str) -> float:
        """Schedule-predicted latency of one batched decode step (ms)."""
        i = self._idx(tenant)
        npf = self.n_prefill_groups[tenant]
        dur = sum(iv.end - iv.start
                  for iv in self.solution.result.timeline
                  if iv.workload == i and iv.group >= npf)
        n_steps = self.specs[i].max_new * self.iterations[i]
        return dur / n_steps if n_steps else 0.0

    def _idx(self, tenant: str) -> int:
        for i, s in enumerate(self.specs):
            if s.name == tenant:
                return i
        raise KeyError(tenant)

    def summary(self) -> str:
        sol, rr = self.solution.result, self.round_robin
        rows = [f"objective={self.solution.kind} "
                f"optimal={self.solution.optimal}",
                f"  {'round-robin':18s} lat={rr.latency_ms:9.3f}ms "
                f"fps={rr.throughput_fps:8.1f}",
                f"  {'haxconn':18s} lat={sol.latency_ms:9.3f}ms "
                f"fps={sol.throughput_fps:8.1f} "
                f"({100 * (self.speedup_vs_round_robin - 1):+.1f}% fps)"]
        for s in self.specs:
            ph = self.phase_assignment(s.name)
            rows.append(f"    {s.name}: prefill->{set(ph['prefill'])} "
                        f"decode->{set(ph['decode'])} "
                        f"step={self.predicted_decode_step_ms(s.name):.3f}ms")
        return "\n".join(rows)


def round_robin_workloads(platform: Platform, graphs: Sequence[DNNGraph],
                          iterations: Sequence[int]) -> list[Workload]:
    """Naive multi-tenant baseline: whole model *i* on accelerator *i % n*,
    both phases pinned together, no contention awareness."""
    names = platform.names
    return [Workload(g, (names[i % len(names)],) * len(g),
                     iterations=iterations[i])
            for i, g in enumerate(graphs)]


def plan_gateway(specs: Sequence[TenantSpec],
                 gcfg: GatewayConfig = GatewayConfig(),
                 iterations: Sequence[int] | None = None,
                 deadline_s: float | None = 20.0,
                 scheduler: Scheduler | None = None) -> GatewayPlan:
    """Contention-aware (model, phase) -> accelerator plan for all tenants.

    ``scheduler`` lets a control plane share one plan cache across tenant
    churn (and pre-load serialized :class:`Plan` artifacts so booting the
    gateway performs zero solver invocations); when given, its platform and
    model override ``gcfg.platform``/``gcfg.model``.
    """
    sched = scheduler or Scheduler(gcfg.platform, gcfg.model)
    plat = sched.platform
    graphs = [tenant_phase_graph(s, plat, gcfg.body_groups) for s in specs]
    npf = {}
    for s, g in zip(specs, graphs):
        npf[s.name] = sum(1 for gr in g.groups
                          if gr.name.startswith("prefill:"))
    its = list(iterations or [1] * len(specs))
    plan = sched.resolve(sched.request(
        graphs, gcfg.objective, solver=gcfg.solver,
        max_transitions=gcfg.max_transitions,
        iterations=its, deadline_s=deadline_s,
        solver_knobs=dict(gcfg.solver_knobs)))
    sol = plan.solution
    # re-simulate with the timeline recorded — predicted per-step latencies
    # are read off the decode-group intervals.
    res = simulate(plat, sol.workloads, sched.model, record_timeline=True)
    sol = Solution(sol.workloads, res, sol.objective, sol.kind,
                   sol.evaluated, sol.optimal, params=dict(sol.params))
    rr = simulate(plat, round_robin_workloads(plat, graphs, its),
                  sched.model, record_timeline=False)
    return GatewayPlan(plat, list(specs), graphs, its, sol, rr, npf,
                       plan=plan)


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

@dataclass
class RescheduleEvent:
    step: int
    tenants: tuple[str, ...]
    observed_factor: float
    old_objective: float
    new_objective: float
    changed: bool


@dataclass
class GatewayStepReport:
    step: int
    active: dict[str, int]
    kv_bytes_in_use: int
    fired: tuple[str, ...]
    rescheduled: bool


class MultiTenantGateway:
    """Admits and serves requests for several models concurrently under one
    contention-aware schedule and one shared memory budget."""

    def __init__(self, specs: Sequence[TenantSpec],
                 gcfg: GatewayConfig = GatewayConfig(),
                 iterations: Sequence[int] | None = None,
                 deadline_s: float | None = 20.0, seed: int = 0,
                 scheduler: Scheduler | None = None):
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("duplicate tenant names")
        for s in specs:
            if not s.cfg.has_decode:
                raise ValueError(
                    f"tenant {s.name!r}: {s.cfg.name} is encoder-only — "
                    f"the gateway serves decode workloads")
        self.specs = {s.name: s for s in specs}
        self.gcfg = gcfg
        # bounded cache: the gateway re-solves at runtime-observed
        # severities indefinitely, so its private cache must not grow
        # without limit (a shared scheduler manages its own policy).
        self.scheduler = scheduler or Scheduler(
            gcfg.platform, gcfg.model, cache=PlanCache(max_entries=256))
        self.plan = plan_gateway(specs, gcfg, iterations, deadline_s,
                                 scheduler=self.scheduler)
        self._base_model = self.scheduler.model
        self.engines: dict[str, ServingEngine] = {}
        for i, s in enumerate(specs):
            m = build(s.cfg)
            params = m.init(jax.random.PRNGKey(seed + i))
            self.engines[s.name] = ServingEngine(
                m, params, max_slots=s.max_slots, capacity=s.capacity,
                admission_gate=lambda req, name=s.name: self._gate(name, req))
        self.monitors = {
            s.name: SlowdownMonitor(threshold=gcfg.slowdown_threshold,
                                    patience=gcfg.patience,
                                    cooldown=gcfg.cooldown,
                                    warmup=gcfg.warmup)
            for s in specs}
        #: fastest observed step per tenant — the wall-clock calibration
        #: anchor (simulated predicted ms and CPU wall ms are incommensurate;
        #: deviation from the own steady-state floor is the §4.4 signal).
        self._floor_ms: dict[str, float] = {}
        self.total_steps = 0
        self.deferred_admissions = 0
        self.reschedules: list[RescheduleEvent] = []

    # ---- admission ----------------------------------------------------
    @property
    def kv_bytes_in_use(self) -> int:
        return sum(self.engines[n].active * s.kv_bytes_per_slot
                   for n, s in self.specs.items())

    def _gate(self, tenant: str, req: Request) -> bool:
        budget = self.gcfg.memory_budget_bytes
        if budget is None:
            return True
        ok = (self.kv_bytes_in_use
              + self.specs[tenant].kv_bytes_per_slot) <= budget
        if not ok:
            self.deferred_admissions += 1
        return ok

    # ---- request path -------------------------------------------------
    def submit(self, tenant: str, prompt, max_new: int | None = None,
               eos: int | None = None) -> Request:
        spec = self.specs[tenant]
        return self.engines[tenant].submit(
            prompt, max_new=max_new or spec.max_new, eos=eos)

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.engines.values())

    def step(self, observed_ms: Mapping[str, float] | None = None
             ) -> GatewayStepReport:
        """Multiplex one non-blocking decode step across every tenant.

        ``observed_ms`` overrides the wall-clock measurement per tenant —
        tests and replay harnesses inject deviations through it.
        """
        self.total_steps += 1
        fired: list[str] = []
        active: dict[str, int] = {}
        for name, eng in self.engines.items():
            if not eng.has_work:
                active[name] = 0
                continue
            active[name] = eng.step()
            obs = (observed_ms or {}).get(name, eng.counters.last_step_ms)
            if active[name] == 0 or obs <= 0.0:
                continue
            floor = self._floor_ms.get(name)
            # slowly-decaying minimum: one outlier-fast step cannot anchor
            # the baseline forever and poison the ratio stream.
            floor = obs if floor is None else min(floor * 1.02, obs)
            self._floor_ms[name] = floor
            if self.monitors[name].observe(obs, floor):
                fired.append(name)
        rescheduled = False
        if fired:
            rescheduled = self._reschedule(tuple(fired))
        return GatewayStepReport(self.total_steps, active,
                                 self.kv_bytes_in_use, tuple(fired),
                                 rescheduled)

    def run_until_drained(self, max_steps: int = 10000
                          ) -> dict[str, list[Request]]:
        while self.has_work and self.total_steps < max_steps:
            self.step()
        return {n: e.completed for n, e in self.engines.items()}

    def metrics(self) -> dict:
        """Telemetry snapshot: one ``tenants`` row per engine in the
        canonical :data:`~repro.serve.engine.METRIC_KEYS` shape plus
        gateway-level aggregates — the same format the fleet loop
        (:mod:`repro.serve.fleet`) consumes and re-emits."""
        tenants = {n: e.metrics() for n, e in self.engines.items()}
        return conform(GATEWAY_SCHEMA, {
            "steps": self.total_steps,
            "kv_bytes_in_use": self.kv_bytes_in_use,
            "deferred_admissions": self.deferred_admissions,
            "reschedules": len(self.reschedules),
        }, tenants=tenants)

    # ---- dynamic loop -------------------------------------------------
    def _reschedule(self, tenants: tuple[str, ...]) -> bool:
        """Re-solve under the observed contention severity (§4.4).

        The incumbent schedule is re-evaluated under the same scaled model
        and kept unless the bounded re-solve genuinely improves on it — a
        budget-starved solver slice must never replace a good plan with a
        naive one.  Both objectives in the recorded event are therefore
        commensurate (same contention model).
        """
        # quantized once, up front: the incumbent re-evaluation and the
        # re-solve must price contention under the *same* model or their
        # objectives are incommensurate.
        factor = quantize_severity(
            max(self.monitors[n].ratio for n in tenants))
        model = ScaledContentionModel(self._base_model, factor)
        old = self.plan.solution
        cur_res = simulate(self.plan.platform, old.workloads, model,
                           record_timeline=True)
        cur_obj = cur_res.objective(self.gcfg.objective)
        rplan = reschedule_plan(
            self.scheduler, self.plan.graphs, factor,
            objective=self.gcfg.objective,
            max_transitions=self.gcfg.max_transitions,
            iterations=self.plan.iterations,
            budget_s=self.gcfg.reschedule_budget_s)
        best = rplan.solution
        if best.objective < cur_obj - 1e-9:
            res = simulate(self.plan.platform, best.workloads, model,
                           record_timeline=True)
            new = Solution(best.workloads, res, best.objective,
                           best.kind, best.evaluated, best.optimal)
            art = rplan          # provenance follows the adopted schedule
        else:
            new = Solution(old.workloads, cur_res, cur_obj, old.kind,
                           best.evaluated, False)
            art = self.plan.plan
        changed = new.assignments != old.assignments
        self.reschedules.append(RescheduleEvent(
            self.total_steps, tenants, factor, cur_obj, new.objective,
            changed))
        get_tracer().instant("gateway.reschedule", "dynamic",
                             step=self.total_steps,
                             tenants=",".join(tenants), factor=factor,
                             changed=changed)
        get_registry().counter(
            "gateway_reschedules",
            "§4.4 slowdown-triggered re-schedules").labels(
                changed=str(changed).lower()).inc()
        self.plan = dataclasses.replace(self.plan, solution=new, plan=art)
        for n in tenants:
            self.monitors[n].reset()
            # the post-adaptation steady state becomes the new baseline
            self._floor_ms.pop(n, None)
        return changed

"""Training loop: microbatched grad accumulation, pjit, checkpoint/restart.

``make_train_step`` builds the jit-able step: loss+grad over
``cfg.microbatches`` microbatches via lax.scan (bounds activation/logits
memory — the global batch never materializes at once), global-norm clip,
optimizer update.  ``Trainer`` wraps it with data, checkpointing (periodic +
emergency-on-signal), restart (bitwise-resumable thanks to the counter-mode
pipeline), and elastic restore onto a different mesh.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model, sharding
from . import checkpoint as ckpt_lib
from . import optimizer as opt_lib


@dataclass
class TrainState:
    step: jnp.ndarray
    params: Any
    opt: Any


def make_train_step(model: Model, optimizer: opt_lib.Optimizer,
                    microbatches: int = 1) -> Callable:
    cfg = model.cfg

    def train_step(state: TrainState, batch):
        def loss_of(params, mb):
            return model.loss_fn(params, mb)

        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_of, has_aux=True)(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state.params, batch)

        grads, gnorm = opt_lib.clip_by_global_norm(grads, cfg.grad_clip)
        updates, new_opt = optimizer.update(grads, state.opt, state.params,
                                            state.step)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            state.params, updates)
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm)
        return TrainState(state.step + 1, new_params, new_opt), out_metrics

    return train_step


jax.tree_util.register_dataclass(TrainState, ("step", "params", "opt"), ())


class Trainer:
    """Fault-tolerant single-controller training driver."""

    def __init__(self, model: Model, data, ckpt_dir: str | None = None,
                 ckpt_every: int = 50, mesh=None):
        self.model = model
        cfg = model.cfg
        lr = opt_lib.warmup_cosine(cfg.learning_rate)
        self.optimizer = opt_lib.make(cfg.optimizer, lr,
                                      **({"weight_decay": cfg.weight_decay}
                                         if cfg.optimizer == "adamw" else {}))
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.mesh = mesh
        self.step_fn = jax.jit(make_train_step(model, self.optimizer,
                                               cfg.microbatches),
                               donate_argnums=(0,))
        self.state: TrainState | None = None
        self._interrupted = False

    # ------------------------------------------------------------------
    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        opt = self.optimizer.init(params)
        self.state = TrainState(jnp.zeros((), jnp.int32), params, opt)
        return self.state

    def restore_or_init(self, key) -> TrainState:
        if self.ckpt_dir and ckpt_lib.latest_step(self.ckpt_dir) is not None:
            like = jax.eval_shape(lambda: TrainState(
                jnp.zeros((), jnp.int32),
                self.model.abstract_params(),
                self.optimizer.init(self.model.abstract_params())))
            self.state, _ = ckpt_lib.restore(self.ckpt_dir, like)
            return self.state
        return self.init_state(key)

    # ------------------------------------------------------------------
    def _install_signal_handler(self):
        def handler(signum, frame):   # emergency checkpoint on preemption
            self._interrupted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:            # non-main thread (tests)
            pass

    def run(self, steps: int, log_every: int = 10,
            on_metrics=None) -> list[dict]:
        assert self.state is not None, "call restore_or_init first"
        self._install_signal_handler()
        history = []
        t0 = time.perf_counter()
        start = int(self.state.step)
        for step in range(start, steps):
            batch = self.data.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.step_fn(self.state, batch)
            if self._interrupted:
                if self.ckpt_dir:
                    ckpt_lib.save(self.ckpt_dir, int(self.state.step),
                                  self.state)
                raise KeyboardInterrupt("preempted; emergency ckpt saved")
            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                ckpt_lib.save(self.ckpt_dir, int(self.state.step), self.state)
            if (step + 1) % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                if on_metrics:
                    on_metrics(m)
        if self.ckpt_dir:
            ckpt_lib.save(self.ckpt_dir, int(self.state.step), self.state)
        return history

"""Optimizers in pure JAX: AdamW and Adafactor (+ clip, schedules).

Optimizer states are pytrees mirroring the params, so they inherit the
params' shardings under pjit (FSDP shards optimizer state for free).
Adafactor keeps factored second moments for >=2-D weights — the memory
choice for the 132B/235B MoE configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int = 100,
                  total: int = 10000, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, step) -> (upd, state)


def adamw(lr: Callable | float, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** step_f
        bc2 = 1.0 - b2 ** step_f
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        lr_t = lr_fn(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"m": m, "v": v}

    return Optimizer(init, update)


def adafactor(lr: Callable | float, eps=1e-30, clip_threshold=1.0,
              decay=0.8, weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return jax.tree.map(one, params,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def update(grads, state, params, step):
        beta = 1.0 - (jnp.asarray(step, jnp.float32) + 1.0) ** -decay
        lr_t = lr_fn(step)

        def one(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                rhat = (vr / denom)[..., None]
                u = gf / (jnp.sqrt(rhat * vc[..., None, :]) + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), new_s

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = tree.flatten_up_to(state)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        upds = tree.unflatten([o[0] for o in out])
        new_state = tree.unflatten([o[1] for o in out])
        return upds, new_state

    return Optimizer(init, update)


def make(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(name)

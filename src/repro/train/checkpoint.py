"""Fault-tolerant checkpointing: atomic, mesh-agnostic, elastic.

Checkpoints store host-side numpy arrays keyed by pytree path, plus the
step and data-pipeline cursor, in a single .npz written atomically
(tmp + rename) with a rolling ``latest`` pointer and configurable keep
count.  Because arrays are stored unsharded, a restore may target a mesh of
a *different* shape (elastic scaling): arrays are re-placed with the new
shardings at load time.  An emergency save hook covers preemption.
"""
from __future__ import annotations

import os
import pathlib
import re
import tempfile
from typing import Any

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):       # GetAttrKey (dataclass fields)
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str | pathlib.Path, step: int, state: Any,
         keep: int = 3) -> pathlib.Path:
    """Atomic save of ``state`` (any pytree) at ``step``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    flat["__step__"] = np.asarray(step)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        final = ckpt_dir / f"ckpt_{step:08d}.npz"
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    (ckpt_dir / "latest.tmp").write_text(final.name)
    os.replace(ckpt_dir / "latest.tmp", ckpt_dir / "latest")
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    ckpts = sorted(ckpt_dir.glob("ckpt_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink()


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ptr = ckpt_dir / "latest"
    if not ptr.exists():
        return None
    m = re.match(r"ckpt_(\d+)\.npz", ptr.read_text().strip())
    return int(m.group(1)) if m else None


def restore(ckpt_dir: str | pathlib.Path, like: Any,
            shardings: Any | None = None, step: int | None = None):
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding matching ``like`` —
    restoring onto a different mesh (elastic rescale) re-places arrays
    under the new shardings; with None, arrays land on the default device.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    data = np.load(ckpt_dir / f"ckpt_{step:08d}.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        arr = data[_path_key(path)]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{_path_key(path)}: checkpoint shape "
                             f"{arr.shape} != model shape {leaf.shape}")
        if shard is not None:
            leaves.append(jax.device_put(arr.astype(leaf.dtype), shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(leaves), step

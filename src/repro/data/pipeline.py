"""Deterministic, resumable, sharded synthetic LM data pipeline.

Every batch is a pure function of (seed, step, dp_rank) — a counter-mode
PRNG stream — so:
  * restart-from-checkpoint replays the exact token stream (bitwise
    resumability, tested),
  * no host state needs checkpointing beyond the step counter,
  * a straggling/replaced host can regenerate any shard on demand
    (straggler recovery without data redistribution),
  * elastic rescale re-partitions rank streams deterministically.

Batches are Zipf-distributed token ids (vocab-shaped like natural text)
with next-token labels; a file-backed reader with the same interface covers
real corpora.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


class SyntheticLM:
    """Counter-mode synthetic LM stream."""

    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1):
        if cfg.global_batch % dp_size:
            raise ValueError("global_batch must divide by dp_size")
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.local_batch = cfg.global_batch // dp_size

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, step, self.dp_rank]))
        # Zipf over the vocab, clipped; heavier head like text
        toks = rng.zipf(cfg.zipf_a,
                        size=(self.local_batch, cfg.seq_len + 1))
        toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
        return {"token_ids": toks[:, :-1],
                "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileBackedLM:
    """Same interface over a flat token file (np.memmap of int32)."""

    def __init__(self, path: str, cfg: DataConfig, dp_rank: int = 0,
                 dp_size: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.local_batch = cfg.global_batch // dp_size
        self._per_step = cfg.global_batch * (cfg.seq_len + 1)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        base = (step * self._per_step
                + self.dp_rank * self.local_batch * (cfg.seq_len + 1))
        n = self.local_batch * (cfg.seq_len + 1)
        flat = np.array(self.tokens[base % (len(self.tokens) - n):]
                        [:n]).reshape(self.local_batch, cfg.seq_len + 1)
        return {"token_ids": flat[:, :-1].astype(np.int32),
                "labels": flat[:, 1:].astype(np.int32)}


def device_put_batch(batch, mesh, rules):
    """Host numpy batch -> globally-sharded jax arrays on the mesh."""
    from repro.models import sharding
    out = {}
    for k, v in batch.items():
        logical = ("batch", "seq") if v.ndim == 2 else ("batch", "seq", None)
        out[k] = jax.device_put(
            v, sharding.named_sharding(mesh, rules, logical))
    return out

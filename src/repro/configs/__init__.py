"""Architecture registry: --arch <id> resolution for launchers and tests."""
from importlib import import_module

from .base import (RULES_FSDP_TP, RULES_TP, RULES_TP_2D,  # noqa: F401
                   RULES_ZERO3)
from .base import SHAPES, ModelConfig, MoEConfig, ShapeCell, cell_supported  # noqa: F401

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-7b": "rwkv6_7b",
    "internvl2-2b": "internvl2_2b",
    "stablelm-1.6b": "stablelm_1_6b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama3.2-3b": "llama3_2_3b",
    "hubert-xlarge": "hubert_xlarge",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
}

ARCHS = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG

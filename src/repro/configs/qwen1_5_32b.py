"""qwen1.5-32b [dense]: QKV bias, MHA-like GQA (kv=40).

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064 [hf:Qwen/Qwen1.5].
40 heads do not divide the 16-way model axis: GSPMD pads the head axis
(visible as useful-flops ratio loss in the roofline; a hillclimb lever).
int8 KV cache keeps decode_32k under 16 GB/chip (40 kv heads x 64 layers).
"""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    act="swiglu",
    kv_cache_dtype="int8",
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

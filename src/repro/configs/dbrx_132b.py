"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
[hf:databricks/dbrx-base].  Adafactor for optimizer-state memory; expert
weights 2-D sharded for serving (experts->model, expert_mlp->data).
"""
from .base import MoEConfig, ModelConfig, RULES_TP_2D

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4),
    act="swiglu",
    optimizer="adafactor",
    serve_rules=dict(RULES_TP_2D),
    microbatches=16,
)

"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, local window 2048
[arXiv:2402.19427].  38 = 12 full (rglru, rglru, local) supergroups + 2
tail recurrent layers.  Sub-quadratic -> long_500k runs.
"""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    act="swiglu",
    tie_embeddings=True,
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

"""rwkv6-7b [ssm] "Finch": attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536 [arXiv:2404.05892].  Head size 64
(64 heads); time-mix + channel-mix per layer.  Sub-quadratic -> long_500k.
"""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                 # rwkv6 head size 64
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    block_pattern=("rwkv",),
    act="squared_relu",         # rwkv channel-mix uses relu^2 internally
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

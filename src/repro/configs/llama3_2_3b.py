"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama].  rope theta 500k, tied embeddings."""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    act="swiglu",
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

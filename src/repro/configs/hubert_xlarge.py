"""hubert-xlarge [audio]: encoder-only, bidirectional attention.

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (k-means units)
[arXiv:2106.07447].  The CNN feature extractor is a STUB per the
assignment: input_specs() provides precomputed frame embeddings.
Encoder-only -> no decode shapes.
"""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    bidirectional=True,
    embeds_only=True,
    act="gelu",
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

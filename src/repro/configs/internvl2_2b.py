"""internvl2-2b [vlm]: InternViT frontend (stubbed) + InternLM2-1.8b backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821].
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (1024-dim InternViT features) that a learned
projector maps into the first mm_prefix positions.
"""
from .base import ModelConfig, RULES_ZERO3

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    mm_prefix=1024,
    mm_embed_dim=1024,
    act="swiglu",
    microbatches=1,
    rules=dict(RULES_ZERO3),
)

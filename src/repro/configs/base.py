"""Model/run configuration system.

One :class:`ModelConfig` fully describes an architecture (family, dims,
block pattern, MoE, modality stubs), its numerics (dtypes, remat, scan) and
its sharding rules (logical-axis -> mesh-axis mapping, MaxText style).  Every
assigned architecture ships a full config and a reduced ``smoke()`` config of
the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Sequence

# ---------------------------------------------------------------------------
# Sharding rule sets: logical axis -> mesh axis (or tuple / None).
# "fsdp" style additionally shards the big weight dim over the data axis.
# ---------------------------------------------------------------------------
RULES_TP = {
    "batch": ("pod", "data"),
    "seq": None,
    # decode KV-cache sequence axis: always divisible by the model axis
    # (32k/512k/window), unlike small GQA head counts -> shard it there.
    "kv_seq": "model",
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    #: MoE dispatch-buffer capacity dim — sharding it over data keeps the
    #: (E, C, d) buffers from replicating across the data axis.
    "expert_capacity": "data",
    "rnn": "model",
    "layers": None,
}
RULES_FSDP_TP = dict(RULES_TP, embed="data")
#: serving variant for very large models: expert/mlp inner dim additionally
#: sharded over the data axis (2-D weight sharding).
RULES_TP_2D = dict(RULES_TP, expert_mlp="data")
#: ZeRO-3 / fully-data-parallel training: both mesh axes act as data
#: parallelism, parameters are stored fully sharded (over data+model on
#: their "embed" dim) and gathered per layer at use (weight_use), so the
#: per-layer Megatron TP activation all-reduces disappear entirely.  The
#: right regime for <=32B dense models at 4k sequence on 256 chips.
RULES_ZERO3 = {
    "batch": ("pod", "data", "model"),
    "seq": None,
    "kv_seq": "model",
    "embed": ("data", "model"),
    "vocab": None,
    "heads": None,
    "kv_heads": None,
    "head_dim": None,
    "mlp": None,
    "experts": None,
    "expert_mlp": None,
    "expert_capacity": ("data", "model"),
    "rnn": None,
    "layers": None,
}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    moe: MoEConfig | None = None
    #: repeating block pattern; "attn" | "local" | "rglru" | "rwkv".
    block_pattern: tuple[str, ...] = ("attn",)
    bidirectional: bool = False     # encoder-only (no causal mask, no decode)
    local_window: int = 2048
    act: str = "swiglu"             # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    #: RG-LRU branch width (hybrid family); 0 -> d_model.
    d_rnn: int = 0
    #: multimodal stub: first mm_prefix positions take precomputed embeddings
    #: (projected from mm_embed_dim); used by [vlm].  [audio]/encoder uses
    #: embeds-only input (no token ids) when embeds_only is set.
    mm_prefix: int = 0
    mm_embed_dim: int = 0
    embeds_only: bool = False
    # ---- numerics & memory ----
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    kv_cache_dtype: str = "bfloat16"    # or "int8"
    attn_block_q: int = 1024
    attn_block_kv: int = 1024
    # ---- distribution ----
    rules: Mapping[str, object] = field(
        default_factory=lambda: dict(RULES_FSDP_TP))
    serve_rules: Mapping[str, object] = field(
        default_factory=lambda: dict(RULES_TP))
    # ---- training ----
    microbatches: int = 1
    optimizer: str = "adamw"        # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    z_loss: float = 1e-4

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        for b in self.block_pattern:
            if b not in ("attn", "local", "rglru", "rwkv"):
                raise ValueError(f"unknown block kind {b!r}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")

    # ------------------------------------------------------------------
    @property
    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer uses full (global) attention — long_500k eligible."""
        return all(k in ("local", "rglru", "rwkv") for k in self.layer_kinds)

    @property
    def has_decode(self) -> bool:
        return not self.bidirectional

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += V * d                  # unembedding
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                total += d * hq * dh + 2 * d * hkv * dh + hq * dh * d
                if self.qkv_bias:
                    total += (hq + 2 * hkv) * dh
            elif kind == "rglru":
                r = self.d_rnn
                total += 2 * d * r + r * d   # in / gate / out projections
                total += 2 * r * r           # recurrence + input gates
                total += 8 * r               # conv1d(4) + Λ + biases
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o projections
                total += 2 * d              # decay/bonus params per channel
            # channel mix / MLP
            if self.moe is not None:
                total += d * self.moe.n_experts  # router
                n_mats = 3 if self.act == "swiglu" else 2
                total += self.moe.n_experts * n_mats * d * ff
            else:
                n_mats = 3 if self.act == "swiglu" else 2
                total += n_mats * d * ff
            total += 2 * d                  # norms
        return total

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        n_mats = 3 if self.act == "swiglu" else 2
        per_layer_experts = self.moe.n_experts * n_mats * self.d_model * self.d_ff
        active = (self.moe.top_k / self.moe.n_experts) * per_layer_experts
        return int(full - self.n_layers * per_layer_experts
                   + self.n_layers * active)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test sibling: same family/pattern, tiny dims."""
        pat = self.block_pattern
        small = dict(
            n_layers=max(2, 2 * len(pat)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_head=16,
            d_ff=128,
            vocab=256,
            d_rnn=64,
            local_window=32,
            mm_prefix=4 if self.mm_prefix else 0,
            mm_embed_dim=32 if self.mm_embed_dim else 0,
            dtype="float32",
            param_dtype="float32",
            kv_cache_dtype="float32",
            microbatches=1,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            # generous capacity so reduced-config decode is drop-free and
            # prefill+decode consistency is exact
            small["moe"] = MoEConfig(n_experts=4, top_k=2,
                                     capacity_factor=8.0)
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shape cells (assigned): every LM arch is paired with all four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    cell = SHAPES[shape]
    if cell.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k":
        if any(k == "attn" for k in cfg.layer_kinds):
            return False, ("pure full-attention arch: 512k decode requires "
                           "sub-quadratic attention")
    return True, ""

"""qwen3-moe-235b-a22b [moe]: 128 experts top-8.

94L d_model=4096 64H (GQA kv=4) d_head=128 d_ff=1536 vocab=151936
[hf:Qwen/Qwen3].  Largest assigned arch: FSDP+TP training sharding,
Adafactor, 2-D expert sharding for serving.
"""
from .base import MoEConfig, ModelConfig, RULES_TP_2D

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8),
    act="swiglu",
    optimizer="adafactor",
    serve_rules=dict(RULES_TP_2D),
    microbatches=16,
)

"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

    PYTHONPATH=src python -m repro.analysis.report [--dir artifacts/dryrun]

Prints markdown to stdout; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
import pathlib

ARCH_ORDER = [
    "recurrentgemma-9b", "rwkv6-7b", "internvl2-2b", "stablelm-1.6b",
    "nemotron-4-15b", "qwen1.5-32b", "llama3.2-3b", "hubert-xlarge",
    "dbrx-132b", "qwen3-moe-235b-a22b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: pathlib.Path, mesh: str):
    recs = {}
    for f in dir_.glob(f"*_{mesh}.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_ms(x):
    if x >= 1000:
        return f"{x / 1e3:.2f}s"
    return f"{x:.1f}ms"


def roofline_table(recs) -> str:
    out = ["| arch | shape | status | t_compute | t_memory | t_collective |"
           " bound | useful (6ND/HLO) | frac | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                out.append(f"| {arch} | {shape} | MISSING | | | | | | | |")
                continue
            if r["status"] == "skip":
                out.append(f"| {arch} | {shape} | SKIP | | | | | | | "
                           f"{r['reason']} |")
                continue
            rl = r["roofline"]
            out.append(
                f"| {arch} | {shape} | ok | {fmt_ms(rl['t_compute_ms'])} "
                f"| {fmt_ms(rl['t_memory_ms'])} "
                f"| {fmt_ms(rl['t_collective_ms'])} | {rl['bottleneck']} "
                f"| {rl['model_flops_ratio']:.2f} "
                f"| {rl['roofline_fraction']:.3f} ({rl['useful_metric']}) "
                f"| {rl['what_would_help'][:58]} |")
    return "\n".join(out)


def dryrun_table(recs, mesh) -> str:
    out = [f"| arch | shape | compile | GB/chip (arg+tmp+out) | collectives |",
           "|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None or r["status"] != "ok":
                reason = "" if r is None else r.get("reason", "")
                out.append(f"| {arch} | {shape} | {'SKIP' if r else 'MISSING'}"
                           f" | | {reason} |")
                continue
            ops = r["roofline"]["collective_ops"]
            opstr = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                             for k, v in sorted(ops.items()))
            out.append(f"| {arch} | {shape} | {r['compile_s']:.1f}s "
                       f"| {r['memory']['peak_estimate_gb']:.2f} | {opstr} |")
    return "\n".join(out)


def summary_stats(recs) -> str:
    oks = [r for r in recs.values() if r["status"] == "ok"]
    skips = [r for r in recs.values() if r["status"] == "skip"]
    bounds = {}
    for r in oks:
        b = r["roofline"]["bottleneck"]
        bounds[b] = bounds.get(b, 0) + 1
    fr = sorted((r["roofline"]["roofline_fraction"],
                 r["arch"], r["shape"]) for r in oks)
    lines = [f"- cells compiled: {len(oks)}; skipped per assignment rules: "
             f"{len(skips)}",
             f"- bottleneck split: {bounds}",
             f"- worst roofline fraction: {fr[0][0]:.3f} "
             f"({fr[0][1]} × {fr[0][2]})",
             f"- best roofline fraction: {fr[-1][0]:.3f} "
             f"({fr[-1][1]} × {fr[-1][2]})"]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(pathlib.Path(args.dir), args.mesh)
    print(f"### Roofline ({args.mesh}-pod mesh)\n")
    print(summary_stats(recs) + "\n")
    print(roofline_table(recs) + "\n")
    print(f"### Dry-run ({args.mesh}-pod mesh)\n")
    print(dryrun_table(recs, args.mesh))


if __name__ == "__main__":
    main()

"""Roofline term extraction from compiled dry-run artifacts.

This container is CPU-only; TPU v5e is the *target*.  The three terms come
from the compiled SPMD module (which is per-device after GSPMD
partitioning — ``cost_analysis`` flops/bytes and HLO collective shapes are
already per-chip):

    t_compute    = flops_per_chip / peak_FLOPs
    t_memory     = bytes_per_chip / HBM_bw
    t_collective = collective_bytes_per_chip / ICI_link_bw

``collective_bytes`` is not in cost_analysis: we parse the compiled HLO
text and sum operand sizes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute (operand size derived from
the printed result shape and the replica group size).  Collectives whose
replica groups cross the pod axis are tagged DCN (multi-pod mesh).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    op_counts: dict
    operand_bytes: float          # Σ operand sizes (per device)
    moved_bytes: float            # ring-algorithm traffic estimate
    top: list = None              # largest ops: (op, bytes, shape)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    operand_bytes = 0.0
    moved = 0.0
    top: list = []
    for line in hlo_text.splitlines():
        if "all-reduce" not in line and "all-gather" not in line \
                and "reduce-scatter" not in line and "all-to-all" not in line \
                and "collective-permute" not in line:
            continue
        m = _COLL_RE.search(line)
        shapes = []
        if m:
            op = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            op = mt.group(2)
            shapes = _SHAPE_RE.findall(mt.group(1))
        if line.strip().startswith("%") and "-done" in line.split("=")[0]:
            continue                    # async -done pairs with -start
        gm = _GROUPS_RE.search(line)
        gsize = int(gm.group(2)) if gm else 1
        res = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if op == "all-reduce":
            operand = res
            ring = 2 * res * (gsize - 1) / max(gsize, 1)
        elif op == "all-gather":
            operand = res / max(gsize, 1)
            ring = res * (gsize - 1) / max(gsize, 1)
        elif op == "reduce-scatter":
            operand = res * gsize
            ring = res * (gsize - 1)
        elif op == "all-to-all":
            operand = res
            ring = res * (gsize - 1) / max(gsize, 1)
        else:                           # collective-permute
            operand = res
            ring = res
        counts[op] = counts.get(op, 0) + 1
        operand_bytes += operand
        moved += ring
        top.append((op, operand, "/".join(f"{dt}[{dims}]"
                                          for dt, dims in shapes)))
    top.sort(key=lambda t: -t[1])
    return CollectiveStats(counts, operand_bytes, moved, top[:8])


@dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    t_compute_ms: float
    t_memory_ms: float
    t_collective_ms: float
    t_dominant_ms: float
    bottleneck: str
    model_flops: float
    model_flops_ratio: float     # MODEL_FLOPS / (flops_per_chip * chips)
    roofline_fraction: float     # useful-time / dominant-term (MFU/MBU proxy)
    useful_metric: str
    collective_ops: dict
    what_would_help: str = ""


def analyze(cost: dict, coll: CollectiveStats, n_chips: int,
            model_flops: float, useful_bytes_per_chip: float | None = None,
            kind: str = "train") -> Roofline:
    flops_pd = float(cost.get("flops", 0.0))
    bytes_pd = float(cost.get("bytes accessed", 0.0))
    t_c = flops_pd / PEAK_FLOPS
    t_m = bytes_pd / HBM_BW
    t_x = coll.operand_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    t_dom = terms[bottleneck]
    ratio = model_flops / max(flops_pd * n_chips, 1.0)

    if kind == "decode" and useful_bytes_per_chip:
        # decode is memory-bound by nature: usefulness = model-bytes / HBM
        useful_t = useful_bytes_per_chip / HBM_BW
        metric = "MBU"
    else:
        useful_t = model_flops / (n_chips * PEAK_FLOPS)
        metric = "MFU"
    frac = useful_t / max(t_dom, 1e-30)

    help_ = {
        "compute": "reduce non-model flops (remat/padding waste) or raise "
                   "MXU utilization via larger per-chip tiles",
        "memory": "cut HBM traffic: fuse, microbatch less aggressively, "
                  "quantize cache/weights, better layouts",
        "collective": "reshard to shrink collective operands, overlap "
                      "collectives with compute, or move the axis to ICI-"
                      "cheaper dims",
    }[bottleneck]
    return Roofline(
        flops_per_chip=flops_pd, bytes_per_chip=bytes_pd,
        coll_bytes_per_chip=coll.operand_bytes,
        t_compute_ms=t_c * 1e3, t_memory_ms=t_m * 1e3,
        t_collective_ms=t_x * 1e3, t_dominant_ms=t_dom * 1e3,
        bottleneck=bottleneck, model_flops=model_flops,
        model_flops_ratio=ratio, roofline_fraction=min(frac, 1.0),
        useful_metric=metric, collective_ops=coll.op_counts,
        what_would_help=help_,
    )


def to_dict(r: Roofline) -> dict:
    return asdict(r)


# ---------------------------------------------------------------------------
# Analytic HBM traffic model (TPU-fusion assumption).
#
# The dry-run probes compile on the CPU backend, whose near-absent fusion
# materializes every HLO op — "bytes accessed" overstates TPU HBM traffic by
# 1-2 orders of magnitude.  The memory roofline term instead uses this
# analytic model (every materialized tensor between fused regions counted
# once, MaxText-napkin style); the probe bytes are kept in the artifact as
# ``bytes_xla_probe`` for reference.  flops and collective bytes come from
# the probes (backend-independent: same HLO math, same SPMD partitioner).
# ---------------------------------------------------------------------------

def analytic_hbm_bytes(cfg, cell) -> float:
    """Global HBM bytes per step (sum over chips)."""
    B, S = cell.global_batch, cell.seq_len
    train = cell.kind == "train"
    decode = cell.kind == "decode"
    tokens = B * (1 if decode else S)
    act_b = 2 if cfg.dtype == "bfloat16" else 4
    pd_b = 4 if cfg.param_dtype == "float32" else 2
    kv_b = 1 if cfg.kv_cache_dtype == "int8" else act_b
    M = cfg.microbatches if train else 1
    n = cfg.n_params()
    n_active = cfg.n_active_params()

    # ---- weights + optimizer streams ----
    if train:
        # read per microbatch in fwd, remat-fwd and bwd; grad write f32 and
        # all-reduced read; optimizer moment read+write; param read+write.
        opt_b = 16 if cfg.optimizer == "adamw" else 6   # m,v vs factored
        w = n * (3 * M * pd_b + 2 * 4 + opt_b + 2 * pd_b)
    elif decode:
        w = n_active * pd_b                  # active experts only
    else:
        w = n * pd_b

    # ---- per-token per-layer activation streams (fwd) ----
    d, ff = cfg.d_model, cfg.d_ff
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    per_tok = 0.0
    for kind in cfg.layer_kinds:
        per_tok += 4 * d * act_b             # residual in/out + 2 norms
        if kind in ("attn", "local"):
            qkv = (hq + 2 * hkv) * dh
            per_tok += (2 * qkv + 2 * hq * dh + d) * act_b   # proj + attn io
        elif kind == "rglru":
            r = cfg.d_rnn
            per_tok += (6 * r + d) * act_b
        elif kind == "rwkv":
            per_tok += (8 * d + d) * act_b
        if kind != "rwkv":
            eff_ff = ff * (cfg.moe.top_k if cfg.moe else 1)
            n_in = 2 if cfg.act == "swiglu" else 1
            per_tok += (d + (n_in + 1) * eff_ff + d) * act_b
            if cfg.moe:
                per_tok += 2 * cfg.moe.n_experts * 4         # router probs
        else:
            per_tok += (2 * ff + 2 * d) * act_b
    act = tokens * per_tok * (3.0 if train else 1.0)  # fwd + remat + bwd

    # ---- embeddings / logits ----
    V = cfg.vocab
    emb = tokens * d * act_b * (2 if train else 1)
    if train:
        logits = B * S * V * 4 * 2           # f32 write fwd + read bwd
    elif decode:
        logits = B * V * 4
    else:
        logits = B * V * 4                   # last-position only

    # ---- kv / state cache traffic ----
    cache = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "local"):
            span = min(cfg.local_window, S) if kind == "local" else S
            if decode:
                cache += B * span * 2 * hkv * dh * kv_b      # read cache
                cache += B * 2 * hkv * dh * kv_b             # write 1 token
            elif cell.kind == "prefill":
                cache += B * span * 2 * hkv * dh * kv_b      # write cache
        elif kind == "rglru" and decode:
            cache += B * cfg.d_rnn * 4 * 4
        elif kind == "rwkv" and decode:
            H = cfg.n_heads
            cache += B * H * (d // H) ** 2 * 4 * 2
    return w + act + emb + logits + cache

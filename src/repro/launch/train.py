"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs under one process per host with
jax.distributed.initialize(); on this CPU container it trains reduced
configs end-to-end (full configs are exercised via the dry-run).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced smoke config (CPU container)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg, backend="auto")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.global_batch))
    trainer = Trainer(model, data, ckpt_dir=args.ckpt_dir)
    trainer.restore_or_init(jax.random.PRNGKey(args.seed))
    hist = trainer.run(args.steps, log_every=max(1, args.steps // 10),
                       on_metrics=lambda m: print(
                           f"step {m['step']:5d} loss={m['loss']:.4f} "
                           f"gnorm={m['grad_norm']:.2f}"))
    print(f"done: final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

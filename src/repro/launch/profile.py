"""Profiling launcher: ``python -m repro.launch.profile [...]``.

Runs the measured characterize → calibrate → bundle pipeline
(:mod:`repro.profiling`) and writes a content-hashed ``ProfileBundle``
artifact that :class:`~repro.core.scheduler.Scheduler` (and
``repro.launch.serve --profile-bundle``) can solve from directly.

Two executors:

* ``--executor virtual`` (default, CI-safe): the deterministic virtual
  SoC — ground-truth paper profiles + a generating contention model with
  seeded measurement noise.  With ``--solve`` the bundle is solved and
  compared against the plan under the generating model, closing the loop.

      PYTHONPATH=src python -m repro.launch.profile --platform xavier-agx \\
          --dnns vgg19 resnet101 --out artifacts/profiles/xavier.json --solve

* ``--executor jax``: real measurement on the local JAX backend — layer
  groups built from a registered model config run under the harness
  timing discipline, and the contention model is calibrated from genuine
  co-runs of the streaming antagonist (:mod:`repro.profiling.probes`)
  against itself at swept duty cycles.

      PYTHONPATH=src python -m repro.launch.profile --executor jax \\
          --arch stablelm-1.6b --seq 256 --batch 2 --out /tmp/cpu.json
"""
from __future__ import annotations

import argparse

from repro.core.accelerators import PLATFORMS


def _parse_levels(text: str) -> list[float]:
    try:
        levels = [float(x) for x in text.split(",") if x.strip()]
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--ext-levels must be comma-separated floats, got {text!r}")
    if not levels or any(x <= 0 for x in levels):
        raise argparse.ArgumentTypeError("--ext-levels must be positive")
    return levels


def _virtual_bundle(args, timer):
    from repro import profiling
    from repro.core.contention import ProportionalShareModel
    from repro.core.profiles import get_graph

    platform = PLATFORMS[args.platform]()
    graphs = [get_graph(d, platform) for d in args.dnns]
    true_model = (ProportionalShareModel(capacity=1.0, sensitivity=3.0)
                  if args.true_model == "proportional"
                  else profiling.paper_like_pccs())
    vsoc = profiling.VirtualSoC(
        platform, graphs, true_model, noise=args.noise,
        outlier_rate=args.outlier_rate, seed=args.seed)
    bundle = profiling.run_pipeline(
        vsoc, timer=timer, ext_levels=args.ext_levels, fit_kind=args.fit)
    return bundle, vsoc


def _jax_bundle(args, timer):
    from repro import configs, profiling
    from repro.configs.base import ShapeCell
    from repro.profiling import probes

    cfg = configs.get(args.arch).reduced()
    cell = ShapeCell(f"{args.kind}_{args.seq}", args.seq, args.batch,
                     args.kind)
    platform = PLATFORMS[args.platform]()
    print(f"measuring {cfg.name} layer groups on the local JAX backend ...")
    measured = profiling.measure_arch(cfg, cell, backend=args.backend,
                                      timer=timer,
                                      max_groups=args.max_groups)
    for mg in measured:
        m = mg.measurement
        print(f"  {m.name}: {m.median_ms:.3f} ms "
              f"(n={len(m.kept_ms)}/{m.n_total}, std={m.std_ms:.3f})")
    graph = profiling.graph_from_measurements(
        f"{args.arch}:{cell.name}", platform, measured)

    print("calibrating from streaming-antagonist co-runs ...")
    usable_levels = [e for e in args.ext_levels if e <= 1.0]
    if not usable_levels:
        raise SystemExit(
            f"--executor jax sweeps the antagonist by duty cycle, so "
            f"every --ext-levels entry must be <= 1.0 (got "
            f"{args.ext_levels})")
    peak = probes.measure_peak_bandwidth(backend=args.backend, timer=timer)
    x, y = probes.make_buffers(8.0)
    base = profiling.measure_wallclock(
        lambda: probes.stream_once(x, y, backend=args.backend), timer=timer)
    own = min(1.0, (probes.stream_bytes(x)
                    / (base.median_ms * 1e-3)) / peak)
    samples = []
    for ext in usable_levels:
        with probes.MemoryProbe(demand=ext, backend=args.backend):
            co = profiling.measure_wallclock(
                lambda: probes.stream_once(x, y, backend=args.backend),
                timer=timer)
        samples.append((own, float(ext),
                        max(1.0, co.median_ms / base.median_ms)))
    result = profiling.fit(samples, args.fit)
    print(f"  peak={peak / 1e9:.2f} GB/s  {result.summary()}")
    bundle = profiling.ProfileBundle(
        platform=platform, graphs=(graph,), model=result.model,
        samples=tuple(samples),
        provenance={"executor": "jax-harness", "arch": args.arch,
                    "cell": cell.name, "backend": args.backend,
                    "timer": timer.to_dict(),
                    "peak_stream_bytes_per_s": peak,
                    "fit_kind": args.fit,
                    "fit": result.report.to_dict(),
                    **profiling.harness.local_device_provenance()})
    return bundle, None


def _measure_search_throughput(args, bundle):
    """Record this host's measured anneal-search candidates/s in the
    bundle provenance, so later ``budget_ms`` solves from the artifact
    skip the live probe.  Skipped quietly when the bundle's contention
    model has no lowerable surface (the search itself would refuse too).
    """
    import dataclasses

    from repro.core import solver_anneal
    try:
        cps = solver_anneal.measure_search_throughput(
            bundle.platform, list(bundle.graphs), bundle.model,
            max_transitions=args.max_transitions, devices=args.devices)
    except (ValueError, RuntimeError) as exc:
        print(f"(search-throughput probe skipped: {exc})")
        return bundle
    print(f"measured anneal-search throughput: {cps:,.0f} candidates/s"
          + (f" on {args.devices} device(s)" if args.devices else ""))
    prov = {**bundle.provenance, "search_cands_per_s": float(cps)}
    if args.devices:
        prov["search_devices"] = int(args.devices)
    return dataclasses.replace(bundle, provenance=prov)


def _anneal_knobs(args, bundle) -> dict:
    knobs = {}
    if args.solver != "anneal":
        return knobs
    if args.devices:
        knobs["devices"] = args.devices
    if args.search_budget_ms:
        knobs["budget_ms"] = args.search_budget_ms
        cps = bundle.provenance.get("search_cands_per_s")
        if cps:
            knobs["cands_per_s"] = float(cps)
    return knobs


def _solve_from_bundle(args, bundle, vsoc) -> int:
    from repro import profiling

    sched = profiling.scheduler_from_bundle(bundle)
    if len(bundle.platform.names) < 2:
        print("(platform has one accelerator: nothing to co-schedule)")
        return 0
    knobs = _anneal_knobs(args, bundle)
    plan = sched.solve(list(bundle.graphs), args.objective,
                       solver=args.solver,
                       max_transitions=args.max_transitions,
                       deadline_s=20.0, solver_knobs=knobs)
    print("solved from measured bundle:")
    print(plan.summary())
    if args.trace_out:
        from repro.obs import timeline
        print(timeline.plan_ascii(plan))
        path = timeline.write_chrome(timeline.plan_chrome(plan),
                                     args.trace_out)
        print(f"timeline: schedule gantt -> {path} "
              f"(open at https://ui.perfetto.dev)")
    if vsoc is not None:
        from repro.core import Scheduler
        truth_model = next(iter(vsoc.models.values()))
        truth = Scheduler(vsoc.platform, model=truth_model).solve(
            list(vsoc.graphs.values()), args.objective, solver=args.solver,
            max_transitions=args.max_transitions, deadline_s=20.0,
            solver_knobs=knobs)
        rel = (abs(plan.objective - truth.objective)
               / max(abs(truth.objective), 1e-12))
        print(f"generating-model objective={truth.objective:.4f}  "
              f"measured-bundle objective={plan.objective:.4f}  "
              f"rel-diff={rel:.2%}")
        if rel > args.solve_tolerance:
            print(f"ERROR: objective deviates more than "
                  f"{args.solve_tolerance:.0%} from the generating model")
            return 1
    return 0


def main(argv=None) -> int:
    from repro.profiling import ProfileBundle, TimerConfig

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executor", choices=("virtual", "jax"),
                    default="virtual")
    ap.add_argument("--platform", default="xavier-agx",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--dnns", nargs="+", default=["vgg19", "resnet101"],
                    help="paper-profile DNNs to characterize (virtual)")
    ap.add_argument("--true-model", default="piecewise",
                    choices=("piecewise", "proportional"),
                    help="generating contention model of the virtual SoC")
    ap.add_argument("--noise", type=float, default=0.003,
                    help="relative timing-noise sigma of the virtual SoC")
    ap.add_argument("--outlier-rate", type=float, default=0.05,
                    help="probability of a preemption-style timing outlier")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="stablelm-1.6b",
                    help="model config measured by --executor jax")
    ap.add_argument("--kind", default="prefill",
                    choices=("prefill", "decode"))
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--backend", default="auto",
                    help="kernel backend (auto|xla|pallas|pallas_interpret)")
    ap.add_argument("--max-groups", type=int, default=None,
                    help="cap measured groups (jax executor)")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--ext-levels", type=_parse_levels,
                    default=[0.15, 0.3, 0.45, 0.6, 0.75, 0.9, 1.05],
                    metavar="F,F,...",
                    help="antagonist demand sweep (fractions of capacity)")
    ap.add_argument("--fit", default=None,
                    choices=("piecewise", "proportional"),
                    help="model class to calibrate (default: piecewise for "
                         "virtual, proportional for jax)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="bundle path (default artifacts/profiles/"
                         "<platform-or-arch>.json)")
    ap.add_argument("--solve", action="store_true",
                    help="solve a schedule from the bundle; with the "
                         "virtual executor also compare against the "
                         "generating-model plan")
    ap.add_argument("--objective", default="latency")
    ap.add_argument("--solver", default="auto")
    ap.add_argument("--max-transitions", type=int, default=2)
    ap.add_argument("--solve-tolerance", type=float, default=0.05,
                    help="max generating-vs-measured objective deviation")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="fan --solver anneal solves over N devices "
                         "(emulated on CPU hosts via "
                         "--xla_force_host_platform_device_count, applied "
                         "before jax initializes)")
    ap.add_argument("--search-budget-ms", type=float, default=None,
                    metavar="MS",
                    help="wall-clock budget per anneal solve: population/"
                         "steps auto-tune from the bundle-measured search "
                         "throughput (recorded in provenance as "
                         "search_cands_per_s); requires --solver anneal")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="with --solve: write the solved schedule as a "
                         "per-accelerator Gantt in Chrome-trace/Perfetto "
                         "JSON (contention intervals and transitions "
                         "annotated) and print its ASCII rendering; open "
                         "at https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a JSON snapshot of the metrics registry "
                         "(solver counters, search_compile_s, ...) to PATH")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    ap.add_argument("--log-json", action="store_true",
                    help="emit one JSON object per log line instead of "
                         "plain text")
    args = ap.parse_args(argv)

    from repro.obs import configure_logging
    configure_logging(args.log_level, json=args.log_json)
    if args.trace_out and not args.solve:
        ap.error("--trace-out renders the solved schedule; it requires "
                 "--solve")

    if (args.devices or args.search_budget_ms) and args.solver != "anneal":
        ap.error("--devices/--search-budget-ms tune the device-resident "
                 "search; they require --solver anneal")
    if args.devices:
        from repro.core import xla_env
        xla_env.apply(devices=args.devices)

    if args.fit is None:
        args.fit = "piecewise" if args.executor == "virtual" \
            else "proportional"
    timer = TimerConfig(warmup=args.warmup, repeats=args.repeats)
    if args.executor == "virtual":
        bundle, vsoc = _virtual_bundle(args, timer)
        default_out = f"artifacts/profiles/{args.platform}.json"
    else:
        bundle, vsoc = _jax_bundle(args, timer)
        default_out = f"artifacts/profiles/{args.arch}.json"

    if args.solver == "anneal" and len(bundle.platform.names) >= 2:
        bundle = _measure_search_throughput(args, bundle)

    path = bundle.save(args.out or default_out)
    # reload immediately: the tamper check re-verifies the content hash,
    # so a bundle that cannot round-trip never ships.
    reloaded = ProfileBundle.load(path)
    assert reloaded.bundle_hash() == bundle.bundle_hash()
    print(bundle.summary())
    print(f"bundle {bundle.bundle_hash()[:12]} saved to {path} "
          f"(round-trip verified)")

    rc = 0
    if args.solve:
        rc = _solve_from_bundle(args, bundle, vsoc)
    if args.metrics_out:
        from repro.obs import get_registry
        get_registry().write(args.metrics_out)
        print(f"metrics: registry snapshot -> {args.metrics_out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

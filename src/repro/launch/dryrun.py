import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Everything below this line may touch jax (device count is locked above).
import argparse        # noqa: E402
import json            # noqa: E402
import pathlib         # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                      # noqa: E402
from repro.analysis import roofline            # noqa: E402
from repro.configs.base import SHAPES, cell_supported  # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.models import Model, sharding       # noqa: E402
from repro.train import optimizer as opt_lib   # noqa: E402
from repro.train.trainer import TrainState, make_train_step  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent — sharding
propagates, collectives are legal, per-device memory is bounded — without
real hardware: inputs are ShapeDtypeStructs (no allocation), and the
compiled module yields memory_analysis / cost_analysis / the collective
schedule for the §Roofline analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out artifacts/dryrun
"""

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# cache logical specs (mirror model.init_cache structure)
# ---------------------------------------------------------------------------

def cache_logical(model: Model):
    cfg = model.cfg

    def kv_layer():
        d = {"data": ("batch", "kv_seq", "kv_heads", "head_dim")}
        if cfg.kv_cache_dtype == "int8":
            d["scale"] = ("batch", "kv_seq", "kv_heads", None)
        return d

    def one(kind, scanned: bool):
        pre = ("layers",) if scanned else ()
        if kind in ("attn", "local"):
            lay = kv_layer()
            return {"k": {k: pre + v for k, v in lay.items()},
                    "v": {k: pre + v for k, v in lay.items()}}
        if kind == "rglru":
            return {"h": pre + ("batch", "rnn"),
                    "conv": pre + ("batch", None, "rnn")}
        if kind == "rwkv":
            return {"S": pre + ("batch", "heads", None, None),
                    "x_t": pre + ("batch", "embed"),
                    "x_c": pre + ("batch", "embed")}
        raise ValueError(kind)

    kinds = cfg.layer_kinds
    P = len(cfg.block_pattern)
    n_groups = (len(kinds) // P) if cfg.scan_layers else 0
    groups = (tuple(one(cfg.block_pattern[pos], True) for pos in range(P))
              if n_groups else None)
    tail = tuple(one(kinds[i], False)
                 for i in range(n_groups * P, len(kinds)))
    return {"groups": groups, "tail": tail}


def batch_logical(batch):
    out = {}
    for k, v in batch.items():
        if k in ("token_ids", "labels", "mask"):
            out[k] = ("batch", "seq")
        elif k in ("embeds", "mm_embeds"):
            out[k] = ("batch", "seq", None)
        elif k == "lengths":
            out[k] = ("batch",)
        else:
            out[k] = tuple([None] * v.ndim)
    return out


# ---------------------------------------------------------------------------
# per-cell build + compile
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape: str, mesh, rules_override=None,
               cfg_override=None, cell_override=None, backend="xla"):
    """Returns (fn, args_abstract, in_shardings, meta)."""
    cfg = cfg_override or configs.get(arch)
    cell = cell_override or SHAPES[shape]
    train = cell.kind == "train"
    if not train and cfg.param_dtype != "bfloat16":
        import dataclasses as _dc
        cfg = _dc.replace(cfg, param_dtype="bfloat16")   # serve in bf16
    rules = dict(rules_override or (cfg.rules if train else cfg.serve_rules))
    model = Model(cfg, rules=rules, backend=backend)
    batch = model.input_specs(cell)
    b_sh = sharding.tree_shardings(mesh, rules, batch_logical(batch), batch)
    params = model.abstract_params()
    p_sh = sharding.tree_shardings(mesh, rules, model.specs(), params)

    if train:
        opt = opt_lib.make(cfg.optimizer, cfg.learning_rate)
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = jax.tree.map(
            lambda x: sharding.named_sharding(
                mesh, rules, tuple([None] * x.ndim), x.shape),
            opt_state)
        # better: optimizer state mirrors param shardings where shapes match
        o_sh = _opt_shardings(mesh, rules, model.specs(), params, opt_state)
        state = TrainState(jax.ShapeDtypeStruct((), jnp.int32), params,
                           opt_state)
        state_sh = TrainState(
            sharding.named_sharding(mesh, rules, ()), p_sh, o_sh)
        step = make_train_step(model, opt, cfg.microbatches)
        fn = lambda s, b: step(s, b)
        return fn, (state, batch), (state_sh, b_sh), model

    if cell.kind == "prefill":
        fn = lambda p, b: model.prefill(p, b, capacity=cell.seq_len)
        return fn, (params, batch), (p_sh, b_sh), model

    # decode
    caches = model.cache_specs(cell)
    c_sh = sharding.tree_shardings(mesh, rules, cache_logical(model), caches)
    fn = lambda p, c, b: model.decode_step(p, c, b)
    return fn, (params, caches, batch), (p_sh, c_sh, b_sh), model


def _opt_shardings(mesh, rules, specs, params, opt_state):
    """Optimizer-state shardings: mirror the param's logical axes where the
    state leaf has the same shape; factored (adafactor) leaves drop axes."""
    flat_p, tdef_p = jax.tree.flatten(params)
    flat_s = jax.tree.flatten(specs, is_leaf=sharding._is_logical)[0]
    by_shape = {}
    for p, lg in zip(flat_p, flat_s):
        by_shape.setdefault(p.shape, lg)

    def one(x):
        lg = by_shape.get(x.shape)
        if lg is None:
            # factored moment: match a param by prefix shape
            for shape, plg in by_shape.items():
                if x.shape == shape[:-1]:
                    lg = plg[:-1]
                    break
                if x.shape == shape[:-2] + shape[-1:]:
                    lg = plg[:-2] + plg[-1:]
                    break
        if lg is None:
            lg = tuple([None] * x.ndim)
        return sharding.named_sharding(mesh, rules, lg, x.shape)

    return jax.tree.map(one, opt_state)


def _compile_costs(arch, shape, mesh, cfg, cell, rules_override,
                   backend="stub"):
    """(flops, bytes, coll_operand_bytes) of one compiled variant.

    Probes default to the "stub" mixer backend: temporal-mix ops read/write
    kernel-true HBM shapes with ~zero flops (the Pallas kernels keep score
    tiles in VMEM on the TPU target; the XLA fallback would spill S x bkv
    score tensors and wildly overstate the memory term).  The mixers' flops
    are added back analytically by ``mixer_flops``.
    """
    with mesh:
        fn, args, shardings, _ = build_cell(
            arch, shape, mesh, rules_override, cfg_override=cfg,
            cell_override=cell, backend=backend)
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        coll = roofline.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll.operand_bytes, coll.op_counts)


def probe_costs(arch: str, shape: str, mesh, rules_override=None):
    """XLA cost_analysis counts a scan body ONCE regardless of trip count,
    so the full-depth compile under-reports flops/bytes/collectives.  We
    measure the layer body on *unrolled* probes (scan_layers=False,
    microbatches=1) and reconstruct the real step cost

        C = opt_base + M * (q + G * r)

    with r (per supergroup) from a depth pair, q (per-microbatch embed/
    logits/loss) from a batch pair, and opt_base (optimizer update, train
    only) as the batch-independent remainder:

        r = C(2P layers, B) - C(P layers, B)
        q + r = C(P layers, 2B) - C(P layers, B)
        opt_base = C(P layers, B) - (q + r)            [0 for serve]

    Unrolled tail layers are checkpointed like scan groups, so remat
    recompute is included.  Fusion differences between the unrolled probes
    and the scanned production program are the residual error.
    """
    import dataclasses as dc
    cfg = configs.get(arch)
    cell = SHAPES[shape]
    P = len(cfg.block_pattern)
    train = cell.kind == "train"
    M_real = cfg.microbatches if train else 1
    G_real = cfg.n_layers / P
    B_mb = max(1, cell.global_batch // M_real)

    def probe(n_layers, batch):
        pcfg = dc.replace(cfg, n_layers=n_layers, microbatches=1,
                          scan_layers=False)
        pcell = dc.replace(cell, global_batch=batch)
        return _compile_costs(arch, shape, mesh, pcfg, pcell, rules_override)

    cA = probe(P, B_mb)
    cB = probe(2 * P, B_mb)
    ops = cB[3]
    if train:
        cC = probe(P, 2 * B_mb)
    out = {}
    for i, name in enumerate(("flops", "bytes", "coll")):
        r = max(cB[i] - cA[i], 0.0)
        if train:
            q_plus_r = max(cC[i] - cA[i], 0.0)
            opt_base = max(cA[i] - q_plus_r, 0.0)
            q = max(q_plus_r - r, 0.0)
        else:
            opt_base = 0.0
            q = max(cA[i] - r, 0.0)
        out[name] = opt_base + M_real * (q + G_real * r)
    out["flops"] += mixer_flops(cfg, cell)
    return out["flops"], out["bytes"], out["coll"], ops


def mixer_flops(cfg, cell) -> float:
    """Analytic global flops of the stubbed temporal-mix kernels, per chip.

    attention: 4 * B * Hq * Sq * kv_len * d_head (QK^T + PV), causal halves
    kv_len, local caps it at the window; rwkv: ~6 H D Dv per token (outer
    product + readout + decay); rglru: ~10 r per token.  Train multiplies by
    4 (fwd + 2x bwd + remat recompute).
    """
    B, S = cell.global_batch, cell.seq_len
    train = cell.kind == "train"
    mult = 4.0 if train else 1.0
    sq = 1 if cell.kind == "decode" else S
    total = 0.0
    H, dh = cfg.n_heads, cfg.d_head
    for kind in cfg.layer_kinds:
        if kind == "attn":
            kv_len = S if cfg.bidirectional else (
                S if cell.kind == "decode" else S / 2)
            total += 4.0 * B * H * sq * kv_len * dh
        elif kind == "local":
            kv_len = min(cfg.local_window, S)
            total += 4.0 * B * H * sq * kv_len * dh
        elif kind == "rwkv":
            d_head_r = cfg.d_model // H
            total += 6.0 * B * sq * H * d_head_r * d_head_r
        elif kind == "rglru":
            total += 10.0 * B * sq * cfg.d_rnn
    # per chip (flops shard over batch x model like the projections)
    return mult * total / 256.0


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: pathlib.Path, rules_override=None,
             tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag}
    cfg = configs.get(arch)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    with mesh:
        fn, args, shardings, model = build_cell(arch, shape, mesh,
                                                rules_override)
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        print(mem)          # proves it fits (per-device bytes)
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
    coll_sched = roofline.parse_collectives(hlo)
    flops_e, bytes_e, coll_e, probe_ops = probe_costs(
        arch, shape, mesh, rules_override)
    bytes_analytic = roofline.analytic_hbm_bytes(cfg, SHAPES[shape]) \
        / mesh.devices.size
    cost = {"flops": flops_e, "bytes accessed": bytes_analytic,
            "bytes_xla_probe": bytes_e}
    coll = roofline.CollectiveStats(
        {k: v for k, v in sorted(coll_sched.op_counts.items())},
        coll_e, coll_e)

    cell = SHAPES[shape]
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    n = cfg.n_active_params() if cfg.moe else cfg.n_params()
    factor = 6 if cell.kind == "train" else 2
    model_flops = factor * n * tokens
    useful_bytes = None
    if cell.kind == "decode":
        # per-chip useful traffic: active params + live kv/state read once
        kv_bytes = _decode_state_bytes(cfg, cell)
        wb = 2 * n  # bf16 weights
        useful_bytes = (wb + kv_bytes) / n_chips
    rl = roofline.analyze(cost, coll, n_chips, model_flops,
                          useful_bytes, cell.kind)

    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_estimate_gb=round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes) / 1e9, 3),
        ),
        cost={k: cost.get(k) for k in ("flops", "bytes accessed",
                                       "utilization")
              if k in cost},
        roofline=roofline.to_dict(rl),
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}_{shape}_{mesh_name}{('_' + tag) if tag else ''}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1))
    return rec


def _decode_state_bytes(cfg, cell) -> float:
    per_tok = 0
    kv_b = 1 if cfg.kv_cache_dtype == "int8" else 2
    for kind in cfg.layer_kinds:
        if kind == "attn":
            per_tok += 2 * cfg.n_kv_heads * cfg.d_head * kv_b * cell.seq_len
        elif kind == "local":
            per_tok += (2 * cfg.n_kv_heads * cfg.d_head * kv_b
                        * min(cfg.local_window, cell.seq_len))
        elif kind == "rglru":
            per_tok += 4 * cfg.d_rnn * 4
        elif kind == "rwkv":
            H = cfg.n_heads
            per_tok += H * (cfg.d_model // H) ** 2 * 4
    return per_tok * cell.global_batch


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--force", action="store_true",
                    help="recompute existing artifacts")
    args = ap.parse_args(argv)

    archs = list(configs.ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                art = out_dir / f"{arch}_{shape}_{mesh_name}.json"
                if art.exists() and not args.force:
                    rec = json.loads(art.read_text())
                    print(f"[cached] {arch} {shape} {mesh_name}: "
                          f"{rec.get('status')}")
                    continue
                label = f"{arch} x {shape} x {mesh_name}"
                try:
                    t0 = time.perf_counter()
                    rec = run_cell(arch, shape, mp, out_dir)
                    dt = time.perf_counter() - t0
                    if rec["status"] == "skip":
                        print(f"[skip] {label}: {rec['reason']}")
                        (out_dir / f"{arch}_{shape}_{mesh_name}.json"
                         ).parent.mkdir(parents=True, exist_ok=True)
                        art.write_text(json.dumps(rec, indent=1))
                    else:
                        r = rec["roofline"]
                        print(f"[ok] {label}: compile={rec['compile_s']}s "
                              f"mem={rec['memory']['peak_estimate_gb']}GB/chip "
                              f"bound={r['bottleneck']} "
                              f"frac={r['roofline_fraction']:.3f} ({dt:.0f}s)")
                except Exception:
                    failures.append(label)
                    print(f"[FAIL] {label}\n{traceback.format_exc()}")
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run complete.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Single-model continuous-batching service on reduced configs (CPU), or
--plan mode: HaX-CoNN concurrent co-serving plan for full configs on the
production pod split.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build
from repro.serve.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--co-arch", default=None, choices=configs.ARCHS,
                    help="plan concurrent serving with a second model")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    if args.co_arch:
        from repro.serve.concurrent import plan_concurrent_serving
        plan = plan_concurrent_serving(
            [configs.get(args.arch), configs.get(args.co_arch)],
            [args.shape, args.shape], objective="latency", deadline_s=20.0)
        print(plan.summary())
        return 0

    cfg = configs.get(args.arch).reduced()
    if not cfg.has_decode:
        print(f"{args.arch} is encoder-only: no decode service")
        return 1
    model = build(cfg, backend="auto")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_slots=4, capacity=128)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab, size=8), max_new=args.max_new)
    done = eng.run_until_drained()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.tokens) for r in done)} tokens, "
          f"{eng.steps} decode steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Single-model continuous-batching service on reduced configs (CPU);
--co-arch plans HaX-CoNN concurrent co-serving for full configs on the
production pod split; --gateway additionally *serves* both models
concurrently through the contention-aware multi-tenant gateway (phase-aware
schedule, shared KV budget, dynamic re-scheduling).

Plan artifacts (pre-solve offline, boot cold with zero solver invocations):

    # pre-solve the gateway schedule and persist it
    python -m repro.launch.serve --gateway --arch A --co-arch B \
        --save-plan artifacts/plans/gw.json --plan-only
    # later / elsewhere: boot the gateway from the cached artifact
    python -m repro.launch.serve --gateway --arch A --co-arch B \
        --plan artifacts/plans/gw.json

Fleet mode (--fleet) replays a seeded arrival trace through the
virtual-time fleet gateway: thousands of open-loop tenants multiplexed
over a pool of solved SoC plans with SLO-aware admission and routing.

    # replay a generated bursty trace at 1k requests, SLO-routed
    python -m repro.launch.serve --fleet --arch A --co-arch B \
        --trace "bursty:base=150,burst=1500,n=1000,tenants=200,seed=7" \
        --slo "p99=400" --cache-root artifacts/plancache
    # second boot from the sharded cache performs zero solver invocations
    python -m repro.launch.serve --fleet ... --cache-root artifacts/plancache \
        --expect-cached
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build
from repro.serve.engine import ServingEngine


def _with_obs(args, run) -> int:
    """Run one serving mode under the requested observability outputs.

    ``--trace-out`` installs a process-wide :class:`repro.obs.Tracer`
    before the run (solver spans, cache hits, gateway/fleet instants all
    land on it) and writes the Perfetto JSON afterwards — even when the
    run exits nonzero, so a failed boot still leaves its trace behind.
    ``--metrics-out`` snapshots the metrics registry the same way.
    """
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer, set_tracer
        tracer = Tracer()
        set_tracer(tracer)
    try:
        return run(args)
    finally:
        if tracer is not None:
            tracer.write(args.trace_out)
            print(f"trace: {len(tracer.events())} events -> "
                  f"{args.trace_out} (open at https://ui.perfetto.dev)")
        if args.metrics_out:
            from repro.obs import get_registry
            get_registry().write(args.metrics_out)
            print(f"metrics: registry snapshot -> {args.metrics_out}")


def _solver_knobs(args) -> tuple:
    """--devices/--search-budget-ms as GatewayConfig.solver_knobs pairs."""
    knobs = {}
    if args.devices:
        knobs["devices"] = args.devices
    if args.search_budget_ms:
        knobs["budget_ms"] = args.search_budget_ms
    return tuple(sorted(knobs.items()))


def _run_gateway(args) -> int:
    from repro.core.accelerators import tpu_pod_split
    from repro.core.plan import Plan
    from repro.core.scheduler import Scheduler
    from repro.serve.gateway import (GatewayConfig, MultiTenantGateway,
                                     TenantSpec)
    archs = [args.arch, args.co_arch]
    specs = [TenantSpec(a, configs.get(a).reduced(),
                        plan_cfg=configs.get(a), max_slots=4, capacity=96,
                        max_new=args.max_new)
             for a in archs]
    budget = (args.budget_slots * max(s.kv_bytes_per_slot for s in specs)
              if args.budget_slots else None)
    platform = tpu_pod_split(4, 12, name="v5e-4x12-split")
    model = None
    if args.profile_bundle:
        from repro.profiling import ProfileBundle
        bundle = ProfileBundle.load(args.profile_bundle)
        if len(bundle.platform.names) < 2:
            print(f"ERROR: profile bundle {args.profile_bundle} measured a "
                  f"single-accelerator platform; nothing to co-schedule")
            return 1
        platform, model = bundle.platform, bundle.model
        print(f"profile bundle {bundle.bundle_hash()[:12]}: planning on "
              f"measured platform {platform.name} with calibrated "
              f"{type(model).__name__}")
    gcfg = GatewayConfig(platform=platform, model=model,
                         memory_budget_bytes=budget, solver=args.solver,
                         solver_knobs=_solver_knobs(args))
    scheduler = Scheduler(gcfg.platform, gcfg.model,
                          evaluator=args.evaluator)
    if args.plan:
        loaded = Plan.load(args.plan)
        scheduler.cache.add(loaded)
        print(f"loaded plan {loaded.request_hash[:12]} "
              f"(solver={loaded.solver}, "
              f"solved offline in {loaded.solve_time_s:.3f}s)")

    if args.plan_only:
        from repro.serve.gateway import plan_gateway
        plan = plan_gateway(specs, gcfg, scheduler=scheduler)
    else:
        gw = MultiTenantGateway(specs, gcfg, scheduler=scheduler)
        plan = gw.plan

    if args.plan:
        if scheduler.solves:
            print("ERROR: plan artifact did not cover the request — "
                  f"{scheduler.solves} fresh solver invocation(s)")
            return 1
        print(f"plan cache hit: booted from {args.plan} with zero solver "
              f"invocations")
    if args.save_plan:
        path = plan.plan.save(args.save_plan)
        print(f"plan {plan.plan.request_hash[:12]} "
              f"(solver={plan.plan.solver}) saved to {path}")
    print(plan.summary())
    if args.plan_only:
        return 0

    rng = np.random.default_rng(0)
    for name, s in gw.specs.items():
        for _ in range(args.requests):
            gw.submit(name, rng.integers(0, s.cfg.vocab, size=8))
    done = gw.run_until_drained()
    for name, reqs in done.items():
        print(f"{name}: served {len(reqs)} requests, "
              f"{sum(len(r.tokens) for r in reqs)} tokens")
    print(f"gateway steps={gw.total_steps} "
          f"deferred={gw.deferred_admissions} "
          f"reschedules={len(gw.reschedules)}")
    return 0


def _run_fleet(args) -> int:
    from repro.core.accelerators import tpu_pod_split
    from repro.core.plan import ShardedPlanCache
    from repro.serve.fleet import (FleetConfig, FleetGateway, build_pool,
                                   parse_slo, parse_trace_spec)
    from repro.serve.gateway import GatewayConfig, TenantSpec

    trace = parse_trace_spec(args.trace)
    print(f"trace: kind={trace.kind} n={len(trace)} "
          f"tenants={trace.n_tenants} rate={trace.mean_rate_rps:.1f} req/s "
          f"burstiness={trace.burstiness():.2f} hash={trace.trace_hash()[:12]}")

    bundle = model = None
    if args.profile_bundle:
        from repro.profiling import ProfileBundle
        bundle = ProfileBundle.load(args.profile_bundle)
        model = bundle.model
        print(f"profile bundle {bundle.bundle_hash()[:12]}: pool plans "
              f"priced under calibrated {type(model).__name__}")

    # full-size configs: the fleet loop bills service from the solved
    # schedule's predictions and never builds the models, so planning the
    # production shapes costs nothing extra.
    specs = [TenantSpec(a, configs.get(a), max_slots=4, capacity=256,
                        prompt_len=64, max_new=args.max_new)
             for a in (args.arch, args.co_arch)]
    cache = ShardedPlanCache(args.cache_root) if args.cache_root else None
    splits = [(4, 12), (8, 8), (12, 4)]
    plats = [tpu_pod_split(a, b, name=f"v5e-{a}x{b}-split")
             for a, b in splits]
    budget = (args.budget_slots * max(s.kv_bytes_per_slot for s in specs)
              if args.budget_slots else None)
    pool = build_pool(specs, plats,
                      GatewayConfig(solver=args.solver, model=model,
                                    solver_knobs=_solver_knobs(args)),
                      cache, slots=8)
    solves = sum(pp.scheduler.solves for pp in pool)
    print(f"pool: {len(pool)} plans, {solves} solver invocation(s)")
    if args.expect_cached and solves:
        print(f"ERROR: --expect-cached but {solves} fresh solve(s) — the "
              f"sharded cache at {args.cache_root} did not cover the pool")
        return 1

    recal = None
    if args.recalibrate:
        from repro.profiling import StreamingRecalibrator
        recal = StreamingRecalibrator(
            bundle, window=args.recalibrate_window,
            min_new=args.recalibrate_min_new)
        print(f"closed-loop recalibration on: window="
              f"{args.recalibrate_window} min_new={args.recalibrate_min_new}")
    cfg = FleetConfig(policy=args.policy, default_slo=parse_slo(args.slo),
                      memory_budget_bytes=budget, throttle=args.throttle,
                      throttle_duty=args.throttle_duty)
    gw = FleetGateway(pool, n_tenants=trace.n_tenants, cfg=cfg,
                      capacity_hint=len(trace), recalibrator=recal)
    rep = gw.replay(trace)
    print(rep.summary())
    exported = gw.export_trace()
    if exported:
        print(f"trace: {exported} per-request queue/service spans exported")
    if recal is not None:
        head = recal.bundle
        print(f"recalibration: {recal.refits} re-fit(s) published, lineage "
              f"depth {len(recal.lineage)}, head {head.bundle_hash()[:12]} "
              f"(root {recal.lineage[0].bundle_hash()[:12]})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--co-arch", default=None, choices=configs.ARCHS,
                    help="plan concurrent serving with a second model")
    ap.add_argument("--gateway", action="store_true",
                    help="serve --arch and --co-arch concurrently through "
                         "the multi-tenant gateway (requires --co-arch)")
    ap.add_argument("--budget-slots", type=int, default=0,
                    help="shared KV budget in slot units (0 = unlimited)")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fleet", action="store_true",
                    help="replay an arrival trace through the virtual-time "
                         "fleet gateway (requires --co-arch and --trace)")
    ap.add_argument("--trace", default=None, metavar="SPEC|PATH",
                    help="arrival trace: a saved trace JSON path or a "
                         "generator spec like "
                         "'poisson:rate=200,n=1000,tenants=100,seed=0', "
                         "'bursty:base=100,burst=1000,n=5000,tenants=200' "
                         "or 'diurnal:peak=300,n=5000,tenants=500'")
    ap.add_argument("--slo", default="p99=1000", metavar="SPEC",
                    help="default tenant SLO, e.g. 'p99=400,rps=5'")
    ap.add_argument("--policy", default="slo",
                    choices=("slo", "round_robin"),
                    help="fleet routing policy (round_robin = baseline)")
    ap.add_argument("--cache-root", default=None, metavar="DIR",
                    help="sharded disk-backed plan cache root shared by "
                         "every pool scheduler; a re-run over the same pool "
                         "boots with zero solver invocations")
    ap.add_argument("--expect-cached", action="store_true",
                    help="fail unless the pool booted entirely from "
                         "--cache-root (zero fresh solves)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="boot the gateway from a serialized Plan artifact "
                         "(fails if the request is not covered: zero solver "
                         "invocations are asserted)")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="serialize the solved gateway Plan to PATH")
    ap.add_argument("--plan-only", action="store_true",
                    help="plan (and optionally save) without serving")
    ap.add_argument("--profile-bundle", default=None, metavar="PATH",
                    help="plan from a measured ProfileBundle "
                         "(repro.launch.profile). With --gateway the "
                         "bundle's platform and calibrated contention model "
                         "replace the built-in pod split + default model; "
                         "with --fleet the calibrated model prices every "
                         "pool plan and seeds --recalibrate")
    ap.add_argument("--recalibrate", action="store_true",
                    help="fleet mode: stream completion telemetry into a "
                         "StreamingRecalibrator seeded from "
                         "--profile-bundle; published re-fits (versioned, "
                         "lineage-hashed) are adopted by every pool plan "
                         "at reschedule time")
    ap.add_argument("--recalibrate-window", type=int, default=256,
                    metavar="N", help="telemetry window size (live "
                         "samples) for streaming re-fits")
    ap.add_argument("--recalibrate-min-new", type=int, default=128,
                    metavar="N", help="fresh samples required between "
                         "consecutive re-fits")
    ap.add_argument("--throttle", action="store_true",
                    help="fleet mode: duty-cycle tenants whose SLOs still "
                         "cannot be met after re-solving (per-tenant "
                         "hysteresis, pressure-held release)")
    ap.add_argument("--throttle-duty", type=float, default=0.5,
                    metavar="F", help="fraction of a throttled tenant's "
                         "arrivals admitted (deterministic token bucket)")
    ap.add_argument("--solver", default="auto", metavar="NAME",
                    help="registry solver entry for any fresh gateway "
                         "solve: z3 | bb | greedy | anneal (device-resident "
                         "annealing over the lowered IR; requires jax) | "
                         "auto = best available by priority. Unknown names "
                         "fail listing the registered solvers.")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="fan the anneal search over N devices "
                         "(shard_map mesh with ring elite migration). "
                         "Applied as --xla_force_host_platform_device_count "
                         "before jax initializes, so CPU-only hosts emulate "
                         "an N-device mesh; requires --solver anneal")
    ap.add_argument("--search-budget-ms", type=float, default=None,
                    metavar="MS",
                    help="wall-clock budget for each fresh anneal solve: "
                         "population/steps are auto-tuned from the problem "
                         "size, --devices, and measured search throughput "
                         "instead of fixed defaults; requires --solver "
                         "anneal")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(solver spans, plan-cache hits, fleet "
                         "queue/service spans, reschedule/throttle/"
                         "recalibration instants) to PATH; open at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a JSON snapshot of the metrics registry "
                         "(counters/gauges/histograms) to PATH")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    ap.add_argument("--log-json", action="store_true",
                    help="emit one JSON object per log line instead of "
                         "plain text")
    ap.add_argument("--evaluator", default="auto", metavar="NAME",
                    help="candidate-schedule evaluator for any fresh solve: "
                         "a registered evaluator name (batch = vectorized "
                         "NumPy, jax = XLA jit+vmap over the lowered IR, "
                         "scalar = the authoritative simulator looped; "
                         "auto = best available, currently batch). Unknown "
                         "names fail listing the registered evaluators.")
    args = ap.parse_args(argv)

    from repro.obs import configure_logging
    configure_logging(args.log_level, json=args.log_json)

    if (args.devices or args.search_budget_ms) and args.solver != "anneal":
        ap.error("--devices/--search-budget-ms tune the device-resident "
                 "search; they require --solver anneal")
    if args.devices:
        # before any jax device use: the emulated-device-count flag is
        # read once, at backend initialization.
        from repro.core import xla_env
        xla_env.apply(devices=args.devices)

    if args.solver != "auto":
        from repro.core import registry
        try:
            sentry = registry.get_solver(args.solver)
        except KeyError as exc:       # UnknownEntryError: lists known names
            ap.error(str(exc))
        if not sentry.available():
            avail = [e.name for e in registry.auto_order()]
            ap.error(f"solver {args.solver!r} is registered but its "
                     f"backend is not available here (available: "
                     f"{', '.join(avail) or 'none'})")

    if args.evaluator != "auto":
        from repro.core import registry
        try:
            entry = registry.get_evaluator(args.evaluator)
        except KeyError as exc:       # UnknownEntryError: lists known names
            ap.error(str(exc))
        if not entry.available():
            avail = [e for e in registry.evaluator_names()
                     if registry.get_evaluator(e).available()]
            ap.error(f"evaluator {args.evaluator!r} is registered but its "
                     f"backend is not available here (available: "
                     f"{', '.join(avail) or 'none'})")

    if args.fleet:
        if not args.co_arch:
            ap.error("--fleet requires --co-arch")
        if not args.trace:
            ap.error("--fleet requires --trace")
        if args.expect_cached and not args.cache_root:
            ap.error("--expect-cached requires --cache-root")
        if args.recalibrate and not args.profile_bundle:
            ap.error("--recalibrate requires --profile-bundle (the offline "
                     "seed of the lineage chain)")
        return _with_obs(args, _run_fleet)
    for flag in ("trace", "cache_root", "recalibrate", "throttle"):
        if getattr(args, flag):
            ap.error(f"--{flag.replace('_', '-')} requires --fleet")

    if args.plan or args.save_plan or args.plan_only:
        if not args.gateway:
            ap.error("--plan/--save-plan/--plan-only require --gateway")
    if args.profile_bundle and not args.gateway:
        ap.error("--profile-bundle requires --gateway or --fleet")
    if args.gateway:
        if not args.co_arch:
            ap.error("--gateway requires --co-arch")
        if args.co_arch == args.arch:
            ap.error("--gateway needs two distinct models")
        for a in (args.arch, args.co_arch):
            if not configs.get(a).has_decode:
                ap.error(f"{a} is encoder-only: no decode service")
        return _with_obs(args, _run_gateway)

    if args.co_arch:
        return _with_obs(args, _run_concurrent)

    return _with_obs(args, _run_single)


def _run_concurrent(args) -> int:
    from repro.serve.concurrent import plan_concurrent_serving
    plan = plan_concurrent_serving(
        [configs.get(args.arch), configs.get(args.co_arch)],
        [args.shape, args.shape], objective="latency", deadline_s=20.0)
    print(plan.summary())
    return 0


def _run_single(args) -> int:
    cfg = configs.get(args.arch).reduced()
    if not cfg.has_decode:
        print(f"{args.arch} is encoder-only: no decode service")
        return 1
    model = build(cfg, backend="auto")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_slots=4, capacity=128)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab, size=8), max_new=args.max_new)
    done = eng.run_until_drained()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.tokens) for r in done)} tokens, "
          f"{eng.steps} decode steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Gated linear recurrence (RG-LRU core) as a Pallas TPU kernel.

Computes ``h_t = a_t * h_{t-1} + b_t`` over the time axis with the carry in
VMEM scratch.  Grid = (batch, channel_tiles, time_tiles); time is innermost
(sequential), channels are vectorized across the VPU lanes (tile = 128·k
channels), and each time tile is walked with an in-kernel fori_loop.  This is
the TPU-native shape of the RG-LRU: the recurrence is memory-bound and
element-wise, so lane-parallel channels + sequential time maximize VPU
utilization without any MXU involvement.

The same primitive serves recurrentgemma's RG-LRU (a, b precomputed from the
recurrence/input gates) and any diagonal SSM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref, carry, *,
            block_t: int, seq_len: int):
    it = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        carry[...] = h0_ref[0, :].astype(jnp.float32)

    def body(t, h):
        # steps past seq_len are tile padding: keep h (NaN-poison guard)
        valid = it * block_t + t < seq_len
        h_new = jnp.where(
            valid,
            a_ref[0, t, :].astype(jnp.float32) * h
            + b_ref[0, t, :].astype(jnp.float32),
            h)
        h_ref[0, t, :] = h_new.astype(h_ref.dtype)
        return h_new

    carry[...] = jax.lax.fori_loop(0, block_t, body, carry[...])

    @pl.when(it == n_t - 1)
    def _finalize():
        hlast_ref[0, :] = carry[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_d", "interpret"))
def rglru_scan(a, b, h0=None, *, block_t: int = 256, block_d: int = 256,
               interpret: bool = False):
    """a, b: (B, S, D); h0: (B, D) -> (h_all (B,S,D), h_last (B,D))."""
    B, S, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)
    block_t = min(block_t, S)
    block_d = min(block_d, D)
    grid = (B, pl.cdiv(D, block_d), pl.cdiv(S, block_t))
    kernel = functools.partial(_kernel, block_t=block_t, seq_len=S)
    h_all, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, id_, it: (b_, it, id_)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, id_, it: (b_, it, id_)),
            pl.BlockSpec((1, block_d), lambda b_, id_, it: (b_, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, id_, it: (b_, it, id_)),
            pl.BlockSpec((1, block_d), lambda b_, id_, it: (b_, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), a.dtype),
            jax.ShapeDtypeStruct((B, D), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h_all, h_last

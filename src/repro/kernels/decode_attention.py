"""Single-token GQA decode attention over a KV cache (Pallas TPU kernel).

One query token per sequence attends over a long cache with per-sequence
valid lengths.  Grid = (batch, q_heads, kv_tiles); the kv tile axis is
innermost/sequential with the online-softmax state in VMEM scratch, so HBM
traffic is exactly one read of the live cache region per head — the memory
roofline for decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_kv: int):
    ikv = pl.program_id(2)
    n_kv = pl.num_programs(2)
    b = pl.program_id(0)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_pos = ikv * block_kv + jax.lax.iota(jnp.int32, block_kv)

    @pl.when(ikv * block_kv < length)
    def _tile():
        q = q_ref[0, 0, 0, :].astype(jnp.float32) * scale        # (d,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bkv, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)                # (bkv, dv)
        valid = k_pos < length
        k = jnp.where(valid[:, None], k, 0.0)    # 0*NaN guard (padding)
        v = jnp.where(valid[:, None], v, 0.0)
        s = k @ q                                                # (bkv,)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[0] = l_scr[0] * alpha + p.sum()
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[0] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        o_ref[0, 0, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_kv: int = 512,
                     interpret: bool = False):
    """q: (B, 1, Hq, D); caches: (B, S, Hkv, D); lengths: (B,) int32."""
    b, sq, hq, d = q.shape
    assert sq == 1, "decode kernel: one query token"
    _, skv, hkv, dv = v_cache.shape
    group = hq // hkv
    block_kv = min(block_kv, skv)
    scale = 1.0 / (d ** 0.5)
    grid = (b, hq, pl.cdiv(skv, block_kv))
    kernel = functools.partial(_kernel, scale=scale, block_kv=block_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # lengths, read via ref[b]
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, ikv: (b_, 0, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, ikv, g=group: (b_, ikv, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, dv),
                         lambda b_, h, ikv, g=group: (b_, ikv, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dv), lambda b_, h, ikv: (b_, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((dv,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)

"""Batched piecewise-linear PCCS slowdown surface as a Pallas kernel.

The innermost op of the XLA schedule evaluator
(:mod:`repro.core.simulate_jax`) is the contention model: one slowdown
lookup per candidate × workload × contention interval.  For PCCS proper
(:class:`~repro.core.contention.PiecewiseModel`) that lookup is bilinear
interpolation of a calibration table over (own, external) demand — a
gather, which TPUs hate.  This kernel reformulates it gather-free as a
tensor-product of 1-D *hat* bases:

    s(own, ext) = Σ_i Σ_j hat_i(own) · hat_j(ext) · table[i, j]
                = hatO @ table @ hatE^T        (row-wise)

so each block of demands becomes two tiny dense contractions on the MXU —
no dynamic indexing, no scatter.  Grid = flat demand blocks; the knots and
table ride along whole (they are a handful of floats).

Backends follow the repo-wide dispatch idiom (:mod:`repro.kernels.ops`):

  * ``pallas``           — Mosaic lowering on TPU;
  * ``pallas_interpret`` — same kernel body, interpreted (tests on CPU);
  * ``xla``              — the identical contraction in pure jnp
                           (:func:`repro.kernels.ref.piecewise_slowdown`),
                           used on CPU and inside vmapped/tiny call sites
                           where a kernel launch cannot pay for itself;
  * ``auto``             — pallas on TPU for big flat batches, xla
                           otherwise.

The NumPy evaluator stack never reaches this module: its fallback is
``repro.core.lowering.slowdown_array`` (surface dispatch + elementwise
last resort), which the differential suite pins to the scalar models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _hat_weights, piecewise_slowdown as _ref_piecewise

#: below this many demand points a pallas launch cannot pay for itself —
#: ``backend="auto"`` stays on the fused-XLA contraction instead.
_MIN_PALLAS_ELEMS = 4096


def _kernel(own_ref, ext_ref, ok_ref, ek_ref, tab_ref, out_ref):
    own = own_ref[...]                      # (1, B)
    ext = ext_ref[...]
    ok = ok_ref[...][0]                     # (K,)
    ek = ek_ref[...][0]                     # (M,)
    tab = tab_ref[...]                      # (K, M)
    ho = _hat_weights(ok, own[0])           # (B, K)
    he = _hat_weights(ek, ext[0])           # (B, M)
    s = jnp.sum((ho @ tab) * he, axis=-1)   # (B,)
    one = jnp.ones((), s.dtype)
    s = jnp.where((own[0] <= 0.0) | (ext[0] <= 0.0), one, s)
    out_ref[...] = s[None, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _pallas_piecewise(own, ext, own_knots, ext_knots, table, *,
                      block: int, interpret: bool):
    n = own.shape[0]
    nb = pl.cdiv(n, block)
    pad = nb * block - n
    if pad:
        own = jnp.pad(own, (0, pad))
        ext = jnp.pad(ext, (0, pad))
    own2 = own.reshape(nb, block)
    ext2 = ext.reshape(nb, block)
    ok2 = own_knots.reshape(1, -1)
    ek2 = ext_knots.reshape(1, -1)
    flat = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec(ok2.shape, lambda i: (0, 0)),
            pl.BlockSpec(ek2.shape, lambda i: (0, 0)),
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), own.dtype),
        interpret=interpret,
    )(own2, ext2, ok2, ek2, table)
    return flat.reshape(nb * block)[:n]


def piecewise_slowdown(own, ext, own_knots, ext_knots, table, *,
                       backend: str = "auto", block: int = 1024):
    """Batched PCCS slowdown over equal-shaped demand arrays.

    ``own``/``ext`` are demand fractions of any shape; ``own_knots`` (K,),
    ``ext_knots`` (M,) and ``table`` (K, M) are the calibration surface.
    Returns the elementwise slowdown (1.0 wherever either demand is zero),
    matching ``PiecewiseModel.slowdown`` within float tolerance.
    """
    own = jnp.asarray(own)
    ext = jnp.asarray(ext)
    ok = jnp.asarray(own_knots, own.dtype)
    ek = jnp.asarray(ext_knots, own.dtype)
    tab = jnp.asarray(table, own.dtype)
    b = backend
    if b == "auto":
        big = own.size >= _MIN_PALLAS_ELEMS
        b = "pallas" if (jax.default_backend() == "tpu" and big) else "xla"
    if b in ("xla", "ref"):
        return _ref_piecewise(own, ext, ok, ek, tab)
    if b in ("pallas", "pallas_interpret"):
        shape = own.shape
        out = _pallas_piecewise(
            own.reshape(-1), ext.reshape(-1), ok, ek, tab,
            block=min(block, max(128, own.size)),
            interpret=(b == "pallas_interpret"))
        return out.reshape(shape)
    raise ValueError(f"unknown backend {b!r}")

"""Dispatching wrappers: one call site per op, three interchangeable backends.

  * ``pallas``           — the TPU kernels (Mosaic lowering on TPU).
  * ``pallas_interpret`` — same kernel bodies, interpreted on CPU (tests).
  * ``xla``              — blocked pure-JAX implementations with the same
                           memory behaviour (O(tile) attention, scan-carried
                           recurrences).  Used on CPU and for the dry-run so
                           the lowered HLO is backend-portable.

``backend="auto"`` picks pallas on TPU, xla elsewhere.  All backends are
bit-compatible up to float tolerance with :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import ref as _ref
from . import rglru as _rglru
from . import rwkv6 as _rwkv6

Backend = Literal["auto", "xla", "pallas", "pallas_interpret", "ref", "stub"]
# "stub": HBM-traffic stand-in for dry-run cost probes — reads every input
# once and writes the true output shape, with negligible flops, matching
# the Pallas kernel's memory behaviour (tiles never spill score tensors to
# HBM).  The dry-run adds the kernels' flops analytically.


def _auto() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              block_q: int = 1024, block_kv: int = 1024,
              backend: Backend = "auto"):
    """Multi-head GQA attention. q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D)."""
    b = _auto() if backend == "auto" else backend
    if b == "stub":
        hq, hkv = q.shape[2], k.shape[2]
        kv = (k.sum(1) + v.sum(1))[:, None]            # reads k, v fully
        return (q * jnp.repeat(kv, hq // hkv, 2)).astype(q.dtype)
    if b == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window)
    if b in ("pallas", "pallas_interpret"):
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=min(block_q, 512), block_kv=min(block_kv, 512),
            interpret=(b == "pallas_interpret"))
    return _attention_xla(q, k, v, causal=causal, window=window,
                          block_kv=block_kv)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_kv"))
def _attention_xla(q, k, v, *, causal, window, block_kv):
    """Blocked online-softmax attention in pure JAX (scan over kv tiles)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    group = Hq // Hkv
    block_kv = int(min(block_kv, Skv))
    n_tiles = (Skv + block_kv - 1) // block_kv
    pad = n_tiles * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.astype(jnp.float32) / np.sqrt(D)
    qg = qf.reshape(B, Sq, Hkv, group, D)
    kt = k.reshape(B, n_tiles, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vt = v.reshape(B, n_tiles, block_kv, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(Sq) + (Skv - Sq)

    def step(carry, tile):
        m, l, acc = carry
        kb, vb, it = tile
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb.astype(jnp.float32))
        k_pos = it * block_kv + jnp.arange(block_kv)
        mask = k_pos[None, :] < Skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhe->bqhge", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, Hkv, group), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kt, vt, jnp.arange(n_tiles)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (one token, KV cache, per-sequence lengths)
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, lengths, *,
                     backend: Backend = "auto"):
    b = _auto() if backend == "auto" else backend
    if b == "stub":
        hq, hkv = q.shape[2], k_cache.shape[2]
        kv = (k_cache.sum(1) + v_cache.sum(1))[:, None]
        scale = (1 + lengths.astype(q.dtype) * 0)[:, None, None, None]
        return (q * jnp.repeat(kv, hq // hkv, 2) * scale).astype(q.dtype)
    if b == "ref":
        return _ref.attention(q, k_cache, v_cache, causal=True,
                              lengths=lengths)
    if b in ("pallas", "pallas_interpret"):
        return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                     interpret=(b == "pallas_interpret"))
    return _decode_xla(q, k_cache, v_cache, lengths)


@jax.jit
def _decode_xla(q, k_cache, v_cache, lengths):
    B, _, Hq, D = q.shape
    _, S, Hkv, Dv = v_cache.shape
    group = Hq // Hkv
    qg = q.astype(jnp.float32).reshape(B, Hkv, group, D) / np.sqrt(D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhe->bhge", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# gated linear recurrence (RG-LRU core)
# ---------------------------------------------------------------------------
def linear_scan(a, b, h0=None, *, backend: Backend = "auto"):
    """h_t = a_t h_{t-1} + b_t over axis 1.  a, b: (B, S, D)."""
    be = _auto() if backend == "auto" else backend
    if be == "stub":
        h = (a * b).astype(a.dtype)                    # reads a, b; writes h
        last = h[:, -1].astype(jnp.float32) + (
            0.0 if h0 is None else h0.astype(jnp.float32))
        return h, last
    if be == "ref":
        return _ref.linear_scan(a, b, h0)
    if be in ("pallas", "pallas_interpret"):
        return _rglru.rglru_scan(a, b, h0,
                                 interpret=(be == "pallas_interpret"))
    return _linear_scan_xla(a, b, h0)


@jax.jit
def _linear_scan_xla(a, b, h0=None):
    """Log-depth associative scan (Blelloch) — XLA-friendly."""
    B, S, D = a.shape
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 (h0) + b_1
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    aa, bb = jax.lax.associative_scan(combine, (af, bf), axis=1)
    h_all = bb.astype(a.dtype)
    return h_all, bb[:, -1]


# ---------------------------------------------------------------------------
# RWKV-6 recurrence
# ---------------------------------------------------------------------------
def rwkv6(r, k, v, w, u, state0=None, *, backend: Backend = "auto"):
    be = _auto() if backend == "auto" else backend
    if be == "stub":
        g = (r + k + w).sum(-1, keepdims=True)         # reads r, k, w
        y = (v * g).astype(v.dtype)                    # reads v, writes y
        B, T, H, D = r.shape
        Dv = v.shape[-1]
        s0 = (jnp.zeros((B, H, D, Dv), jnp.float32) if state0 is None
              else state0.astype(jnp.float32))
        sT = s0 + (k.astype(jnp.float32).mean(1)[..., None]
                   * v.astype(jnp.float32).mean(1)[..., None, :])
        return y, sT
    if be == "ref":
        return _ref.rwkv6(r, k, v, w, u, state0)
    if be in ("pallas", "pallas_interpret"):
        return _rwkv6.rwkv6_scan(r, k, v, w, u, state0,
                                 interpret=(be == "pallas_interpret"))
    return _rwkv6_xla(r, k, v, w, u, state0)


@jax.jit
def _rwkv6_xla(r, k, v, w, u, state0=None):
    B, T, H, D = r.shape
    Dv = v.shape[-1]
    S0 = (jnp.zeros((B, H, D, Dv), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., None] * vt[..., None, :]               # (B,H,D,Dv)
        y = ((S + uf[None, :, :, None] * kv)
             * rt[..., None]).sum(axis=2)                   # (B,H,Dv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(x.astype(jnp.float32).transpose(1, 0, 2, 3)
               for x in (r, k, v, w))
    S, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3).astype(v.dtype)            # (B,T,H,Dv)
    return y, S

"""Pure-jnp reference oracles for every kernel.

These are the semantic ground truth: naive, O(S^2)-memory where applicable,
no blocking, no numerics tricks beyond float32 softmax.  Kernel tests sweep
shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def piecewise_slowdown(own, ext, own_knots, ext_knots, table):
    """Reference batched piecewise-linear PCCS slowdown surface.

    Bilinear interpolation of ``table`` over the (own, ext) grid with
    clamped extension outside, expressed gather-free as a tensor product of
    1-D hat bases: ``s = Σ_i Σ_j hat_i(own) hat_j(ext) table[i, j]`` — the
    same contraction the Pallas kernel in :mod:`repro.kernels.slowdown`
    runs blocked on the MXU.  Zero own/external demand is the identity
    (slowdown 1), mirroring ``repro.core.contention.PiecewiseModel``.
    """
    own = jnp.asarray(own)
    ext = jnp.asarray(ext)
    shape = own.shape
    ho = _hat_weights(jnp.asarray(own_knots, own.dtype), own.reshape(-1))
    he = _hat_weights(jnp.asarray(ext_knots, ext.dtype), ext.reshape(-1))
    tab = jnp.asarray(table, own.dtype)
    s = jnp.einsum("bk,km,bm->b", ho, tab, he).reshape(shape)
    return jnp.where((own <= 0.0) | (ext <= 0.0), jnp.ones((), own.dtype), s)


def _hat_weights(knots, x):
    """(B, K) linear-interpolation hat weights of x against sorted knots.

    Row b holds the barycentric weights of ``x[b]``: for x inside
    ``[knots[i], knots[i+1]]`` exactly hats i and i+1 are non-zero and sum
    to 1; outside the grid the nearest end knot gets weight 1 (clamping).
    """
    k = knots[None, :]
    kprev = jnp.concatenate([knots[:1], knots[:-1]])[None, :]
    knext = jnp.concatenate([knots[1:], knots[-1:]])[None, :]
    xb = x[:, None]
    tiny = jnp.asarray(1e-30, x.dtype)
    up = (xb - kprev) / jnp.maximum(k - kprev, tiny)     # rising edge
    dn = (knext - xb) / jnp.maximum(knext - k, tiny)     # falling edge
    h = jnp.clip(jnp.minimum(up, dn), 0.0, 1.0)
    n = knots.shape[0]
    col = jnp.arange(n)[None, :]
    h = jnp.where((col == 0) & (xb <= knots[0]), 1.0, h)
    h = jnp.where((col == n - 1) & (xb >= knots[-1]), 1.0, h)
    return h


def anneal_select(cur, prop, best, cur_obj, prop_obj, best_obj, u, temp):
    """Reference Metropolis accept + incumbent select over a population.

    ``cur``/``prop``/``best`` are (P, L) assignment rows; ``cur_obj``/
    ``prop_obj``/``best_obj``/``u`` are (P,); ``temp`` is a scalar
    temperature.  A proposal is accepted when it does not regress, or with
    the Metropolis probability ``exp(-delta/temp)`` against the uniform
    draw ``u``; the per-chain incumbent takes every strict improvement
    (first-found wins on ties).  Chains whose proposal scored non-finite
    (error-poisoned lanes) always reject.  Returns
    ``(new_cur, new_cur_obj, new_best, new_best_obj)``.
    """
    cur_obj = jnp.asarray(cur_obj)
    dt = cur_obj.dtype
    prop_obj = jnp.asarray(prop_obj, dt)
    best_obj = jnp.asarray(best_obj, dt)
    u = jnp.asarray(u, dt)
    temp = jnp.maximum(jnp.asarray(temp, dt), jnp.asarray(1e-30, dt))
    delta = prop_obj - cur_obj
    accept = (delta <= 0) | (u < jnp.exp(-delta / temp))
    accept &= jnp.isfinite(prop_obj)
    improved = prop_obj < best_obj
    new_cur = jnp.where(accept[:, None], prop, cur)
    new_cur_obj = jnp.where(accept, prop_obj, cur_obj)
    new_best = jnp.where(improved[:, None], prop, best)
    new_best_obj = jnp.where(improved, prop_obj, best_obj)
    return new_cur, new_cur_obj, new_best, new_best_obj


def _gqa_expand(k, n_heads):
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating kv heads."""
    b, s, hkv, d = k.shape
    rep = n_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              lengths=None):
    """Reference attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, Dk/Dv).  GQA via head repetition.
    ``window``: local attention — position i attends to [i-window+1, i]
    (combined with causal).  ``lengths``: (B,) valid kv lengths (decode).
    For Sq < Skv the queries are the *last* Sq positions (decode offset).
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    q_pos = jnp.arange(sq) + (skv - sq)         # absolute query positions
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask = jnp.broadcast_to(mask[None, None], logits.shape)
    if lengths is not None:
        valid = k_pos[None, :] < lengths[:, None]          # (B, Skv)
        mask &= valid[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jnp.nan_to_num(jnp.exp(logits - logits.max(-1, keepdims=True)))
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def linear_scan(a, b, h0=None):
    """Reference gated linear recurrence: h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, D); h0: (B, D) or None (zeros).  Returns (h_all, h_last).
    Sequential python loop over S — the oracle for rglru.
    """
    B, S, D = a.shape
    h = jnp.zeros((B, D), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    hs = []
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    for t in range(S):
        h = af[:, t] * h + bf[:, t]
        hs.append(h)
    h_all = jnp.stack(hs, axis=1).astype(a.dtype)
    return h_all, h


def rwkv6(r, k, v, w, u, state0=None):
    """Reference RWKV-6 (Finch) recurrence.

    Per head with state S in R^{D x Dv}:
        y_t = (S_{t-1} + (u ⊙ k_t) v_t^T)^T r_t
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    r, k, w: (B, T, H, D); v: (B, T, H, Dv); u: (H, D);
    state0: (B, H, D, Dv).  Returns (y (B,T,H,Dv), state (B,H,D,Dv)).
    ``w`` is the per-step decay in (0, 1) (already exp(-exp(...))-activated).
    """
    B, T, H, D = r.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    S = (jnp.zeros((B, H, D, Dv), f32) if state0 is None
         else state0.astype(f32))
    ys = []
    rf, kf, vf, wf = (x.astype(f32) for x in (r, k, v, w))
    uf = u.astype(f32)
    for t in range(T):
        kt = kf[:, t]                     # (B,H,D)
        vt = vf[:, t]                     # (B,H,Dv)
        rt = rf[:, t]
        wt = wf[:, t]
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt, S + uf[None] [..., None] * kv)
        S = wt[..., None] * S + kv
        ys.append(y)
    y_all = jnp.stack(ys, axis=1).astype(v.dtype)
    return y_all, S

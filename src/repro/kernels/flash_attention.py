"""Blocked flash attention as a Pallas TPU kernel.

Online-softmax attention with explicit BlockSpec VMEM tiling, MXU-aligned
(128-multiple) q/kv tiles, GQA via index-mapped kv head selection, and
causal / local-window / bidirectional masking with fully-masked-tile
skipping.  Grid = (batch, q_heads, q_tiles, kv_tiles); the kv dimension is
innermost (sequential on TPU), with the running max / denominator / output
accumulator carried in VMEM scratch across kv tiles.

Validated against :mod:`repro.kernels.ref` in interpret mode on CPU; on a
real TPU backend the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_kv: int, seq_q: int, seq_kv: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile's queries/keys (queries are the last
    # seq_q positions of the kv timeline — decode-style offset).
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + (seq_kv - seq_q)
    k_pos = ikv * block_kv + jax.lax.iota(jnp.int32, block_kv)

    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bkv, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (bkv, dv)
        # zero the padded kv tail: p is 0 there but 0*NaN would poison acc
        kv_valid = (k_pos < seq_kv)[:, None]
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones((block_q, block_kv), dtype=jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos[None, :] < seq_kv) & (q_pos[:, None] <
                                             seq_kv)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal or window is not None:
        # skip tiles that are entirely masked out
        first_q = iq * block_q + (seq_kv - seq_q)
        last_q = first_q + block_q - 1
        first_k = ikv * block_kv
        last_k = first_k + block_kv - 1
        live = jnp.bool_(True)
        if causal:
            live &= first_k <= last_q
        if window is not None:
            live &= last_k > first_q - window
        pl.when(live)(_tile)
    else:
        _tile()

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v.shape
    assert hq % hkv == 0, "GQA requires n_heads % n_kv_heads == 0"
    group = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    nq = pl.cdiv(sq, block_q)
    nkv = pl.cdiv(skv, block_kv)
    scale = 1.0 / (d ** 0.5)

    grid = (b, hq, nq, nkv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_q=sq, seq_kv=skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b_, h, iq, ikv: (b_, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b_, h, iq, ikv, g=group: (b_, ikv, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, dv),
                         lambda b_, h, iq, ikv, g=group: (b_, ikv, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv),
                               lambda b_, h, iq, ikv: (b_, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),          # running max
            pltpu.VMEM((block_q,), jnp.float32),          # denominator
            pltpu.VMEM((block_q, dv), jnp.float32),       # output accum
        ],
        interpret=interpret,
    )(q, k, v)

"""RWKV-6 (Finch) time-mix recurrence as a Pallas TPU kernel.

Per head h with matrix state S in R^{DxDv}:

    y_t = r_t^T (S_{t-1} + (u ⊙ k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t: data-dependent decay)

Grid = (batch, heads, time_tiles); the (D, Dv) state lives in VMEM scratch
across the sequential time-tile axis, and each tile walks its steps with a
fori_loop of rank-1 updates (outer products on the VPU — D=64 keeps the
state at 16 KiB, far under VMEM).  This is the TPU-native adaptation of the
CUDA wkv kernels: channels-per-head map to lanes, the head axis to the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            state, *, block_t: int, seq_len: int):
    it = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                        # (D,)

    def body(t, S):
        r = r_ref[0, t, 0, :].astype(jnp.float32)           # (D,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)           # (D,)
        v = v_ref[0, t, 0, :].astype(jnp.float32)           # (Dv,)
        w = w_ref[0, t, 0, :].astype(jnp.float32)           # (D,)
        kv = k[:, None] * v[None, :]                        # (D, Dv)
        y = ((S + u[:, None] * kv) * r[:, None]).sum(axis=0)
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        # steps past seq_len are tile padding: keep state unchanged
        valid = it * block_t + t < seq_len
        return jnp.where(valid, w[:, None] * S + kv, S)

    state[...] = jax.lax.fori_loop(0, block_t, body, state[...])

    @pl.when(it == n_t - 1)
    def _finalize():
        sT_ref[0, 0] = state[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, state0=None, *, block_t: int = 128,
               interpret: bool = False):
    """r,k,w: (B,T,H,D); v: (B,T,H,Dv); u: (H,D); state0: (B,H,D,Dv).

    Returns (y (B,T,H,Dv), state (B,H,D,Dv)).
    """
    B, T, H, D = r.shape
    Dv = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, D, Dv), jnp.float32)
    block_t = min(block_t, T)
    grid = (B, H, pl.cdiv(T, block_t))
    kernel = functools.partial(_kernel, block_t=block_t, seq_len=T)
    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, block_t, 1, Dv), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, D), lambda b, h, it: (h, 0)),
            pl.BlockSpec((1, 1, D, Dv), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, 1, Dv), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, 1, D, Dv), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, Dv), v.dtype),
            jax.ShapeDtypeStruct((B, H, D, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, Dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state0)
    return y, sT

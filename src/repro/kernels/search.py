"""Annealing select step (Metropolis accept + incumbent update) as a kernel.

The inner step of the device-resident schedule search
(:mod:`repro.core.search_jax`) is, per temperature step: every chain's
mutated assignment row has been scored by the event machine, and the
population must be *selected* — Metropolis-accept each proposal against the
chain's current state and fold strict improvements into the per-chain
incumbent.  That step is one elementwise decision broadcast across a
(P, L) block of assignment rows: a natural Pallas kernel, blocked over the
chain axis with the row length riding whole.

Backends follow the repo-wide dispatch idiom (:mod:`repro.kernels.slowdown`):

  * ``pallas``           — Mosaic lowering on TPU;
  * ``pallas_interpret`` — same kernel body, interpreted (tests on CPU);
  * ``xla``              — the identical decision in pure jnp
                           (:func:`repro.kernels.ref.anneal_select`), used
                           on CPU where a kernel launch cannot pay for
                           itself;
  * ``auto``             — pallas on TPU for big populations, xla otherwise.

All backends compute the same accept predicate from the same uniform draws,
so the search incumbent is bit-identical across them — pinned by
``tests/test_search.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import anneal_select as _ref_select

#: below this many chains a pallas launch cannot pay for itself —
#: ``backend="auto"`` stays on the fused-XLA decision instead.
_MIN_PALLAS_CHAINS = 1024


def _kernel(cur_ref, prop_ref, best_ref, curo_ref, propo_ref, besto_ref,
            u_ref, temp_ref, out_cur_ref, out_curo_ref, out_best_ref,
            out_besto_ref):
    cur = cur_ref[...]                       # (B, L) int32
    prop = prop_ref[...]
    best = best_ref[...]
    curo = curo_ref[...][0]                  # (B,)
    propo = propo_ref[...][0]
    besto = besto_ref[...][0]
    u = u_ref[...][0]
    temp = jnp.maximum(temp_ref[0, 0], jnp.asarray(1e-30, curo.dtype))
    delta = propo - curo
    accept = (delta <= 0) | (u < jnp.exp(-delta / temp))
    accept &= jnp.isfinite(propo)
    improved = propo < besto
    out_cur_ref[...] = jnp.where(accept[:, None], prop, cur)
    out_curo_ref[...] = jnp.where(accept, propo, curo)[None, :]
    out_best_ref[...] = jnp.where(improved[:, None], prop, best)
    out_besto_ref[...] = jnp.where(improved, propo, besto)[None, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _pallas_select(cur, prop, best, cur_obj, prop_obj, best_obj, u, temp, *,
                   block: int, interpret: bool):
    p, l = cur.shape
    nb = pl.cdiv(p, block)
    pad = nb * block - p
    if pad:
        cur, prop, best = (jnp.pad(a, ((0, pad), (0, 0)))
                           for a in (cur, prop, best))
        cur_obj, prop_obj, best_obj, u = (
            jnp.pad(a, (0, pad)) for a in (cur_obj, prop_obj, best_obj, u))
    row = pl.BlockSpec((block, l), lambda i: (i, 0))
    col = pl.BlockSpec((1, block), lambda i: (i, 0))
    dt = cur_obj.dtype
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[row, row, row, col, col, col, col,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[row, col, row, col],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block, l), cur.dtype),
            jax.ShapeDtypeStruct((nb, block), dt),
            jax.ShapeDtypeStruct((nb * block, l), cur.dtype),
            jax.ShapeDtypeStruct((nb, block), dt),
        ],
        interpret=interpret,
    )(cur, prop, best,
      cur_obj.reshape(nb, block), prop_obj.reshape(nb, block),
      best_obj.reshape(nb, block), u.reshape(nb, block),
      temp.reshape(1, 1).astype(dt))
    return (out[0][:p], out[1].reshape(-1)[:p],
            out[2][:p], out[3].reshape(-1)[:p])


def anneal_select(cur, prop, best, cur_obj, prop_obj, best_obj, u, temp, *,
                  backend: str = "auto", block: int = 256,
                  global_lanes: int | None = None):
    """Metropolis accept + per-chain incumbent update over (P, L) rows.

    Semantics (and the reference oracle) live in
    :func:`repro.kernels.ref.anneal_select`; this wrapper dispatches the
    same decision to a blocked Pallas kernel or the fused XLA form.
    ``global_lanes`` is the population across *all* mesh shards — under
    ``shard_map`` each device sees only its slice of the chain axis, and
    the ``auto`` big-population threshold must be judged on the global
    lane count so backend choice (hence bit-identity) does not change
    with device count.  Returns ``(new_cur, new_cur_obj, new_best,
    new_best_obj)``.
    """
    cur = jnp.asarray(cur)
    cur_obj = jnp.asarray(cur_obj)
    dt = cur_obj.dtype
    prop_obj = jnp.asarray(prop_obj, dt)
    best_obj = jnp.asarray(best_obj, dt)
    u = jnp.asarray(u, dt)
    temp = jnp.asarray(temp, dt)
    b = backend
    if b == "auto":
        big = (global_lanes or cur.shape[0]) >= _MIN_PALLAS_CHAINS
        b = "pallas" if (jax.default_backend() == "tpu" and big) else "xla"
    if b in ("xla", "ref"):
        return _ref_select(cur, jnp.asarray(prop), jnp.asarray(best),
                           cur_obj, prop_obj, best_obj, u, temp)
    if b in ("pallas", "pallas_interpret"):
        return _pallas_select(
            cur, jnp.asarray(prop), jnp.asarray(best), cur_obj, prop_obj,
            best_obj, u, temp, block=min(block, max(8, cur.shape[0])),
            interpret=(b == "pallas_interpret"))
    raise ValueError(f"unknown backend {b!r}")

"""Calibration tests: profiles reproduce the paper's published numbers."""
import pytest

from repro.core import api
from repro.core.graph import DNNGraph
from repro.core.grouping import RawLayer, group_layers
from repro.core.profiles import DNN_SET, TABLE2_GOOGLENET, TABLE5, get_graph


class TestTable5Calibration:
    @pytest.mark.parametrize("dnn", sorted(TABLE5))
    @pytest.mark.parametrize("plat_name,gcol,dcol", [
        ("agx-orin", 0, 1), ("xavier-agx", 2, 3)])
    def test_standalone_totals_match(self, dnn, plat_name, gcol, dcol):
        plat = api.resolve_platform(plat_name)
        g = get_graph(dnn, plat)
        assert g.standalone_time("GPU") == pytest.approx(
            TABLE5[dnn][gcol], rel=1e-6)
        if TABLE5[dnn][dcol] is not None:
            assert g.standalone_time("DLA") == pytest.approx(
                TABLE5[dnn][dcol], rel=1e-6)
        else:
            assert "DLA" not in g.accelerators

    def test_densenet_has_no_dla_on_xavier_only(self):
        xav = get_graph("densenet", api.resolve_platform("xavier-agx"))
        orin = get_graph("densenet", api.resolve_platform("agx-orin"))
        assert "DLA" not in xav.accelerators
        assert "DLA" in orin.accelerators


class TestTable2Calibration:
    def test_googlenet_group_ratios_in_published_range(self):
        # Raw Table-2 ratios span 1.40x..2.02x; rescaling to the Table-5
        # standalone totals preserves the relative spread (2.02/1.40) and the
        # per-group ordering, which is what drives scheduling decisions.
        g = get_graph("googlenet", api.resolve_platform("xavier-agx"))
        ratios = [grp.time_on("DLA") / grp.time_on("GPU") for grp in g]
        assert max(ratios) / min(ratios) == pytest.approx(2.02 / 1.40,
                                                          rel=0.02)
        raw = [row[2] / row[1] for row in TABLE2_GOOGLENET]
        order = sorted(range(len(raw)), key=raw.__getitem__)
        assert order == sorted(range(len(ratios)), key=ratios.__getitem__)

    def test_googlenet_transition_times_reproduced(self):
        plat = api.resolve_platform("xavier-agx")
        g = get_graph("googlenet", plat)
        for grp, row in zip(g, TABLE2_GOOGLENET):
            tau = plat.transition_cost_ms(grp.out_bytes, "GPU", "DLA")
            assert tau == pytest.approx(row[3], abs=2e-3)

    def test_memory_throughput_column(self):
        g = get_graph("googlenet", api.resolve_platform("xavier-agx"))
        for grp, row in zip(g, TABLE2_GOOGLENET):
            assert grp.demand_on("GPU") == pytest.approx(row[4], rel=1e-6)
            # black-box DSA estimate is below the GPU demand (DLA is slower)
            assert grp.demand_on("DLA") < grp.demand_on("GPU")


class TestFig1CaseStudy:
    """Fig. 1: VGG-19 + ResNet101 on Xavier AGX."""

    @pytest.fixture(scope="class")
    def setup(self):
        plat = api.resolve_platform("xavier-agx")
        return plat, api.resolve_graphs(["vgg19", "resnet101"], plat)

    def test_case1_serial_gpu(self, setup):
        _, res = api.evaluate_baseline("fastest_only", ["vgg19", "resnet101"],
                                       "xavier-agx")
        assert res.latency_ms == pytest.approx(11.3, rel=0.02)  # paper: 11.3

    def test_case2_naive_concurrent(self, setup):
        # Paper's Fig. 1 reports 10.6 ms — numerically identical to the
        # *contention-free* DLA standalone of ResNet101 (Table 5), which is
        # inconsistent with the paper's own Table-6 contention levels
        # (exp 1: naive = 16.05 vs a 12.71 contention-free floor, +26%).
        # Our calibration is anchored to Table 6, so Case 2 lands between
        # the contention-free floor and the Table-6 inflation level.
        _, res = api.evaluate_baseline("naive_concurrent",
                                       ["vgg19", "resnet101"], "xavier-agx")
        assert 10.6 <= res.latency_ms <= 10.6 * 1.35
        _, naive_152 = api.evaluate_baseline(
            "naive_concurrent", ["vgg19", "resnet152"], "xavier-agx")
        assert 12.71 < naive_152.latency_ms <= 16.05 * 1.05

    def test_case3_haxconn_considerably_better(self, setup):
        sol = api.schedule(["vgg19", "resnet101"], "xavier-agx", "latency")
        assert sol.optimal
        # certified better than the best baseline (serial GPU, 11.29ms) by a
        # material margin; the paper's headline pair (exp 1, ResNet152)
        # reaches ~19-23%, checked in benchmarks/table6_scenarios.py.
        best_base = 11.29
        improvement = 1 - sol.result.latency_ms / best_base
        assert 0.05 <= improvement <= 0.40
        # the optimal schedule uses both accelerators with transitions
        used = {a for asg in sol.assignments for a in asg}
        assert used == {"GPU", "DLA"}


class TestGrouping:
    def test_fusion_and_legality_rules(self):
        layers = [
            RawLayer("conv1", "conv", {"A": 1.0}, fuse_with_next=True),
            RawLayer("bn1", "norm", {"A": 0.1}),
            RawLayer("elt", "eltwise", {"A": 0.2}, no_transition_after=True),
            RawLayer("conv2", "conv", {"A": 1.0}, reformat_after=True),
            RawLayer("pool", "pool", {"A": 0.3}),
            RawLayer("fc", "fc", {"A": 0.5}),
        ]
        g = group_layers("net", layers)
        # conv1+bn1 fused; elt merges into conv2's group; conv2 reformat
        # merges forward until the cheap pool boundary.
        assert len(g) == 3
        assert g[0].name == "conv1..bn1"
        assert g[0].time_on("A") == pytest.approx(1.1)
        assert g[1].name == "elt..pool"
        assert g[2].name == "fc"

    def test_group_total_time_preserved(self):
        layers = [RawLayer(f"l{i}", "conv", {"A": 0.5, "B": 1.0},
                           fuse_with_next=(i % 2 == 0)) for i in range(6)]
        g = group_layers("net", layers)
        assert g.standalone_time("A") == pytest.approx(3.0)
        assert g.standalone_time("B") == pytest.approx(6.0)

    def test_merged_preserves_totals(self):
        plat = api.resolve_platform("xavier-agx")
        g = get_graph("resnet101", plat)
        m = g.merged([1, 4])
        assert isinstance(m, DNNGraph)
        assert len(m) == 3
        assert m.standalone_time("GPU") == pytest.approx(
            g.standalone_time("GPU"))
        assert m.standalone_time("DLA") == pytest.approx(
            g.standalone_time("DLA"))


@pytest.mark.parametrize("dnn", DNN_SET)
def test_all_dnns_resolvable_on_all_soc_platforms(dnn):
    for plat_name in ("agx-orin", "xavier-agx", "snapdragon-865"):
        plat = api.resolve_platform(plat_name)
        g = get_graph(dnn, plat)
        assert len(g) >= 4
        assert g.standalone_time("GPU") > 0

"""repro.profiling: harness discipline, virtual SoC, calibration, bundles.

The acceptance loop: profile on the deterministic virtual SoC → calibrate
a PCCS surface → pack a content-hashed ProfileBundle → solve a Table-6
style schedule from the bundle — asserting at each stage that the
measured pipeline reproduces the generating ground truth.
"""
import json

import numpy as np
import pytest

from repro import profiling
from repro.core import Scheduler
from repro.core.accelerators import xavier_agx
from repro.core.contention import PiecewiseModel, ProportionalShareModel
from repro.core.plan import platform_fingerprint
from repro.core.profiles import get_graph
from repro.profiling import (ProfileBundle, TimerConfig, VirtualSoC,
                             calibrate, paper_like_pccs,
                             platform_from_bundle, scheduler_from_bundle)


@pytest.fixture(scope="module")
def platform():
    return xavier_agx()


@pytest.fixture(scope="module")
def truth_graphs(platform):
    return [get_graph(d, platform) for d in ("vgg19", "resnet101")]


@pytest.fixture(scope="module")
def pipeline(platform, truth_graphs):
    """One shared profile→calibrate→bundle run (the expensive part)."""
    vsoc = VirtualSoC(platform, truth_graphs, noise=0.003,
                      outlier_rate=0.05, seed=0)
    bundle = profiling.run_pipeline(vsoc)
    return vsoc, bundle


# ---------------------------------------------------------------------------
# timing discipline
# ---------------------------------------------------------------------------

class TestTimer:
    def test_outlier_rejection(self):
        times = [1.0, 1.02, 0.99, 1.01, 1.0, 5.0, 0.98]
        kept, rejected = profiling.reject_outliers(times)
        assert rejected == [5.0]
        assert 5.0 not in kept and len(kept) == 6

    def test_min_kept_floor(self):
        # pathological spread: never reject below min_kept samples
        kept, rejected = profiling.reject_outliers(
            [1.0, 10.0, 100.0], min_kept=3)
        assert len(kept) == 3 and not rejected

    def test_zero_mad_keeps_all(self):
        kept, rejected = profiling.reject_outliers([2.0, 2.0, 2.0, 9.0])
        # median-absolute-deviation degenerates to 0: nothing is scored
        assert len(kept) == 4 and not rejected

    def test_measure_samples_applies_discipline(self):
        seq = iter([7.0, 7.0,           # warmup, discarded
                    1.0, 1.0, 1.02, 0.98, 1.0, 42.0, 1.01])
        m = profiling.measure_samples(lambda: next(seq),
                                      timer=TimerConfig(warmup=2, repeats=7),
                                      name="synthetic")
        assert m.rejected_ms == (42.0,)
        assert m.median_ms == pytest.approx(1.0)
        assert m.n_total == 7

    def test_measure_wallclock_jax(self):
        import jax.numpy as jnp
        x = jnp.ones((64, 64))
        m = profiling.measure_wallclock(
            lambda: x @ x, timer=TimerConfig(warmup=1, repeats=3),
            name="matmul")
        assert m.median_ms > 0.0
        assert len(m.kept_ms) >= TimerConfig().min_kept

    def test_timer_config_validates(self):
        with pytest.raises(ValueError):
            TimerConfig(repeats=0)
        t = TimerConfig(warmup=1, repeats=5)
        assert TimerConfig.from_dict(t.to_dict()) == t


# ---------------------------------------------------------------------------
# virtual SoC
# ---------------------------------------------------------------------------

class TestVirtualSoC:
    def test_deterministic(self, platform, truth_graphs):
        a = VirtualSoC(platform, truth_graphs, seed=7)
        b = VirtualSoC(platform, truth_graphs, seed=7)
        seq_a = [a.run_group("vgg19", 0, "GPU", e) for e in (0, 0.5, 0.9)]
        seq_b = [b.run_group("vgg19", 0, "GPU", e) for e in (0, 0.5, 0.9)]
        assert seq_a == seq_b

    def test_noise_free_matches_ground_truth(self, platform, truth_graphs):
        vsoc = VirtualSoC(platform, truth_graphs, noise=0.0, seed=0)
        g = truth_graphs[0]
        assert vsoc.run_group(g.name, 1, "GPU") == g.groups[1].time_on("GPU")
        own = g.groups[1].demand_on("GPU")
        t_co = vsoc.run_group(g.name, 1, "GPU", external=0.8)
        want = g.groups[1].time_on("GPU") * paper_like_pccs().slowdown(
            own, 0.8)
        assert t_co == pytest.approx(want)

    def test_contention_slows_down(self, platform, truth_graphs):
        vsoc = VirtualSoC(platform, truth_graphs, noise=0.0, seed=0)
        base = vsoc.run_group("vgg19", 0, "GPU")
        assert vsoc.run_group("vgg19", 0, "GPU", external=0.9) > base


# ---------------------------------------------------------------------------
# measured profiles
# ---------------------------------------------------------------------------

class TestProfileGraphs:
    def test_measured_times_match_truth(self, pipeline, truth_graphs):
        _, bundle = pipeline
        for truth in truth_graphs:
            measured = bundle.graph(truth.name)
            assert len(measured) == len(truth)
            for mg, tg in zip(measured.groups, truth.groups):
                for acc in tg.times:
                    assert mg.time_on(acc) == pytest.approx(
                        tg.time_on(acc), rel=0.05)
                    assert mg.demand_on(acc) == pytest.approx(
                        tg.demand_on(acc), rel=0.1)

    def test_samples_cover_demand_grid(self, pipeline):
        _, bundle = pipeline
        own = {round(s[0], 3) for s in bundle.samples}
        ext = {s[1] for s in bundle.samples}
        assert len(own) > 5 and len(ext) >= 5
        assert all(s[2] >= 1.0 for s in bundle.samples)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

class TestCalibrate:
    def test_acceptance_five_percent(self, pipeline):
        """Fitted PCCS reproduces the generating model's co-run slowdowns
        within 5% across the sampled (own, external) grid."""
        vsoc, bundle = pipeline
        for own, ext, _ in bundle.samples:
            true = vsoc.true_slowdown("GPU", own, ext)
            got = bundle.model.slowdown(own, ext)
            assert got == pytest.approx(true, rel=0.05)

    def test_fitted_table_is_monotone_and_floored(self, pipeline):
        _, bundle = pipeline
        tab = np.asarray(bundle.model.table)
        assert (tab >= 1.0).all()
        assert (np.diff(tab, axis=0) >= 0).all()
        assert (np.diff(tab, axis=1) >= 0).all()

    def test_fit_reports_residuals(self, pipeline):
        _, bundle = pipeline
        fit = bundle.provenance["fit"]
        assert fit["n_samples"] == len(bundle.samples)
        assert 0.0 <= fit["max_rel_err"] < 0.05
        assert fit["rmse"] < 0.05

    def test_exactly_representable_surface_recovered(self):
        truth = paper_like_pccs()
        rng = np.random.default_rng(1)
        own = rng.uniform(0.1, 0.95, 400)
        ext = rng.uniform(0.1, 0.95, 400)
        samples = [(o, e, truth.slowdown(o, e)) for o, e in zip(own, ext)]
        r = calibrate.fit_piecewise(samples, own_knots=truth.own_knots,
                                    ext_knots=truth.ext_knots)
        assert r.report.max_rel_err < 0.02
        got = np.asarray(r.model.table)
        assert np.allclose(got, np.asarray(truth.table), atol=0.05)

    def test_fit_proportional_recovers_parameters(self):
        truth = ProportionalShareModel(capacity=1.0, sensitivity=3.0)
        rng = np.random.default_rng(2)
        own = rng.uniform(0.1, 1.0, 300)
        ext = rng.uniform(0.1, 1.0, 300)
        samples = [(o, e, truth.slowdown(o, e)) for o, e in zip(own, ext)]
        r = calibrate.fit_proportional(samples)
        assert r.model.capacity == pytest.approx(1.0, abs=0.1)
        assert r.model.sensitivity == pytest.approx(3.0, abs=0.3)

    def test_noisy_nonmonotone_samples_still_yield_valid_model(self):
        truth = paper_like_pccs()
        rng = np.random.default_rng(3)
        own = rng.uniform(0.1, 0.9, 150)
        ext = rng.uniform(0.1, 0.9, 150)
        sd = np.maximum(1.0, [truth.slowdown(o, e) * (1 + 0.08 * z)
                              for o, e, z in
                              zip(own, ext, rng.standard_normal(150))])
        r = calibrate.fit_piecewise(list(zip(own, ext, sd)))
        tab = np.asarray(r.model.table)       # PiecewiseModel validated it,
        assert (tab >= 1.0).all()             # but assert the projection
        assert (np.diff(tab, axis=0) >= -1e-12).all()
        assert (np.diff(tab, axis=1) >= -1e-12).all()

    def test_rejects_bad_samples(self):
        with pytest.raises(ValueError):
            calibrate.fit_piecewise([])
        with pytest.raises(ValueError):
            calibrate.fit_piecewise([(0.5, 0.5, 0.2)])   # slowdown < 1
        with pytest.raises(ValueError):
            calibrate.fit(
                [(0.5, 0.5, 1.2)], "gaussian-process")


class TestProportionalDifferential:
    """proportional_predict (the differentiable fitter form) must stay
    numerically identical to ProportionalShareModel.slowdown — a drift in
    either formula would silently mis-fit every proportional re-fit."""

    def _diff(self, own, ext, capacity, sensitivity):
        import jax.numpy as jnp
        model = ProportionalShareModel(capacity=capacity,
                                       sensitivity=sensitivity)
        scalar = np.asarray([model.slowdown(o, e)
                             for o, e in zip(own, ext)])
        vec = np.asarray(calibrate.proportional_predict(
            jnp.asarray(own), jnp.asarray(ext), capacity, sensitivity))
        np.testing.assert_allclose(vec, scalar, rtol=1e-6, atol=1e-6)

    def test_dense_grid_matches_scalar(self):
        rng = np.random.default_rng(11)
        own = rng.uniform(0.0, 1.2, 500)
        ext = rng.uniform(0.0, 1.2, 500)
        self._diff(own, ext, 1.0, 1.5)

    def test_fitted_parameters_match_scalar(self):
        # exercise the exact (capacity, sensitivity) a fit produces, not
        # just round numbers.
        truth = ProportionalShareModel(capacity=0.8, sensitivity=2.5)
        rng = np.random.default_rng(12)
        own = rng.uniform(0.05, 1.0, 200)
        ext = rng.uniform(0.05, 1.0, 200)
        r = calibrate.fit_proportional(
            [(o, e, truth.slowdown(o, e)) for o, e in zip(own, ext)])
        self._diff(own, ext, r.model.capacity, r.model.sensitivity)

    def test_own_zero_boundary(self):
        # own == 0 must give exactly 1.0 in both forms, even when the
        # total is far beyond capacity.
        own = np.zeros(5)
        ext = np.asarray([0.0, 0.5, 1.0, 2.0, 10.0])
        self._diff(own, ext, 0.7, 3.0)

    def test_total_equals_capacity_boundary(self):
        # total == capacity sits exactly on the free/contended breakpoint;
        # both forms must agree it is still free (slowdown 1.0).
        cap = 0.9
        own = np.asarray([0.1, 0.45, 0.9, 0.3])
        ext = cap - own
        self._diff(own, ext, cap, 2.0)


# ---------------------------------------------------------------------------
# bundle artifact
# ---------------------------------------------------------------------------

class TestBundle:
    def test_round_trip_hash_intact(self, pipeline):
        _, bundle = pipeline
        again = ProfileBundle.from_json(bundle.to_json())
        assert again.bundle_hash() == bundle.bundle_hash()
        assert again.graph_names == bundle.graph_names
        assert again.model == bundle.model
        assert again.samples == bundle.samples

    def test_save_load(self, pipeline, tmp_path):
        _, bundle = pipeline
        p = bundle.save(tmp_path / "profiles" / "x.json")
        assert ProfileBundle.load(p).bundle_hash() == bundle.bundle_hash()

    def test_tamper_check(self, pipeline):
        _, bundle = pipeline
        d = json.loads(bundle.to_json())
        d["graphs"][0]["groups"][0]["times"]["GPU"] *= 1.5
        with pytest.raises(ValueError, match="corrupt|incompatible"):
            ProfileBundle.from_dict(d)

    def test_format_check(self, pipeline):
        _, bundle = pipeline
        d = json.loads(bundle.to_json())
        d["format"] = 99
        with pytest.raises(ValueError, match="format"):
            ProfileBundle.from_dict(d)

    def test_unknown_graph_name(self, pipeline):
        _, bundle = pipeline
        with pytest.raises(KeyError, match="vgg19"):
            bundle.graph("nope")

    def test_platform_from_bundle(self, pipeline, platform, tmp_path):
        _, bundle = pipeline
        assert platform_fingerprint(platform_from_bundle(bundle)) == \
            platform_fingerprint(platform)
        p = bundle.save(tmp_path / "b.json")
        assert platform_from_bundle(p).name == platform.name


# ---------------------------------------------------------------------------
# the closed loop: solve from the measured bundle
# ---------------------------------------------------------------------------

class TestSolveFromBundle:
    def test_objective_matches_generating_plan(self, pipeline, platform,
                                               truth_graphs):
        """Table-6-style scenario solved from measured profiles lands
        within tolerance of the plan under the generating model."""
        _, bundle = pipeline
        sched = scheduler_from_bundle(bundle)
        plan = sched.solve(list(bundle.graphs), "latency",
                           max_transitions=2, deadline_s=20.0)
        truth = Scheduler(platform, model=paper_like_pccs()).solve(
            truth_graphs, "latency", max_transitions=2, deadline_s=20.0)
        assert plan.objective == pytest.approx(truth.objective, rel=0.05)
        # the plan is valid and carries provenance
        assert plan.optimal or plan.solver == "greedy"
        assert plan.request.platform.name == platform.name

    def test_scheduler_from_bundle_uses_calibrated_model(self, pipeline):
        _, bundle = pipeline
        sched = scheduler_from_bundle(bundle)
        assert isinstance(sched.model, PiecewiseModel)
        assert sched.model == bundle.model

    def test_core_scheduler_from_bundle_hook(self, pipeline, tmp_path):
        _, bundle = pipeline
        p = bundle.save(tmp_path / "b.json")
        sched = Scheduler.from_bundle(p)
        assert sched.platform.name == bundle.platform.name
        assert sched.model == bundle.model


# ---------------------------------------------------------------------------
# probes + jax harness (local backend, kept tiny)
# ---------------------------------------------------------------------------

class TestProbes:
    def test_stream_backends_agree(self):
        from repro.profiling import probes
        x, y = probes.make_buffers(0.02)
        a = np.asarray(probes.stream_once(x, y, backend="xla"))
        b = np.asarray(probes.stream_once(x, y,
                                          backend="pallas_interpret"))
        np.testing.assert_allclose(a, b, rtol=1e-6)
        with pytest.raises(ValueError, match="unknown backend"):
            probes.stream_once(x, y, backend="cuda")

    def test_memory_probe_lifecycle(self):
        from repro.profiling import probes
        probe = probes.MemoryProbe(demand=0.5, mbytes=0.05, period_ms=2.0)
        with probe:
            import time
            time.sleep(0.05)
            with pytest.raises(RuntimeError):
                probe.start()
        assert probe.passes > 0
        probe.stop()                          # idempotent

    def test_probe_demand_validated(self):
        from repro.profiling import probes
        with pytest.raises(ValueError):
            probes.MemoryProbe(demand=0.0)
        with pytest.raises(ValueError):
            probes.MemoryProbe(demand=1.5)


class TestJaxHarness:
    def test_measure_arch_smoke(self):
        from repro import configs
        from repro.configs.base import ShapeCell
        cfg = configs.get("stablelm-1.6b").reduced()
        cell = ShapeCell("prefill_64", 64, 1, "prefill")
        measured = profiling.measure_arch(
            cfg, cell, backend="xla",
            timer=TimerConfig(warmup=1, repeats=3), max_groups=1)
        assert len(measured) == 1
        mg = measured[0]
        assert mg.ms > 0.0 and mg.costs.flops > 0 and mg.costs.hbm_bytes > 0

    def test_graph_from_measurements(self, platform):
        from repro.core.characterize import GroupCosts
        from repro.profiling.harness import MeasuredGroup, Measurement
        measured = [MeasuredGroup(
            GroupCosts(name=f"g{i}", flops=1e9 * (i + 1),
                       hbm_bytes=1e7 * (i + 1), shared_bytes=1e7 * (i + 1),
                       out_bytes=1e5),
            Measurement(f"g{i}", (0.5 + 0.1 * i,))) for i in range(3)]
        g = profiling.graph_from_measurements("m", platform, measured)
        assert len(g) == 3
        # anchor column carries the measured time verbatim
        assert g.groups[0].time_on("GPU") == pytest.approx(0.5)
        assert g.groups[0].time_on("DLA") > 0
        assert 0.0 < g.groups[0].demand_on("GPU") <= 1.5
        # it is schedulable as-is
        Scheduler(platform).solve([g], max_transitions=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestProfileCLI:
    def test_virtual_pipeline_with_solve(self, tmp_path, capsys):
        from repro.launch.profile import main
        out = tmp_path / "bundle.json"
        rc = main(["--platform", "xavier-agx", "--dnns", "vgg19",
                   "resnet101", "--out", str(out), "--solve",
                   "--repeats", "5"])
        assert rc == 0
        text = capsys.readouterr().out
        assert "round-trip verified" in text
        assert "rel-diff" in text
        b = ProfileBundle.load(out)
        assert b.graph_names == ("vgg19", "resnet101")

    def test_bad_ext_levels_rejected(self):
        from repro.launch.profile import main
        with pytest.raises(SystemExit):
            main(["--ext-levels", "0.5,-1.0"])

"""Integration: graph export -> scheduler -> serving plan; sharding rules."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, ShapeCell
from repro.core import api as core_api
from repro.core.accelerators import tpu_pod_split
from repro.core.simulate import Workload, simulate
from repro.models import sharding
from repro.models.graph_export import export_graph
from repro.serve.concurrent import plan_concurrent_serving


class TestGraphExport:
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-7b",
                                      "dbrx-132b", "recurrentgemma-9b"])
    @pytest.mark.parametrize("shape", ["decode_32k", "prefill_32k"])
    def test_exports_schedulable_graph(self, arch, shape):
        cfg = configs.get(arch)
        ok, _ = configs.cell_supported(cfg, shape)
        if not ok:
            pytest.skip("cell not supported")
        plat = tpu_pod_split()
        g = export_graph(cfg, SHAPES[shape], plat)
        assert len(g) >= 3                      # embed + layers + head
        for acc in plat.names:
            assert g.standalone_time(acc) > 0
        for grp in g:
            assert grp.flops >= 0 and grp.out_bytes >= 0
            for a, dem in grp.mem_demand.items():
                assert 0 <= dem <= 1.5

    def test_moe_decode_cheaper_than_dense_of_same_total_size(self):
        """Active-params accounting: qwen3 (235B total, ~22B active) decode
        groups must be far cheaper than a hypothetical dense 235B."""
        plat = tpu_pod_split()
        cfg = configs.get("qwen3-moe-235b-a22b")
        g = export_graph(cfg, SHAPES["decode_32k"], plat)
        t = g.standalone_time("MESH_A")
        assert t < 100.0                        # ms; dense-235B would be ~4x


class TestConcurrentPlanning:
    def test_plan_never_worse_than_baselines(self):
        plan = plan_concurrent_serving(
            [configs.get("llama3.2-3b"), configs.get("stablelm-1.6b")],
            ["decode_32k", "decode_32k"], objective="latency",
            deadline_s=5.0)
        assert plan.plan is not None            # provenance artifact
        assert plan.plan.solver in ("z3", "bb", "greedy")
        for name, res in plan.baselines.items():
            if not core_api.failed(res):
                assert (plan.solution.result.latency_ms
                        <= res.latency_ms + 1e-9), name

    def test_schedule_executes_in_simulator(self):
        plan = plan_concurrent_serving(
            [configs.get("rwkv6-7b"), configs.get("nemotron-4-15b")],
            [ShapeCell("s", 2048, 64, "decode")] * 2,
            objective="throughput", deadline_s=5.0)
        res = simulate(plan.platform, plan.solution.workloads,
                       core_api.default_model(plan.platform))
        assert res.makespan == pytest.approx(
            plan.solution.result.makespan, rel=1e-9)


class TestShardingRules:
    MESH_AXES = ("data", "model")

    def make_mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, self.MESH_AXES)

    def test_axis_used_once(self):
        rules = {"batch": ("pod", "data"), "embed": "data", "seq": None}
        s = sharding.spec(rules, ("batch", "seq", "embed"),
                          self.make_mesh())
        # data consumed by batch; embed falls back to None
        assert s == P("data")

    def test_missing_mesh_axis_dropped(self):
        rules = {"batch": ("pod", "data")}           # no 'pod' axis in mesh
        s = sharding.spec(rules, ("batch",), self.make_mesh())
        assert s == P("data")

    def test_divisibility_fallback(self):
        rules = {"heads": "model"}
        mesh = self.make_mesh()
        ns = sharding.named_sharding(mesh, rules, ("heads", None),
                                     shape=(40, 128))
        # 40 % 1 == 0 on this 1-device mesh -> kept; logic exercised at
        # scale in the dry-run (40 heads over 16 -> dropped)
        assert isinstance(ns.spec, P)

    def test_zero3_rules_have_no_tensor_axes(self):
        rules = dict(configs.RULES_ZERO3)
        for name in ("heads", "mlp", "vocab", "kv_heads"):
            s = sharding.spec(rules, (name,), self.make_mesh())
            assert s == P()


class TestRooflineAnalysis:
    def test_collective_parse(self):
        from repro.analysis import roofline
        hlo = """
  %all-reduce.2 = f32[16,1024]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true
  %all-gather.3 = bf16[32,2048]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={1}
  %x = f32[8,8]{1,0} add(%a, %b)
"""
        st = roofline.parse_collectives(hlo)
        assert st.op_counts == {"all-reduce": 1, "all-gather": 1}
        ar = 16 * 1024 * 4
        ag = 32 * 2048 * 2 / 16
        assert st.operand_bytes == pytest.approx(ar + ag)

    def test_analytic_bytes_monotone_in_depth(self):
        from repro.analysis import roofline
        import dataclasses
        cfg = configs.get("llama3.2-3b")
        cell = SHAPES["train_4k"]
        b1 = roofline.analytic_hbm_bytes(cfg, cell)
        b2 = roofline.analytic_hbm_bytes(
            dataclasses.replace(cfg, n_layers=cfg.n_layers * 2), cell)
        assert b2 > b1

    def test_decode_bytes_dominated_by_weights_and_cache(self):
        from repro.analysis import roofline
        cfg = configs.get("llama3.2-3b")
        cell = SHAPES["decode_32k"]
        total = roofline.analytic_hbm_bytes(cfg, cell)
        weights = cfg.n_params() * 4
        assert total > weights                    # cache adds on top
        assert total < weights * 40               # but stays decode-like

"""Regenerate the golden Plan fixtures (one per Table-6 scenario type).

Run from the repo root after an *intentional* solver/simulator/profile
change:

    PYTHONPATH=src python tests/fixtures/plans/regenerate.py

The fixtures pin the full scheduling problem (graphs, platform, contention
model) plus the solved schedule; ``tests/test_plan_golden.py`` re-solves the
deserialized request on today's code and asserts identical objectives and
assignments, so an unintentional behaviour change in the solver or either
simulator fails loudly.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve()
                       .parents[3] / "src"))

from repro.core import Scheduler                              # noqa: E402
from repro.core.profiles import chain, get_graph              # noqa: E402

HERE = pathlib.Path(__file__).resolve().parent

#: fixture name -> (platform, objective, graph builder, iterations, deps)
#: — one experiment per Table-6 scenario type (§5.2): concurrent (2),
#: streaming pipeline (3), serial chain + third DNN (4).
SCENARIOS = {
    "scenario2-exp1-xavier-vgg19-resnet152": (
        "xavier-agx", "latency",
        lambda p: [get_graph("vgg19", p), get_graph("resnet152", p)],
        [1, 1], [None, None]),
    "scenario3-exp3-xavier-alexnet-resnet101": (
        "xavier-agx", "throughput",
        lambda p: [get_graph("alexnet", p), get_graph("resnet101", p)],
        [4, 4], [None, 0]),
    "scenario4-exp8-orin-resnet101-googlenet-inception": (
        "agx-orin", "latency",
        lambda p: [chain(get_graph("resnet101", p),
                         get_graph("googlenet", p)),
                   get_graph("inception", p)],
        [1, 1], [None, None]),
}


def main() -> None:
    for name, (plat, objective, build, its, deps) in SCENARIOS.items():
        sched = Scheduler(plat)
        plan = sched.solve(build(sched.platform), objective, solver="bb",
                           max_transitions=2, iterations=its,
                           depends_on=deps)
        path = plan.save(HERE / f"{name}.json")
        print(f"{path.name}: {plan.solver}/{plan.evaluator} "
              f"{plan.solution.kind}={plan.objective:.6f} "
              f"optimal={plan.optimal}")


if __name__ == "__main__":
    main()
